"""CI gate for ``python -m repro.analysis.lint`` (fast tier).

Two directions:
  * the LIVE repo is clean — all four passes (source, fingerprint,
    metrics, invariants) report zero findings, and the CLI exits 0.
    This is the gate that keeps every repo contract (jax-free-at-import,
    traced purity, fail-fast ordering, docstring coverage, fingerprint
    coverage, metric-registry coverage, benchmark-record conformance)
    enforced from here on;
  * each pass actually FIRES — scratch fixture trees with forced
    violations (module-scope ``import jax`` in a gated file, a
    wall-clock call or ``open()`` in a traced package, an
    un-fingerprinted ChocoConfig field, an unregistered emitted metric
    key, a doctored benchmark record) must produce a non-zero exit with
    a pointed finding.
"""
import os
import subprocess
import sys
import textwrap

from repro.analysis.lint import run_passes
from repro.analysis.source_lint import (docstring_findings,
                                        lint_failfast_order,
                                        lint_jax_free,
                                        lint_traced_purity)

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(ROOT, "src")


def _run_cli(*args, cwd=ROOT):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-m", "repro.analysis.lint",
                           *args], env=env, cwd=cwd, capture_output=True,
                          text=True, timeout=120)


def _write(tmp_path, rel, text):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(text))
    return str(tmp_path)


# --------------------------------------------------------------------------
# the repo is clean
# --------------------------------------------------------------------------

def test_live_repo_has_zero_findings():
    findings = run_passes(ROOT)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_cli_exits_zero_on_live_repo():
    r = _run_cli("--root", ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "clean" in r.stdout


def test_cli_only_selects_single_pass():
    r = _run_cli("--root", ROOT, "--only", "invariants")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[invariants]" in r.stdout


# --------------------------------------------------------------------------
# forced violations fire, with pointed findings and non-zero exit
# --------------------------------------------------------------------------

def test_module_scope_jax_import_in_gated_file_fires(tmp_path):
    root = _write(tmp_path, "src/repro/configs/evil.py", '''\
        """A gated config module that illegally imports jax."""
        import jax
        ''')
    findings = lint_jax_free(root)
    assert len(findings) == 1
    f = findings[0]
    assert f.path == "src/repro/configs/evil.py" and f.line == 2
    assert "jax-free-at-import" in f.message
    # conditional/try nesting at module scope is still module scope
    root2 = _write(tmp_path / "t2", "src/repro/kernels/dispatch.py", '''\
        """Gated dispatch with a try-hidden jax import."""
        try:
            from jax.experimental import pallas
        except ImportError:
            pallas = None
        ''')
    assert len(lint_jax_free(root2)) == 1
    # ...but TYPE_CHECKING blocks don't execute at import
    root3 = _write(tmp_path / "t3", "src/repro/configs/ok.py", '''\
        """Gated config with a typing-only jax import (legal)."""
        from typing import TYPE_CHECKING
        if TYPE_CHECKING:
            import jax
        ''')
    assert lint_jax_free(root3) == []


def test_wall_clock_and_host_rng_in_traced_package_fire(tmp_path):
    root = _write(tmp_path, "src/repro/core/evil.py", '''\
        """Traced module breaking the purity contract three ways."""
        import random
        import time

        import numpy as np


        def round_fn(x):
            """Bad round function."""
            t0 = time.time()
            jitter = random.random()
            noise = np.random.rand(4)
            good = np.random.default_rng(0)
            return x + jitter + noise.sum() + t0
        ''')
    findings = lint_traced_purity(root)
    msgs = [f.message for f in findings]
    assert len(findings) == 3, msgs
    assert any("time.time" in m for m in msgs)
    assert any("random.random" in m for m in msgs)
    assert any("np.random.rand" in m for m in msgs)
    # the seeded generator (line 13) was NOT flagged
    assert 13 not in [f.line for f in findings]


def test_jax_random_is_not_mistaken_for_stdlib_random(tmp_path):
    root = _write(tmp_path, "src/repro/comm/fine.py", '''\
        """Traced module using jax.random correctly."""
        from jax import random


        def round_fn(key, x):
            """Draws from the traced key — allowed."""
            return x + random.normal(key, x.shape)
        ''')
    assert lint_traced_purity(root) == []


def test_failfast_after_jax_import_fires(tmp_path):
    root = _write(tmp_path, "src/repro/launch/train.py", '''\
        """Launcher with a validation error AFTER device init."""
        import argparse


        def main(argv=None):
            """Bad main."""
            ap = argparse.ArgumentParser()
            ap.add_argument("--n", type=int)
            args = ap.parse_args(argv)
            import jax
            if args.n < 0:
                ap.error("n must be non-negative")
            if args.n > 99:
                raise SystemExit(2)
            return jax.device_count()
        ''')
    findings = lint_failfast_order(root)
    assert len(findings) == 2, [f.render() for f in findings]
    assert all("after the first `import jax`" in f.message
               for f in findings)


def test_missing_docstrings_fire(tmp_path):
    root = _write(tmp_path, "src/repro/core/bare.py", '''\
        import dataclasses
        from typing import NamedTuple


        def naked():
            return 1


        class Undocumented:
            pass


        @dataclasses.dataclass
        class AutoDoc:
            x: int = 0


        class AutoTuple(NamedTuple):
            y: int
        ''')
    findings = docstring_findings(root)
    msgs = [f.message for f in findings]
    # module + naked() + Undocumented fire; dataclass/NamedTuple exempt
    assert len(findings) == 3, msgs
    assert any("module docstring" in m for m in msgs)
    assert any("`naked`" in m for m in msgs)
    assert any("`Undocumented`" in m for m in msgs)


def test_unfingerprinted_choco_field_fires_via_cli(tmp_path):
    root = _write(tmp_path, "src/repro/configs/base.py", '''\
        """Scratch ChocoConfig with an uncovered field."""
        import dataclasses


        @dataclasses.dataclass
        class ChocoConfig:
            compressor: str = "top_k"
            new_knob: int = 0
        ''')
    _write(tmp_path, "src/repro/train/trainer.py", '''\
        """Scratch trainer whose fingerprint misses new_knob."""
        FINGERPRINT_EXEMPT = {}


        class DecentralizedTrainer:
            """Scratch trainer."""

            def fingerprint(self):
                """Covers compressor only."""
                return {"compressor": self.choco.compressor}
        ''')
    r = _run_cli("--root", root, "--only", "fingerprint")
    assert r.returncode == 1, r.stdout + r.stderr
    assert "ChocoConfig.new_knob" in r.stdout
    assert "src/repro/configs/base.py:8" in r.stdout


def test_doctored_bench_record_fires_via_cli(tmp_path):
    import json
    (tmp_path / "BENCH_overlap.json").write_text(json.dumps(
        {"serial": {"permute_launches": 16, "dots_total": 30,
                    "dots_feeding_collective": 30},
         "pipelined": {"permute_launches": 17, "dots_total": 30,
                       "dots_feeding_collective": 0}}))
    r = _run_cli("--root", str(tmp_path), "--only", "invariants")
    assert r.returncode == 1, r.stdout + r.stderr
    assert "permute_launches = 17" in r.stdout


def test_file_io_in_traced_package_fires(tmp_path):
    root = _write(tmp_path, "src/repro/core/evil_io.py", '''\
        """Traced module doing file I/O inside a round function."""


        def round_fn(x):
            """Bad round function: reads a file mid-trace."""
            with open("gamma.txt") as f:
                return x * float(f.read())
        ''')
    findings = lint_traced_purity(root)
    assert len(findings) == 1, [f.render() for f in findings]
    assert "open()" in findings[0].message
    assert "obs/sinks.py" in findings[0].message   # points at the fix


def test_host_side_obs_modules_are_purity_exempt(tmp_path):
    # sinks.py owns the run-log file and the wall clock by design
    root = _write(tmp_path, "src/repro/obs/sinks.py", '''\
        """Host-side sink: clocks and file I/O are its job."""
        import time


        def append(path, line):
            """Append a line, stamped."""
            with open(path, "a") as f:
                f.write(f"{time.time()} {line}")
        ''')
    assert lint_traced_purity(root) == []
    # ...but the in-graph diagnostics module gets no such pass
    root2 = _write(tmp_path / "t2", "src/repro/obs/metrics.py", '''\
        """In-graph diagnostics illegally touching the filesystem."""


        def diagnostics(state):
            """Bad diagnostics."""
            with open("xi.txt") as f:
                return float(f.read())
        ''')
    assert len(lint_traced_purity(root2)) == 1


def test_unregistered_and_stale_metric_keys_fire_via_cli(tmp_path):
    root = _write(tmp_path, "src/repro/obs/schema.py", '''\
        """Scratch registry: one live metric, one stale."""
        METRIC_SPECS = (
            MetricSpec("train/loss", "nats", "mean LM loss"),
            MetricSpec("train/ghost", "1", "registered but never emitted"),
        )
        ''')
    _write(tmp_path, "src/repro/launch/emit.py", '''\
        """Scratch emitter with one registered and one unregistered key."""


        def report(mlog, step, loss, wobble):
            """Emit a step record."""
            mlog.emit(step, {"train/loss": loss, "train/wobble": wobble})
        ''')
    r = _run_cli("--root", root, "--only", "metrics")
    assert r.returncode == 1, r.stdout + r.stderr
    assert "'train/wobble' is not registered" in r.stdout
    assert "src/repro/launch/emit.py:6" in r.stdout
    assert "stale registry entry 'train/ghost'" in r.stdout
    assert "src/repro/obs/schema.py:4" in r.stdout
    # path-ish strings outside the registered namespaces never fire
    root2 = _write(tmp_path / "t2", "src/repro/obs/schema.py", '''\
        """Scratch registry."""
        METRIC_SPECS = (
            MetricSpec("train/loss", "nats", "mean LM loss"),
        )
        ''')
    _write(tmp_path / "t2", "src/repro/launch/ok.py", '''\
        """Emitter whose config-path string must not count as a metric."""


        def report(mlog, step, loss):
            """Emit a step record."""
            mlog.emit(step, {"train/loss": loss}, extra={"cfg": "launch/env"})
        ''')
    r2 = _run_cli("--root", str(tmp_path / "t2"), "--only", "metrics")
    assert r2.returncode == 0, r2.stdout + r2.stderr


def test_malformed_registry_literal_fires(tmp_path):
    from repro.analysis.metrics_lint import run_metrics_lint
    root = _write(tmp_path, "src/repro/obs/schema.py", '''\
        """Registry with a computed entry and a duplicate."""
        METRIC_SPECS = (
            MetricSpec("train/loss", "nats", "mean LM loss"),
            MetricSpec("train/loss", "nats", "duplicate"),
            MetricSpec("BadName", "1", "violates the key regex"),
            MetricSpec("train/" + kind, "1", "non-literal name"),
        )
        ''')
    _write(tmp_path, "src/repro/launch/emit.py", '''\
        """Keeps train/loss emitted."""


        def report(mlog, step, loss):
            """Emit."""
            mlog.emit(step, {"train/loss": loss})
        ''')
    msgs = [f.message for f in run_metrics_lint(root)]
    assert any("duplicate metric name" in m for m in msgs), msgs
    assert any("does not match" in m for m in msgs), msgs
    assert any("string literals" in m for m in msgs), msgs


def test_fingerprint_exemption_contradiction_and_staleness(tmp_path):
    from repro.analysis.fingerprint_lint import run_fingerprint_lint
    root = _write(tmp_path, "src/repro/configs/base.py", '''\
        """Scratch config."""
        import dataclasses


        @dataclasses.dataclass
        class ChocoConfig:
            compressor: str = "top_k"
        ''')
    _write(tmp_path, "src/repro/train/trainer.py", '''\
        """Trainer that both fingerprints and exempts, plus a stale entry."""
        FINGERPRINT_EXEMPT = {
            "compressor": "covered twice",
            "ghost_field": "exempts a field that no longer exists",
        }


        class DecentralizedTrainer:
            """Scratch trainer."""

            def fingerprint(self):
                """Covers compressor."""
                return {"compressor": self.choco.compressor}
        ''')
    msgs = [f.message for f in run_fingerprint_lint(root)]
    assert len(msgs) == 2, msgs
    assert any("both fingerprinted and listed" in m for m in msgs)
    assert any("ghost_field" in m and "stale exemption" in m for m in msgs)
