"""CI gate for ``python -m repro.analysis.lint`` (fast tier).

Two directions:
  * the LIVE repo is clean — all three passes (source, fingerprint,
    invariants) report zero findings, and the CLI exits 0.  This is the
    gate that keeps every repo contract (jax-free-at-import, traced
    purity, fail-fast ordering, docstring coverage, fingerprint coverage,
    benchmark-record conformance) enforced from here on;
  * each pass actually FIRES — scratch fixture trees with forced
    violations (module-scope ``import jax`` in a gated file, a
    wall-clock call in a traced package, an un-fingerprinted ChocoConfig
    field, a doctored benchmark record) must produce a non-zero exit
    with a pointed finding.
"""
import os
import subprocess
import sys
import textwrap

from repro.analysis.lint import run_passes
from repro.analysis.source_lint import (docstring_findings,
                                        lint_failfast_order,
                                        lint_jax_free,
                                        lint_traced_purity)

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(ROOT, "src")


def _run_cli(*args, cwd=ROOT):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-m", "repro.analysis.lint",
                           *args], env=env, cwd=cwd, capture_output=True,
                          text=True, timeout=120)


def _write(tmp_path, rel, text):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(text))
    return str(tmp_path)


# --------------------------------------------------------------------------
# the repo is clean
# --------------------------------------------------------------------------

def test_live_repo_has_zero_findings():
    findings = run_passes(ROOT)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_cli_exits_zero_on_live_repo():
    r = _run_cli("--root", ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "clean" in r.stdout


def test_cli_only_selects_single_pass():
    r = _run_cli("--root", ROOT, "--only", "invariants")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[invariants]" in r.stdout


# --------------------------------------------------------------------------
# forced violations fire, with pointed findings and non-zero exit
# --------------------------------------------------------------------------

def test_module_scope_jax_import_in_gated_file_fires(tmp_path):
    root = _write(tmp_path, "src/repro/configs/evil.py", '''\
        """A gated config module that illegally imports jax."""
        import jax
        ''')
    findings = lint_jax_free(root)
    assert len(findings) == 1
    f = findings[0]
    assert f.path == "src/repro/configs/evil.py" and f.line == 2
    assert "jax-free-at-import" in f.message
    # conditional/try nesting at module scope is still module scope
    root2 = _write(tmp_path / "t2", "src/repro/kernels/dispatch.py", '''\
        """Gated dispatch with a try-hidden jax import."""
        try:
            from jax.experimental import pallas
        except ImportError:
            pallas = None
        ''')
    assert len(lint_jax_free(root2)) == 1
    # ...but TYPE_CHECKING blocks don't execute at import
    root3 = _write(tmp_path / "t3", "src/repro/configs/ok.py", '''\
        """Gated config with a typing-only jax import (legal)."""
        from typing import TYPE_CHECKING
        if TYPE_CHECKING:
            import jax
        ''')
    assert lint_jax_free(root3) == []


def test_wall_clock_and_host_rng_in_traced_package_fire(tmp_path):
    root = _write(tmp_path, "src/repro/core/evil.py", '''\
        """Traced module breaking the purity contract three ways."""
        import random
        import time

        import numpy as np


        def round_fn(x):
            """Bad round function."""
            t0 = time.time()
            jitter = random.random()
            noise = np.random.rand(4)
            good = np.random.default_rng(0)
            return x + jitter + noise.sum() + t0
        ''')
    findings = lint_traced_purity(root)
    msgs = [f.message for f in findings]
    assert len(findings) == 3, msgs
    assert any("time.time" in m for m in msgs)
    assert any("random.random" in m for m in msgs)
    assert any("np.random.rand" in m for m in msgs)
    # the seeded generator (line 13) was NOT flagged
    assert 13 not in [f.line for f in findings]


def test_jax_random_is_not_mistaken_for_stdlib_random(tmp_path):
    root = _write(tmp_path, "src/repro/comm/fine.py", '''\
        """Traced module using jax.random correctly."""
        from jax import random


        def round_fn(key, x):
            """Draws from the traced key — allowed."""
            return x + random.normal(key, x.shape)
        ''')
    assert lint_traced_purity(root) == []


def test_failfast_after_jax_import_fires(tmp_path):
    root = _write(tmp_path, "src/repro/launch/train.py", '''\
        """Launcher with a validation error AFTER device init."""
        import argparse


        def main(argv=None):
            """Bad main."""
            ap = argparse.ArgumentParser()
            ap.add_argument("--n", type=int)
            args = ap.parse_args(argv)
            import jax
            if args.n < 0:
                ap.error("n must be non-negative")
            if args.n > 99:
                raise SystemExit(2)
            return jax.device_count()
        ''')
    findings = lint_failfast_order(root)
    assert len(findings) == 2, [f.render() for f in findings]
    assert all("after the first `import jax`" in f.message
               for f in findings)


def test_missing_docstrings_fire(tmp_path):
    root = _write(tmp_path, "src/repro/core/bare.py", '''\
        import dataclasses
        from typing import NamedTuple


        def naked():
            return 1


        class Undocumented:
            pass


        @dataclasses.dataclass
        class AutoDoc:
            x: int = 0


        class AutoTuple(NamedTuple):
            y: int
        ''')
    findings = docstring_findings(root)
    msgs = [f.message for f in findings]
    # module + naked() + Undocumented fire; dataclass/NamedTuple exempt
    assert len(findings) == 3, msgs
    assert any("module docstring" in m for m in msgs)
    assert any("`naked`" in m for m in msgs)
    assert any("`Undocumented`" in m for m in msgs)


def test_unfingerprinted_choco_field_fires_via_cli(tmp_path):
    root = _write(tmp_path, "src/repro/configs/base.py", '''\
        """Scratch ChocoConfig with an uncovered field."""
        import dataclasses


        @dataclasses.dataclass
        class ChocoConfig:
            compressor: str = "top_k"
            new_knob: int = 0
        ''')
    _write(tmp_path, "src/repro/train/trainer.py", '''\
        """Scratch trainer whose fingerprint misses new_knob."""
        FINGERPRINT_EXEMPT = {}


        class DecentralizedTrainer:
            """Scratch trainer."""

            def fingerprint(self):
                """Covers compressor only."""
                return {"compressor": self.choco.compressor}
        ''')
    r = _run_cli("--root", root, "--only", "fingerprint")
    assert r.returncode == 1, r.stdout + r.stderr
    assert "ChocoConfig.new_knob" in r.stdout
    assert "src/repro/configs/base.py:8" in r.stdout


def test_doctored_bench_record_fires_via_cli(tmp_path):
    import json
    (tmp_path / "BENCH_overlap.json").write_text(json.dumps(
        {"serial": {"permute_launches": 16, "dots_total": 30,
                    "dots_feeding_collective": 30},
         "pipelined": {"permute_launches": 17, "dots_total": 30,
                       "dots_feeding_collective": 0}}))
    r = _run_cli("--root", str(tmp_path), "--only", "invariants")
    assert r.returncode == 1, r.stdout + r.stderr
    assert "permute_launches = 17" in r.stdout


def test_fingerprint_exemption_contradiction_and_staleness(tmp_path):
    from repro.analysis.fingerprint_lint import run_fingerprint_lint
    root = _write(tmp_path, "src/repro/configs/base.py", '''\
        """Scratch config."""
        import dataclasses


        @dataclasses.dataclass
        class ChocoConfig:
            compressor: str = "top_k"
        ''')
    _write(tmp_path, "src/repro/train/trainer.py", '''\
        """Trainer that both fingerprints and exempts, plus a stale entry."""
        FINGERPRINT_EXEMPT = {
            "compressor": "covered twice",
            "ghost_field": "exempts a field that no longer exists",
        }


        class DecentralizedTrainer:
            """Scratch trainer."""

            def fingerprint(self):
                """Covers compressor."""
                return {"compressor": self.choco.compressor}
        ''')
    msgs = [f.message for f in run_fingerprint_lint(root)]
    assert len(msgs) == 2, msgs
    assert any("both fingerprinted and listed" in m for m in msgs)
    assert any("ghost_field" in m and "stale exemption" in m for m in msgs)
