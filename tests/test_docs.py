"""Docs checks (fast tier): the documentation surface must not rot.

Three contracts:
  * every relative markdown link in README / docs/ / EXPERIMENTS / ROADMAP
    resolves to a real file;
  * every public symbol in EVERY ``src/repro`` package (and each module
    itself) carries a docstring — checked per-package through the shared
    AST gate in ``repro.analysis.source_lint`` (the same pass
    ``python -m repro.analysis.lint`` runs), so the test suite and the
    lint CLI can never disagree;
  * the README fail-fast matrix IS the launcher's behaviour: every row is
    run verbatim through ``launch/train.py`` and must exit pre-jax with
    SystemExit(2), and every CLI choice the launcher accepts
    (topologies, processes, modes, engines) is documented in the README.
"""
import os
import re
import shlex

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

DOC_FILES = ["README.md", "EXPERIMENTS.md", "ROADMAP.md", "CHANGES.md",
             "PAPER.md", "docs/ARCHITECTURE.md"]

# [text](target) — excluding images and in-page anchors
_LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")


def _md_files():
    out = [p for p in DOC_FILES if os.path.exists(os.path.join(ROOT, p))]
    assert "README.md" in out, "root README.md must exist"
    assert "docs/ARCHITECTURE.md" in out, "docs/ARCHITECTURE.md must exist"
    return out


def test_markdown_links_resolve():
    """Every relative link in the docs resolves (anchors stripped; http(s)
    and mailto skipped — we don't hit the network in tests)."""
    broken = []
    for rel in _md_files():
        path = os.path.join(ROOT, rel)
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            if not os.path.exists(resolved):
                broken.append(f"{rel}: {m.group(1)}")
    assert not broken, f"broken relative links: {broken}"


def _repro_packages():
    from repro.analysis.source_lint import repro_packages
    pkgs = repro_packages(ROOT)
    # the historical gate covered these four; the generalized AST pass
    # must never cover less
    assert {"comm", "core", "checkpoint", "kernels"} <= set(pkgs), pkgs
    return pkgs


@pytest.mark.parametrize("package", _repro_packages())
def test_public_api_has_docstrings(package):
    """Module docstrings + docstrings on every public top-level
    class/function in the package — delegated to the shared AST gate
    (``repro.analysis.source_lint.docstring_findings``), one package per
    test so a regression names its package."""
    from repro.analysis.source_lint import docstring_findings

    missing = [f.render() for f in docstring_findings(ROOT, [package])]
    assert not missing, \
        f"public {package} symbols without docstrings: {missing}"


def _failfast_rows():
    with open(os.path.join(ROOT, "README.md"), encoding="utf-8") as f:
        text = f.read()
    m = re.search(r"<!-- failfast-matrix:begin -->(.*?)"
                  r"<!-- failfast-matrix:end -->", text, re.S)
    assert m, "README.md must carry the failfast-matrix markers"
    rows = []
    for line in m.group(1).splitlines():
        cell = re.match(r"\|\s*`([^`]+)`\s*\|", line)
        if cell:
            rows.append(cell.group(1))
    assert len(rows) >= 10, f"suspiciously small fail-fast matrix: {rows}"
    return rows


@pytest.mark.parametrize("flags", _failfast_rows())
def test_readme_failfast_rows_are_rejected(flags, capsys):
    """Every row of the README fail-fast matrix is rejected by the real
    launcher, pre-jax (argparse.error -> SystemExit(2)).  A row that starts
    training instead would hang this fast-tier test — the matrix cannot
    drift from the code."""
    from repro.launch.train import main
    with pytest.raises(SystemExit) as ei:
        main(["--arch", "qwen3-1.7b", "--smoke"] + shlex.split(flags))
    assert ei.value.code == 2, (flags, capsys.readouterr().err)


def test_readme_documents_every_cli_choice():
    """The README CLI matrix mentions every accepted topology, process,
    mode, and gossip engine the launcher exposes (the reverse direction of
    the fail-fast rows: nothing the CLI accepts is undocumented)."""
    from repro.launch.train import PROCESS_CHOICES, TOPOLOGY_CHOICES
    with open(os.path.join(ROOT, "README.md"), encoding="utf-8") as f:
        text = f.read()
    undocumented = [
        c for c in (TOPOLOGY_CHOICES + PROCESS_CHOICES
                    + ("choco", "plain", "allreduce", "pushsum",
                       "packed", "per-leaf"))
        if c != "none" and f"`{c}`" not in text]
    assert not undocumented, f"CLI choices missing from README: {undocumented}"


def test_readme_documents_telemetry_flags():
    """The telemetry surface (observability PR) stays documented: every
    run-log / diagnostics / profiler flag appears backticked in the README
    CLI matrix, and the architecture doc carries the Observability
    section the table links to."""
    with open(os.path.join(ROOT, "README.md"), encoding="utf-8") as f:
        readme = f.read()
    missing = [flag for flag in
               ("--metrics-dir", "--diag-every", "--divergence-action",
                "--profile-dir", "--profile-steps", "--requests")
               if f"`{flag}" not in readme]
    assert not missing, f"telemetry flags missing from README: {missing}"
    with open(os.path.join(ROOT, "docs", "ARCHITECTURE.md"),
              encoding="utf-8") as f:
        arch = f.read()
    assert "## Observability" in arch
    for anchor in ("obs/schema.py", "obs/metrics.py", "obs/sinks.py",
                   "telemetry_off", "telemetry_diag"):
        assert anchor in arch, f"ARCHITECTURE.md Observability must " \
                               f"mention {anchor}"
