"""Per-kernel shape/dtype sweeps, assert_allclose against ref.py oracles
(interpret mode on CPU; kernels TARGET TPU tiles)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from optional_hypothesis import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.qsgd import qsgd_quantize, qsgd_dequantize
from repro.kernels.topk import block_topk_mask
from repro.kernels.ef_update import ef_gossip_update
from repro.kernels.flash_attention import flash_attention

KEY = jax.random.PRNGKey(0)


def _tiles(seed, R, C=128, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (R, C)) * scale


# -- qsgd ----------------------------------------------------------------------

@pytest.mark.parametrize("R", [8, 24, 64])
@pytest.mark.parametrize("s", [4, 16, 127])
def test_qsgd_kernel_matches_ref(R, s):
    x = _tiles(R + s, R)
    xi = jax.random.uniform(jax.random.PRNGKey(1), (R, 128))
    ck, sk = qsgd_quantize(x, xi, s)
    cr, sr = ref.qsgd_quantize_ref(x, xi, s)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_allclose(float(sk), float(sr), rtol=1e-6)
    yk = qsgd_dequantize(ck, sk)
    np.testing.assert_allclose(np.asarray(yk),
                               np.asarray(ref.qsgd_dequantize_ref(cr, sr)),
                               rtol=1e-6)


def test_qsgd_kernel_contraction():
    """Kernel output satisfies Assumption 1 with omega = 1/tau."""
    import math
    d = 64 * 128
    x = _tiles(7, 64, scale=2.0)
    errs = []
    for i in range(20):
        xi = jax.random.uniform(jax.random.PRNGKey(i), (64, 128))
        c, s = qsgd_quantize(x, xi, 16)
        q = qsgd_dequantize(c, s)
        errs.append(float(jnp.sum((q - x) ** 2)))
    tau = 1.0 + min(d / 256, math.sqrt(d) / 16)
    assert np.mean(errs) <= (1 - 1 / tau) * float(jnp.sum(x * x)) * 1.1


def test_qsgd_zero_vector():
    x = jnp.zeros((8, 128))
    xi = jnp.zeros((8, 128))
    c, s = qsgd_quantize(x, xi, 16)
    assert float(jnp.sum(jnp.abs(qsgd_dequantize(c, s)))) == 0.0


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 12), st.integers(2, 127), st.integers(0, 10 ** 6))
def test_qsgd_vector_roundtrip_hypothesis(blocks, s, seed):
    d = blocks * 997                     # deliberately unaligned
    x = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    xi = jax.random.uniform(jax.random.PRNGKey(seed + 1), (d,))
    codes, scale = ops.qsgd_compress_vector(x, xi, s)
    y = ops.qsgd_decompress_vector(codes, scale)
    assert y.shape == x.shape
    # contraction (deterministic given xi: compare directly)
    assert float(jnp.sum((y - x) ** 2)) <= float(jnp.sum(x * x)) * 1.0 + 1e-6


# -- block top-k -----------------------------------------------------------------

@pytest.mark.parametrize("R", [8, 32])
@pytest.mark.parametrize("k", [1, 5, 64, 128])
def test_block_topk_matches_ref(R, k):
    x = _tiles(R * k, R)
    mk, tk = block_topk_mask(x, k)
    mr, tr = ref.block_topk_mask_ref(x, k)
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr), rtol=1e-6)


def test_block_topk_counts():
    x = _tiles(3, 8)
    mask, _ = block_topk_mask(x, 10)
    counts = np.asarray(jnp.sum(mask, axis=1))
    assert (counts >= 10).all() and (counts <= 12).all()


def test_block_topk_selects_largest():
    x = _tiles(4, 8)
    mask, _ = block_topk_mask(x, 4)
    mag = np.abs(np.asarray(x))
    for r in range(8):
        sel = mag[r][np.asarray(mask[r]) > 0]
        unsel = mag[r][np.asarray(mask[r]) == 0]
        assert sel.min() >= unsel.max() - 1e-6


def test_block_topk_contraction():
    """Blockwise top-k satisfies Assumption 1 with omega ~= k/C."""
    x = _tiles(11, 16, scale=3.0)
    q = x * block_topk_mask(x, 13)[0]
    lhs = float(jnp.sum((q - x) ** 2))
    assert lhs <= (1 - 13 / 128) * float(jnp.sum(x * x)) + 1e-5


# -- ef update -------------------------------------------------------------------

@pytest.mark.parametrize("R", [256, 1024])
def test_ef_update_matches_ref(R):
    args = [_tiles(i, R) for i in range(5)]
    k1 = ef_gossip_update(*args, 1 / 3, 1 / 3, 0.046)
    r1 = ref.ef_gossip_update_ref(*args, 1 / 3, 1 / 3, 0.046)
    for a, b in zip(k1, r1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10 ** 6),
       st.floats(0.0, 1.0), st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_ef_update_vector_hypothesis(seed, ws, wn, g):
    d = 3000
    args = [jax.random.normal(jax.random.PRNGKey(seed + i), (d,))
            for i in range(5)]
    out_k = ops.ef_gossip_update_vector(*args, ws, wn, g)
    out_r = ref.ef_gossip_update_ref(*args, ws, wn, g)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# -- flash attention ---------------------------------------------------------------

@pytest.mark.parametrize("S,Dh", [(128, 64), (256, 128), (512, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(S, Dh, causal):
    B, H, KV = 1, 2, 1
    q = jax.random.normal(KEY, (B, S, H, Dh), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, Dh)) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, Dh))
    o = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128)
    kk = jnp.repeat(k, H // KV, 2)
    vv = jnp.repeat(v, H // KV, 2)
    o_ref = ref.flash_attention_ref(q, kk, vv, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_softcap_and_bf16():
    B, S, H, Dh = 1, 256, 2, 64
    q = (jax.random.normal(KEY, (B, S, H, Dh)) * 0.5).astype(jnp.bfloat16)
    k = (jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, Dh)) * 0.5).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, Dh)).astype(jnp.bfloat16)
    o = flash_attention(q, k, v, causal=True, softcap=30.0, block_q=64, block_k=64)
    o_ref = ref.flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                                    v.astype(jnp.float32), causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(o_ref),
                               rtol=0.1, atol=0.02)

# -- wire-format parity: int8/int16 codes vs the packing wire format -----------

@pytest.mark.parametrize("s", [1, 127, 128, 255])
def test_qsgd_codes_wire_dtype(s):
    """compress_bucket's wire rule: int8 codes up to s=127, int16 above
    (int8 would silently clamp large coordinates)."""
    from repro.kernels.qsgd import code_dtype, qsgd_quantize_codes
    x = _tiles(3, 8, scale=2.0)
    xi = jax.random.uniform(jax.random.PRNGKey(4), (8, 128))
    want = jnp.int8 if s <= 127 else jnp.int16
    assert code_dtype(s) == want
    norm = jnp.sqrt(jnp.sum(jnp.square(x)))
    codes = qsgd_quantize_codes(x, xi, 1.0 / norm, s)
    assert codes.dtype == want
    ref_codes, _ = jax.jit(ref.qsgd_quantize_ref,
                           static_argnames="s")(x, xi, s)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(ref_codes))
    # extreme levels actually reached: one coordinate carries the whole norm
    spike = jnp.zeros((8, 128)).at[0, 0].set(3.0)
    codes = qsgd_quantize_codes(spike, jnp.zeros((8, 128)), 1.0 / 3.0, s)
    assert int(codes[0, 0]) == s


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 3000), st.sampled_from([1, 16, 127, 255]),
       st.integers(0, 10 ** 6))
def test_qsgd_codes_pallas_matches_jitted_ref_hypothesis(d, s, seed):
    """Odd sizes + padding tails: pallas(interpret) codes over the padded
    tiles slice back to exactly the JITTED ref codes of the flat vector.
    Bit-exact comparisons are always against the jitted ref: the engine
    runs compiled, and eager jnp rounds FMA differently."""
    from repro.kernels import dispatch as kd
    x = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    xi = jax.random.uniform(jax.random.PRNGKey(seed + 1), (d,))
    norm = jnp.sqrt(jnp.sum(jnp.square(x)))
    inv_norm = jnp.where(norm == 0, 0.0, 1.0 / norm)
    got = kd.qsgd_codes(x, xi, inv_norm, s, backend="pallas")
    want = jax.jit(
        lambda x, xi: (jnp.sign(x)
                       * jnp.floor(jnp.abs(x) * inv_norm * s + xi)
                       ).astype(jnp.int8 if s <= 127 else jnp.int16))(x, xi)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qsgd_codes_zero_norm_bucket():
    """All-zero bucket: inv_norm = 0 must code to all-zero (both backends)."""
    from repro.kernels import dispatch as kd
    x = jnp.zeros((901,))
    xi = jax.random.uniform(jax.random.PRNGKey(2), (901,))
    for backend in ("jnp", "pallas"):
        codes = kd.qsgd_codes(x, xi, jnp.float32(0.0), 16, backend=backend)
        assert int(jnp.sum(jnp.abs(codes))) == 0


def test_sign_codes_parity():
    from repro.kernels import dispatch as kd
    x = jax.random.normal(jax.random.PRNGKey(5), (777,))
    want = jax.jit(ref.signnorm_codes_ref)(x)
    for backend in ("jnp", "pallas"):
        got = kd.sign_codes(x, backend=backend)
        assert got.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qsgd_dequantize_bit_exact():
    codes = jnp.asarray(
        jax.random.randint(KEY, (8, 128), -127, 128), jnp.int8)
    scale = jnp.float32(0.037)
    got = qsgd_dequantize(codes, scale)
    want = jax.jit(ref.qsgd_dequantize_ref)(codes, scale)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_block_topk_mask_bit_exact_vs_jitted_ref():
    x = _tiles(9, 16, scale=2.0)
    mk, tk = block_topk_mask(x, 13)
    mr, tr = jax.jit(ref.block_topk_mask_ref, static_argnames="k")(x, k=13)
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
    np.testing.assert_array_equal(np.asarray(tk), np.asarray(tr))


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 4000), st.integers(0, 10 ** 6))
def test_ef_update_pallas_bit_exact_vs_jitted_ref(d, seed):
    """Fused EF kernel == JITTED oracle, bitwise, on odd flat sizes (the
    padded tail stays exactly zero and is sliced off)."""
    args = [jax.random.normal(jax.random.PRNGKey(seed + i), (d,))
            for i in range(5)]
    got = ops.ef_gossip_update_vector(*args, 1 / 3, 1 / 3, 0.046)
    want = jax.jit(ref.ef_gossip_update_ref)(*args, 1 / 3, 1 / 3, 0.046)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ef_bucket_update_backends_match():
    from repro.kernels import dispatch as kd
    d = 1536
    args = [jax.random.normal(jax.random.PRNGKey(10 + i), (d,))
            for i in range(5)]
    outs = {bk: jax.jit(lambda *a, bk=bk: kd.ef_bucket_update(
                *a, 1 / 3, 1 / 3, 0.046, backend=bk))(*args)
            for bk in ("jnp", "pallas")}
    for a, b in zip(outs["jnp"], outs["pallas"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- dispatch ------------------------------------------------------------------

def test_resolve_backend_rules():
    from repro.kernels import dispatch as kd
    assert kd.resolve_backend("auto") in ("pallas", "jnp")
    assert kd.resolve_backend("jnp") == "jnp"
    with pytest.raises(ValueError):
        kd.resolve_backend("vulkan")
    with pytest.raises(ValueError):
        kd.resolve_backend("pallas", engine_eligible=False)
    # auto on an ineligible engine silently stays jnp (never raises)
    assert kd.resolve_backend("auto", engine_eligible=False) == "jnp"
    assert kd.jax_version_tuple() >= (0, 4)


def test_auto_never_picks_interpret_pallas():
    """'auto' selects pallas only where the kernels run compiled; on the
    CPU test toolchain (interpret-only) it must resolve to jnp."""
    from repro.kernels import dispatch as kd
    tc = kd.probe_toolchain()
    if tc.interpret:
        assert kd.resolve_backend("auto") == "jnp"
    else:
        assert kd.resolve_backend("auto") == "pallas"


def test_dispatch_single_node_exchange_backends_agree():
    """Forced jnp vs forced pallas on a 2-bucket spec (in-process, 1-node
    mesh): identical round-1 wire state x_hat (bitwise) and ulp-close x/s
    through the fused bucket-space path.  One round only — later rounds
    quantize the ulp-drifted x, so x_hat stays bit-exact only for the
    round whose input state is shared (the wire witness)."""
    import numpy as onp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.comm.gossip import make_gossip_exchange
    from repro.core.compression import QSGD

    mesh = Mesh(onp.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    params = {"a": jax.random.normal(jax.random.PRNGKey(1), (1, 300)),
              "b": jax.random.normal(jax.random.PRNGKey(2), (1, 4, 128))}
    specs = {"a": P("data", None), "b": P("data", None, "model")}
    outs = {}
    for bk in ("jnp", "pallas"):
        with mesh:
            ex = jax.jit(make_gossip_exchange(
                mode="choco", mesh=mesh, state_specs=specs, axis="data",
                compressor=QSGD(s=16), gamma=0.3, gossip_steps=1,
                kernel_backend=bk))
        outs[bk] = ex(jax.random.PRNGKey(3), params,
                      jax.tree.map(jnp.zeros_like, params),
                      jax.tree.map(jnp.zeros_like, params))
    for j, tol in ((0, 1e-6), (1, 0.0), (2, 1e-6)):   # x, x_hat, s
        for k in params:
            a = np.asarray(outs["jnp"][j][k])
            b = np.asarray(outs["pallas"][j][k])
            if tol == 0.0:
                np.testing.assert_array_equal(a, b)
            else:
                np.testing.assert_allclose(a, b, rtol=0, atol=tol)
