"""Per-kernel shape/dtype sweeps, assert_allclose against ref.py oracles
(interpret mode on CPU; kernels TARGET TPU tiles)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from optional_hypothesis import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.qsgd import qsgd_quantize, qsgd_dequantize
from repro.kernels.topk import block_topk_mask
from repro.kernels.ef_update import ef_gossip_update
from repro.kernels.flash_attention import flash_attention

KEY = jax.random.PRNGKey(0)


def _tiles(seed, R, C=128, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (R, C)) * scale


# -- qsgd ----------------------------------------------------------------------

@pytest.mark.parametrize("R", [8, 24, 64])
@pytest.mark.parametrize("s", [4, 16, 127])
def test_qsgd_kernel_matches_ref(R, s):
    x = _tiles(R + s, R)
    xi = jax.random.uniform(jax.random.PRNGKey(1), (R, 128))
    ck, sk = qsgd_quantize(x, xi, s)
    cr, sr = ref.qsgd_quantize_ref(x, xi, s)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_allclose(float(sk), float(sr), rtol=1e-6)
    yk = qsgd_dequantize(ck, sk)
    np.testing.assert_allclose(np.asarray(yk),
                               np.asarray(ref.qsgd_dequantize_ref(cr, sr)),
                               rtol=1e-6)


def test_qsgd_kernel_contraction():
    """Kernel output satisfies Assumption 1 with omega = 1/tau."""
    import math
    d = 64 * 128
    x = _tiles(7, 64, scale=2.0)
    errs = []
    for i in range(20):
        xi = jax.random.uniform(jax.random.PRNGKey(i), (64, 128))
        c, s = qsgd_quantize(x, xi, 16)
        q = qsgd_dequantize(c, s)
        errs.append(float(jnp.sum((q - x) ** 2)))
    tau = 1.0 + min(d / 256, math.sqrt(d) / 16)
    assert np.mean(errs) <= (1 - 1 / tau) * float(jnp.sum(x * x)) * 1.1


def test_qsgd_zero_vector():
    x = jnp.zeros((8, 128))
    xi = jnp.zeros((8, 128))
    c, s = qsgd_quantize(x, xi, 16)
    assert float(jnp.sum(jnp.abs(qsgd_dequantize(c, s)))) == 0.0


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 12), st.integers(2, 127), st.integers(0, 10 ** 6))
def test_qsgd_vector_roundtrip_hypothesis(blocks, s, seed):
    d = blocks * 997                     # deliberately unaligned
    x = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    xi = jax.random.uniform(jax.random.PRNGKey(seed + 1), (d,))
    codes, scale = ops.qsgd_compress_vector(x, xi, s)
    y = ops.qsgd_decompress_vector(codes, scale)
    assert y.shape == x.shape
    # contraction (deterministic given xi: compare directly)
    assert float(jnp.sum((y - x) ** 2)) <= float(jnp.sum(x * x)) * 1.0 + 1e-6


# -- block top-k -----------------------------------------------------------------

@pytest.mark.parametrize("R", [8, 32])
@pytest.mark.parametrize("k", [1, 5, 64, 128])
def test_block_topk_matches_ref(R, k):
    x = _tiles(R * k, R)
    mk, tk = block_topk_mask(x, k)
    mr, tr = ref.block_topk_mask_ref(x, k)
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr), rtol=1e-6)


def test_block_topk_counts():
    x = _tiles(3, 8)
    mask, _ = block_topk_mask(x, 10)
    counts = np.asarray(jnp.sum(mask, axis=1))
    assert (counts >= 10).all() and (counts <= 12).all()


def test_block_topk_selects_largest():
    x = _tiles(4, 8)
    mask, _ = block_topk_mask(x, 4)
    mag = np.abs(np.asarray(x))
    for r in range(8):
        sel = mag[r][np.asarray(mask[r]) > 0]
        unsel = mag[r][np.asarray(mask[r]) == 0]
        assert sel.min() >= unsel.max() - 1e-6


def test_block_topk_contraction():
    """Blockwise top-k satisfies Assumption 1 with omega ~= k/C."""
    x = _tiles(11, 16, scale=3.0)
    q = x * block_topk_mask(x, 13)[0]
    lhs = float(jnp.sum((q - x) ** 2))
    assert lhs <= (1 - 13 / 128) * float(jnp.sum(x * x)) + 1e-5


# -- ef update -------------------------------------------------------------------

@pytest.mark.parametrize("R", [256, 1024])
def test_ef_update_matches_ref(R):
    args = [_tiles(i, R) for i in range(5)]
    k1 = ef_gossip_update(*args, 1 / 3, 1 / 3, 0.046)
    r1 = ref.ef_gossip_update_ref(*args, 1 / 3, 1 / 3, 0.046)
    for a, b in zip(k1, r1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10 ** 6),
       st.floats(0.0, 1.0), st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_ef_update_vector_hypothesis(seed, ws, wn, g):
    d = 3000
    args = [jax.random.normal(jax.random.PRNGKey(seed + i), (d,))
            for i in range(5)]
    out_k = ops.ef_gossip_update_vector(*args, ws, wn, g)
    out_r = ref.ef_gossip_update_ref(*args, ws, wn, g)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# -- flash attention ---------------------------------------------------------------

@pytest.mark.parametrize("S,Dh", [(128, 64), (256, 128), (512, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(S, Dh, causal):
    B, H, KV = 1, 2, 1
    q = jax.random.normal(KEY, (B, S, H, Dh), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, Dh)) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, Dh))
    o = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128)
    kk = jnp.repeat(k, H // KV, 2)
    vv = jnp.repeat(v, H // KV, 2)
    o_ref = ref.flash_attention_ref(q, kk, vv, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_softcap_and_bf16():
    B, S, H, Dh = 1, 256, 2, 64
    q = (jax.random.normal(KEY, (B, S, H, Dh)) * 0.5).astype(jnp.bfloat16)
    k = (jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, Dh)) * 0.5).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, Dh)).astype(jnp.bfloat16)
    o = flash_attention(q, k, v, causal=True, softcap=30.0, block_q=64, block_k=64)
    o_ref = ref.flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                                    v.astype(jnp.float32), causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(o_ref),
                               rtol=0.1, atol=0.02)
