"""Gossip matrices: Definition 1 validity + Table 1 spectral-gap asymptotics."""
import numpy as np
import pytest

from repro.core.topology import (ring, torus2d, fully_connected, chain, star,
                                 hypercube, make_topology)


@pytest.mark.parametrize("topo_fn,n", [
    (ring, 5), (ring, 25), (fully_connected, 9), (chain, 7), (star, 8),
    (hypercube, 16), (lambda n: torus2d(4, 4), 16),
])
def test_valid_gossip_matrix(topo_fn, n):
    t = topo_fn(n)
    t.validate()
    assert 0 < t.delta <= 1
    assert 0 <= t.beta <= 2


def test_fully_connected_delta_is_one():
    assert abs(fully_connected(25).delta - 1.0) < 1e-9


def test_ring_delta_scaling():
    """Table 1: ring delta ~ O(1/n^2)."""
    d9, d36 = ring(9).delta, ring(36).delta
    ratio = d9 / d36
    assert 10 < ratio < 26          # ~ (36/9)^2 = 16


def test_torus_delta_scaling():
    """Table 1: torus delta ~ O(1/n)."""
    d9 = torus2d(3, 3).delta
    d36 = torus2d(6, 6).delta
    ratio = d9 / d36
    assert 2 < ratio < 8            # ~ 36/9 = 4


def test_ring_beats_chain():
    assert ring(10).delta > chain(10).delta


def test_doubly_stochastic_rows_cols():
    for t in [ring(6), star(6), chain(6)]:
        np.testing.assert_allclose(t.W.sum(0), 1.0, atol=1e-9)
        np.testing.assert_allclose(t.W.sum(1), 1.0, atol=1e-9)


def test_make_topology_registry():
    assert make_topology("ring", 12).n == 12
    assert make_topology("torus", 12).n == 12
    with pytest.raises(ValueError):
        make_topology("nope", 4)


@pytest.mark.parametrize("n", [7, 13, 31])
def test_torus_rejects_degenerate_factorization(n):
    """Prime n factors as a 1 x n strip whose spectral gap is ring-grade
    O(1/n^2), not the advertised torus O(1/n) — must fail fast, not
    silently mis-advertise the mixing rate."""
    with pytest.raises(ValueError, match="ring"):
        make_topology("torus", n)


def test_torus_composite_factorizations_stay_valid():
    for n in (4, 8, 12, 16, 64):
        t = make_topology("torus", n)
        assert t.n == n
        t.validate()
    # a real torus mixes strictly better than the same-n ring
    assert make_topology("torus", 16).delta > make_topology("ring", 16).delta
