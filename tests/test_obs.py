"""Telemetry subsystem acceptance (obs/).

Fast tier:
  * schema — record validation against the metric registry (reserved
    keys, unregistered keys, non-scalar values);
  * sinks — JSONL/CSV/stdout writers, the background MetricLog drains on
    close, validation errors surface at the emit call site;
  * divergence monitor — convergence-floor wobble never trips, sustained
    Lyapunov growth does;
  * timers — compile-aware tap accounting under a fake clock,
    nearest-rank percentiles;
  * Lyapunov contraction — CHOCO-GOSSIP under the Theorem-2 gamma
    contracts Xi_t = consensus + EF residual monotonically and at least
    at the (1 - delta^2 omega / 82)^t rate band on ring and hypercube;
    an overscaled gamma diverges and trips the monitor (the negative
    control the --divergence-action flag exists for).

Slow/distributed tier: the train launcher end-to-end with --diag-every
and --metrics-dir emits a JSONL run log in which every record validates
against the registry (header + compile-once + steady-state taps + diag
records).
"""
import csv
import json

import jax
import jax.numpy as jnp
import pytest

from repro.core.choco_gossip import (auto_stepsize,
                                     choco_gossip_round_efficient,
                                     init_efficient_state, theorem2_rate)
from repro.core.compression import make_compressor
from repro.core.topology import make_topology
from repro.obs.schema import METRIC_SPECS, METRICS, validate_record
from repro.obs.sinks import (CsvSink, DivergenceMonitor, JsonlSink,
                             MetricLog, StdoutSink)
from repro.obs.timers import StepTimer, percentile
from repro.obs.trace import ProfileSession, annotate

from test_distributed import run_sub


# --------------------------------------------------------------------------
# schema
# --------------------------------------------------------------------------

def test_registry_entries_are_well_formed():
    names = [m.name for m in METRIC_SPECS]
    assert len(names) == len(set(names))
    for m in METRIC_SPECS:
        assert "/" in m.name and m.units.strip() and m.description.strip()
    assert METRICS["train/loss"].units == "nats"


def test_validate_record_accepts_registered_metrics():
    validate_record({"kind": "metrics", "step": 3, "train/loss": 1.5,
                     "extra": {"anything": "goes"}})
    validate_record({"kind": "header", "whatever": [1, 2]})
    validate_record({"kind": "log", "msg": "hello"})


def test_validate_record_rejects_bad_records():
    with pytest.raises(ValueError, match="kind"):
        validate_record({"kind": "nope"})
    with pytest.raises(ValueError, match="int step"):
        validate_record({"kind": "metrics", "step": True})
    with pytest.raises(ValueError, match="unregistered"):
        validate_record({"kind": "metrics", "step": 1, "train/bogus": 1.0})
    with pytest.raises(ValueError, match="scalar"):
        validate_record({"kind": "metrics", "step": 1,
                         "train/loss": [1.0, 2.0]})


# --------------------------------------------------------------------------
# sinks
# --------------------------------------------------------------------------

def test_metric_log_drains_to_jsonl_on_close(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with MetricLog([JsonlSink(path)]) as mlog:
        mlog.header(arch="t", gamma=0.5)
        for i in range(20):
            mlog.emit(i, {"train/loss": float(i)})
        mlog.log("done")
    recs = [json.loads(line) for line in open(path)]
    assert [r["kind"] for r in recs] == (["header"] + ["metrics"] * 20
                                         + ["log"])
    for r in recs:
        validate_record(r)
    assert recs[1]["train/loss"] == 0.0 and recs[-2]["step"] == 19


def test_metric_log_validates_on_calling_thread(tmp_path):
    mlog = MetricLog([JsonlSink(str(tmp_path / "m.jsonl"))])
    try:
        with pytest.raises(ValueError, match="unregistered"):
            mlog.emit(0, {"train/nonsense": 1.0})
    finally:
        mlog.close()


def test_csv_sink_writes_fixed_columns(tmp_path):
    path = str(tmp_path / "m.csv")
    with MetricLog([CsvSink(path)]) as mlog:
        mlog.header(skipped="csv ignores headers")
        mlog.emit(1, {"train/loss": 2.5, "train/lr": 0.1})
        mlog.emit(2, {"train/loss": 2.25})
    rows = list(csv.DictReader(open(path)))
    assert [r["step"] for r in rows] == ["1", "2"]
    assert rows[0]["train/loss"] == "2.5" and rows[0]["train/lr"] == "0.1"
    assert rows[1]["train/lr"] == ""       # absent metric -> empty cell


def test_stdout_sink_formatter_skips_none(capsys):
    fmt = lambda rec: None if rec["kind"] == "header" else "LINE"
    with MetricLog([StdoutSink(formatter=fmt)]) as mlog:
        mlog.header(hidden=1)
        mlog.log("shown")
    out = capsys.readouterr().out
    assert "LINE" in out and "hidden" not in out


def test_divergence_monitor_tolerates_floor_wobble():
    mon = DivergenceMonitor(tolerance=1.05, patience=3)
    xi = 100.0
    for step in range(40):
        xi *= 0.9
        assert mon.update(step, xi) is None
    # wobble around the floor within tolerance: never trips
    floor = xi
    for step in range(40, 60):
        assert mon.update(step, floor * (1.0 + 0.02 * (step % 2))) is None
    assert not mon.tripped


def test_divergence_monitor_trips_on_sustained_growth():
    mon = DivergenceMonitor(tolerance=1.05, patience=3)
    assert mon.update(0, 100.0) is None
    msgs = [mon.update(s, 100.0 * 1.3 ** s) for s in range(1, 5)]
    tripped = [m for m in msgs if m is not None]
    assert tripped and mon.tripped
    assert "gamma" in tripped[0] and "Lyapunov" in tripped[0]


# --------------------------------------------------------------------------
# timers / trace
# --------------------------------------------------------------------------

def test_step_timer_separates_compile_from_steady_state():
    clock = iter([0.0, 10.0, 18.0, 20.0]).__next__
    timer = StepTimer(clock=clock)
    timer.start()                                   # t=0
    compile_s = timer.mark_compile(lambda: None)    # t=10
    assert compile_s == 10.0 and timer.compile_s == 10.0
    # steps 1..4 done by t=18: 8s over 4 steps
    assert timer.tap(4, lambda: None) == pytest.approx(2.0)
    # no new steps since the tap: no blocking, no sample
    assert timer.tap(4, lambda: None) is None
    # one more step by t=20
    assert timer.tap(5, lambda: None) == pytest.approx(2.0)


def test_step_timer_requires_start():
    with pytest.raises(ValueError, match="start"):
        StepTimer().mark_compile(lambda: None)
    with pytest.raises(ValueError, match="start"):
        StepTimer().tap(0, lambda: None)


def test_percentile_nearest_rank():
    vals = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert percentile(vals, 50) == 3.0
    assert percentile(vals, 99) == 5.0
    assert percentile(vals, 0) == 1.0
    assert percentile([7.0], 50) == 7.0
    with pytest.raises(ValueError):
        percentile([], 50)
    with pytest.raises(ValueError):
        percentile([1.0], 101)


def test_profile_session_noop_without_dir(tmp_path):
    prof = ProfileSession(None)
    assert not prof.maybe_start(1) and not prof.maybe_stop(1)
    prof.close()
    assert not prof.active and not prof.done
    with pytest.raises(ValueError, match="n_steps"):
        ProfileSession(str(tmp_path), n_steps=0)
    with annotate("obs:test"):      # degrades to a no-op context
        pass


# --------------------------------------------------------------------------
# Lyapunov contraction (the quantity --diag-every reports)
# --------------------------------------------------------------------------

def _xi_trace(topo_name, gamma, rounds, seed=0):
    """Xi_t per CHOCO-GOSSIP round on the (n, d) matrix simulator."""
    n, d = 8, 64
    topo = make_topology(topo_name, n)
    comp = make_compressor("top_k", fraction=0.25)
    W = jnp.asarray(topo.W, jnp.float32)
    x0 = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    xbar = jnp.mean(x0, axis=0, keepdims=True)
    st = init_efficient_state(x0)

    def xi(s):
        return float(jnp.sum((s.x - xbar) ** 2)
                     + jnp.sum((s.x - s.x_hat) ** 2))

    trace = [xi(st)]
    key = jax.random.PRNGKey(seed + 1)
    for t in range(rounds):
        st = choco_gossip_round_efficient(st, W, gamma, comp,
                                          jax.random.fold_in(key, t))
        trace.append(xi(st))
    return topo, comp, trace


@pytest.mark.parametrize("topo_name", ["ring", "hypercube"])
def test_lyapunov_contracts_at_theorem2_rate(topo_name):
    n, d, rounds = 8, 64, 300
    topo = make_topology(topo_name, n)
    comp = make_compressor("top_k", fraction=0.25)
    gamma = auto_stepsize(topo, comp, d)
    topo, comp, trace = _xi_trace(topo_name, gamma, rounds)
    rate = theorem2_rate(topo.delta, comp.omega(d))
    # at least as fast as the Theorem-2 band, and a genuine contraction
    assert trace[-1] <= trace[0] * rate ** rounds, (trace[-1], trace[0])
    assert trace[-1] < 0.5 * trace[0]
    # monotone: the deterministic top-k path never moves Xi_t up
    for a, b in zip(trace, trace[1:]):
        assert b <= a + 1e-4 * trace[0], (a, b)
    # the divergence monitor stays quiet on a healthy run
    mon = DivergenceMonitor()
    assert all(mon.update(t, v) is None for t, v in enumerate(trace))


def test_overscaled_gamma_diverges_and_trips_monitor():
    # ~2000x the Theorem-2 gamma: the error-feedback loop overshoots and
    # Xi_t grows without bound — the failure mode --divergence-action
    # exists to catch
    _, _, trace = _xi_trace("ring", 2.0, 30)
    assert trace[-1] > 10 * trace[0]
    mon = DivergenceMonitor()
    msgs = [mon.update(t, v) for t, v in enumerate(trace)]
    assert mon.tripped and any(m is not None for m in msgs)


# --------------------------------------------------------------------------
# end-to-end: launcher -> validated JSONL run log (slow/distributed)
# --------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.distributed
def test_train_launcher_emits_validated_run_log(tmp_path):
    mdir = str(tmp_path / "metrics")
    run_sub(f"""
        import json
        from repro.launch.train import main
        from repro.obs.schema import validate_record

        mdir = {mdir!r}
        assert main(["--arch", "qwen3-1.7b", "--smoke", "--mesh", "8x1",
                     "--simulate-devices", "8", "--seq-len", "32",
                     "--batch-per-node", "2", "--steps", "5",
                     "--compressor", "top_k", "--fraction", "0.05",
                     "--diag-every", "2", "--metrics-dir", mdir,
                     "--divergence-action", "warn"]) == 0
        recs = [json.loads(l) for l in open(mdir + "/metrics.jsonl")]
        for r in recs:
            validate_record(r)      # every record passes the registry

        headers = [r for r in recs if r["kind"] == "header"]
        assert len(headers) == 1
        h = headers[0]
        assert h["jax_version"] and h["mesh"] == {{"data": 8, "model": 1}}
        assert h["fingerprint"]["compressor"] == "top_k"
        assert h["gamma"] > 0 and h["wire_bytes_round"] > 0
        assert h["buckets"] and all("omega" in b for b in h["buckets"])

        mets = [r for r in recs if r["kind"] == "metrics"]
        compile_recs = [r for r in mets if "train/compile_s" in r]
        assert len(compile_recs) == 1          # compile reported once
        assert "train/s_per_step" not in compile_recs[0]
        assert any("train/s_per_step" in r for r in mets)
        diags = [r for r in mets if "diag/lyapunov" in r]
        assert [r["step"] for r in diags] == [2, 4]
        for r in diags:
            assert r["diag/consensus_dist"] >= 0
            assert r["diag/compress_err"] <= r["diag/compress_err_bound"]
        print("RUN LOG OK")
    """)
