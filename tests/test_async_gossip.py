"""Bounded-staleness async gossip (comm/async_gossip.py + the
core/choco_gossip.py delay-expanded simulator).

Fast tier: StalenessProcess construction + expected-mixing algebra + seed
determinism + simulator convergence/average-preservation + fail-fast wiring.
The distributed engine == simulator equivalence, the HLO permute-launch
audit against the link-failure baseline, and the trainer/CLI e2e live at the
bottom under the standard ``slow``/``distributed`` markers (subprocess with
8 simulated host devices), so the fast inner loop (-m "not slow") never
compiles shard_map graphs.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.topology import make_topology, spectral_gap
from repro.core.compression import Identity, TopK
from repro.core.choco_gossip import (choco_gossip_round_efficient,
                                     choco_stale_round, init_efficient_state,
                                     init_stale_state, run_choco_stale_gossip)
from repro.comm.schedule import compile_schedule
from repro.comm.async_gossip import StalenessProcess
from repro.comm.stochastic import (LinkFailureProcess, choco_process_round,
                                   init_process_state, make_topology_process)

from optional_hypothesis import HAVE_HYPOTHESIS, given, settings, st

TOPOS = ["ring", "hypercube", "star", "chain", "torus", "fully_connected"]


def _sched(name, n=8):
    return compile_schedule(make_topology(name, n))


def _proc(name="ring", tau=2, n=8, **kw):
    return StalenessProcess(_sched(name, n), max_staleness=tau, **kw)


# ---------------------------------------------------------------------------
# construction + validation
# ---------------------------------------------------------------------------

class TestStalenessProcess:
    def test_registry(self):
        sched = _sched("ring")
        p = make_topology_process("staleness", sched, max_staleness=3)
        assert p.kind == "staleness" and p.max_staleness == 3

    def test_negative_tau_rejected(self):
        with pytest.raises(ValueError, match="max_staleness"):
            _proc(tau=-1)

    def test_single_node_schedule_rejected(self):
        with pytest.raises(ValueError, match="at least one round"):
            StalenessProcess(compile_schedule(make_topology("ring", 1)))

    def test_delay_probs_validation(self):
        with pytest.raises(ValueError, match="entries"):
            _proc(tau=2, delay_probs=(0.5, 0.5))          # needs tau+1 = 3
        with pytest.raises(ValueError, match="nonnegative"):
            _proc(tau=1, delay_probs=(1.5, -0.5))
        # unnormalized mass is normalized, not rejected
        p = _proc(tau=1, delay_probs=(3.0, 1.0))
        np.testing.assert_allclose(p.delay_probs, (0.75, 0.25))

    def test_delay_statistics(self):
        p = _proc(tau=2)                      # uniform over {0, 1, 2}
        assert p.mean_delay == pytest.approx(1.0)
        assert p.freshness == pytest.approx((1 + 1 / 2 + 1 / 3) / 3)
        p0 = _proc(tau=0)
        assert p0.mean_delay == 0.0 and p0.freshness == 1.0


# ---------------------------------------------------------------------------
# per-edge straggler links
# ---------------------------------------------------------------------------

class TestStragglerEdges:
    def test_unknown_edge_rejected(self):
        """Naming an edge outside the schedule's support is a ValueError
        (ring(8) has no chord 0-5)."""
        with pytest.raises(ValueError, match="unknown straggler edge 0-5"):
            _proc(straggler_edges=((0, 5),))

    def test_registry_passthrough(self):
        p = make_topology_process(
            "staleness", _sched("ring"), max_staleness=2,
            straggler_edges=((1, 0),),
            straggler_delay_probs=(0.0, 0.5, 0.5))
        # edge canonicalized to (min, max)
        assert p.straggler_edges == ((0, 1),)
        assert p.straggler_delay_probs == (0.0, 0.5, 0.5)

    def test_default_straggler_is_point_mass_at_tau(self):
        p = _proc(tau=2, straggler_edges=((0, 1),))
        assert p.straggler_delay_probs == (0.0, 0.0, 1.0)
        e = p._edges.index((0, 1))
        assert p.edge_freshness[e] == pytest.approx(1.0 / 3)

    def test_probs_without_edges_rejected(self):
        with pytest.raises(ValueError, match="without"):
            _proc(tau=1, straggler_delay_probs=(0.5, 0.5))

    def test_nonstraggler_draws_bit_identical_to_global(self):
        """The per-edge cumulative table shares one uniform draw per edge,
        so adding a straggler edge must not perturb any OTHER edge's delay
        sequence (and the straggler itself obeys its point mass)."""
        base = _proc("torus", tau=2)
        strag = _proc("torus", tau=2, straggler_edges=((0, 1),))
        e = strag._edges.index((0, 1))
        key = jax.random.PRNGKey(11)
        for t in range(6):
            d0 = np.asarray(base.edge_delays(key, t))
            d1 = np.asarray(strag.edge_delays(key, t))
            other = np.arange(len(d0)) != e
            np.testing.assert_array_equal(d0[other], d1[other])
            assert d1[e] == 2

    def test_expected_matrix_per_edge_algebra(self):
        """Straggler edges carry their own phi_e: the expected matrix keeps
        phi * w on every healthy edge, phi_s * w on the straggler, and
        stays symmetric row-stochastic (the undelivered remainder folds
        into BOTH endpoints' diagonals equally)."""
        topo = make_topology("ring", 8)
        p = StalenessProcess(compile_schedule(topo), max_staleness=2,
                             straggler_edges=((2, 3),))
        E = p.expected_matrix()
        phi = p.freshness
        phi_s = 1.0 / 3                      # point mass at tau = 2
        W = topo.W
        np.testing.assert_allclose(E.sum(axis=1), np.ones(8), atol=1e-12)
        np.testing.assert_allclose(E, E.T, atol=1e-12)
        assert E[2, 3] == pytest.approx(phi_s * W[2, 3])
        assert E[0, 1] == pytest.approx(phi * W[0, 1])
        # straggler slows consensus: eigengap strictly below the uniform one
        delta_s, _ = p.expected_delta_beta()
        delta_u, _ = _proc(tau=2).expected_delta_beta()
        assert delta_s < delta_u

    def test_straggler_average_preserved_in_simulator(self):
        """Both directions of the straggler link share its delay, so the
        pairwise-cancellation argument still holds: 1^T x is invariant
        under the extended simulator, step by step."""
        p = _proc("ring", tau=2, straggler_edges=((0, 1), (4, 5)),
                  straggler_delay_probs=(0.1, 0.1, 0.8))
        x0 = jnp.asarray(np.random.default_rng(0).standard_normal((8, 6)),
                         jnp.float32)
        state = init_stale_state(x0, p.max_staleness)
        key = jax.random.PRNGKey(3)
        for t in range(12):
            state = choco_stale_round(state, p, 0.4, TopK(k=2),
                                      jax.random.fold_in(key, t))
            np.testing.assert_allclose(np.asarray(state.x.mean(axis=0)),
                                       np.asarray(x0.mean(axis=0)),
                                       atol=1e-5)

    @pytest.mark.parametrize("stragglers", [None, ((0, 1),)])
    def test_theorem2_contraction_band_holds(self, stragglers):
        """Theorem-2 band under the distribution-aware constants: with
        gamma = theorem2_stepsize(delta_eff, beta_eff, omega_eff) the stale
        simulator's consensus error stays inside
        e_T <= e_0 * rate^T, rate = theorem2_rate(delta_eff, omega_eff) —
        with and without a straggler edge (the straggler's smaller
        delta/omega widen the band; the iterates must still respect it)."""
        from repro.core.choco_gossip import theorem2_rate, theorem2_stepsize
        p = _proc("hypercube", tau=2, straggler_edges=stragglers)
        comp = TopK(k=3)
        omega = p.effective_omega(comp.omega(6))
        delta, beta = p.expected_delta_beta()
        gamma = theorem2_stepsize(delta, beta, omega)
        rate = theorem2_rate(delta, omega)
        x0 = jnp.asarray(np.random.default_rng(1).standard_normal((8, 6)),
                         jnp.float32)
        _, errs = run_choco_stale_gossip(x0, p, gamma, comp, steps=300,
                                         key=jax.random.PRNGKey(5))
        errs = np.asarray(errs)
        bound = float(errs[0]) * rate ** np.arange(len(errs))
        assert (errs <= bound * 1.05).all(), (
            f"consensus error left the Theorem-2 band: "
            f"worst ratio {float((errs / bound).max())}")
        assert errs[-1] < errs[0]


# ---------------------------------------------------------------------------
# expected-mixing algebra (the Theorem-2 surrogate)
# ---------------------------------------------------------------------------

class TestExpectedMixing:
    @pytest.mark.parametrize("name", TOPOS)
    def test_expected_matrix_is_freshness_interpolation(self, name):
        """E_eff = phi W + (1 - phi) I with phi = E[1/(1+d)] — the same
        shape as linkfail's (1-p) W + p I, with phi standing in for the
        keep probability."""
        topo = make_topology(name, 8)
        p = StalenessProcess(compile_schedule(topo), max_staleness=2)
        phi = p.freshness
        np.testing.assert_allclose(
            p.expected_matrix(), phi * topo.W + (1 - phi) * np.eye(8),
            atol=1e-12)
        delta, _ = p.expected_delta_beta()
        assert delta == pytest.approx(phi * spectral_gap(topo.W), abs=1e-9)

    def test_tau_zero_is_static_W(self):
        topo = make_topology("hypercube", 8)
        p = StalenessProcess(compile_schedule(topo), max_staleness=0)
        np.testing.assert_allclose(p.expected_matrix(), topo.W, atol=1e-12)
        assert p.effective_omega(0.25) == 0.25

    def test_drop_is_the_staleness_limit(self):
        """Subsumption: a link that is ALWAYS maximally stale approaches
        the linkfail expected matrix as tau grows (phi -> 0 ~ p -> 1)."""
        sched = _sched("ring")
        delayed = StalenessProcess(
            sched, max_staleness=9,
            delay_probs=(0.0,) * 9 + (1.0,))          # d = 9 always
        lf = LinkFailureProcess(sched, drop_prob=0.9)  # keep prob 0.1
        np.testing.assert_allclose(delayed.expected_matrix(),
                                   lf.expected_matrix(), atol=1e-12)

    def test_effective_omega_is_distribution_aware(self):
        """omega_eff = omega * phi with phi = E[1/(1+d)]: the uniform
        tau=3 distribution keeps more of omega than the worst case, and a
        point mass at tau reproduces the historical omega / (1 + tau)."""
        phi = (1 + 1 / 2 + 1 / 3 + 1 / 4) / 4
        assert _proc(tau=3).effective_omega(0.4) == pytest.approx(0.4 * phi)
        point = StalenessProcess(_sched("ring"), max_staleness=3,
                                 delay_probs=(0.0, 0.0, 0.0, 1.0))
        assert point.effective_omega(0.4) == pytest.approx(0.4 / 4)

    def test_effective_omega_monotone_in_delay_mass(self):
        """Shifting probability mass toward larger delays can only shrink
        the Lyapunov constant: omega_eff is monotone decreasing as the
        delay distribution moves mass from d=0 to d=tau."""
        sched = _sched("ring")
        omegas = []
        for mass in (0.0, 0.25, 0.5, 0.75, 1.0):
            p = StalenessProcess(sched, max_staleness=2,
                                 delay_probs=(1.0 - mass, 0.0, mass))
            omegas.append(p.effective_omega(0.4))
        assert omegas == sorted(omegas, reverse=True)
        assert omegas[0] == pytest.approx(0.4)          # all-fresh
        assert omegas[-1] == pytest.approx(0.4 / 3)     # all at tau=2

    def test_straggler_edge_governs_effective_omega(self):
        """The slowest edge's phi_e bounds the accumulated-error path, so
        one straggler edge drags omega_eff to ITS freshness even when the
        global distribution is all-fresh."""
        sched = _sched("ring")
        p = StalenessProcess(sched, max_staleness=2,
                             delay_probs=(1.0, 0.0, 0.0),
                             straggler_edges=((0, 1),))
        assert p.freshness == pytest.approx(1.0)
        assert p.effective_omega(0.4) == pytest.approx(0.4 / 3)

    def test_sample_matrix_not_a_per_step_matrix(self):
        with pytest.raises(NotImplementedError, match="choco_stale_round"):
            _proc().sample_matrix(jax.random.PRNGKey(0), 0)


# ---------------------------------------------------------------------------
# seed reproducibility: the no-communication determinism contract
# ---------------------------------------------------------------------------

class TestSeedReproducibility:
    def test_edge_delays_pure_function_of_key(self):
        p1, p2 = _proc("hypercube"), _proc("hypercube")
        jit_d = jax.jit(lambda k, t: p1.edge_delays(k, t), static_argnums=1)
        key = jax.random.PRNGKey(42)
        for step in range(10):
            ek = jax.random.fold_in(key, step)
            a = np.asarray(p1.edge_delays(ek, 0))
            np.testing.assert_array_equal(a, np.asarray(p2.edge_delays(ek, 0)))
            np.testing.assert_array_equal(a, np.asarray(jit_d(ek, 0)))

    def test_delays_bounded_and_varying(self):
        p = _proc("torus", tau=3)
        key = jax.random.PRNGKey(7)
        draws = np.stack([np.asarray(p.edge_delays(key, t))
                          for t in range(8)])
        assert draws.min() >= 0 and draws.max() <= 3
        assert (draws != draws[0]).any(), "delay sampler is stuck"

    def test_both_directions_share_the_edge_delay(self):
        """Average preservation needs d_ij == d_ji: the per-round delay a
        destination sees must agree with what the reverse direction's
        destination sees, via the canonical undirected edge id."""
        p = _proc("ring", tau=4, n=8)
        dvecs = [np.asarray(v) for v in
                 p.round_delay_vecs(jax.random.PRNGKey(3), 0)]
        for r, ids in enumerate(p.round_edge_ids):
            for dst, e in enumerate(ids):
                if e < 0:
                    continue
                for r2, ids2 in enumerate(p.round_edge_ids):
                    for dst2, e2 in enumerate(ids2):
                        if e2 == e:
                            assert dvecs[r][dst] == dvecs[r2][dst2]

    def test_empirical_delay_frequencies_match_probs(self):
        probs = (0.5, 0.3, 0.2)
        p = _proc("ring", tau=2, delay_probs=probs)
        key = jax.random.PRNGKey(0)
        draws = np.concatenate([np.asarray(p.edge_delays(key, t))
                                for t in range(400)])
        freq = np.bincount(draws, minlength=3) / len(draws)
        np.testing.assert_allclose(freq, probs, atol=0.05)

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), t=st.integers(0, 7))
    def test_sampling_reproducible_property(self, seed, t):
        p = _proc("star", tau=2)
        key = jax.random.PRNGKey(seed)
        np.testing.assert_array_equal(np.asarray(p.edge_delays(key, t)),
                                      np.asarray(p.edge_delays(key, t)))


# ---------------------------------------------------------------------------
# matrix simulator (core/choco_gossip.py)
# ---------------------------------------------------------------------------

class TestStaleSimulator:
    # 250 delay-expanded rounds x 8 graph/tau combos ~= 50s: slow tier
    # (fast-tier stale signal stays via test_average_preserved_exactly)
    @pytest.mark.slow
    @pytest.mark.parametrize("name", ["ring", "hypercube", "star", "torus"])
    @pytest.mark.parametrize("tau", [1, 2])
    def test_consensus_converges(self, name, tau, key):
        proc = _proc(name, tau=tau)
        x0 = jax.random.normal(key, (8, 32))
        _, errs = run_choco_stale_gossip(x0, proc, 0.25, TopK(k=8), 250)
        assert float(errs[-1]) < 1e-4 * float(errs[0]), (
            f"{name}/tau={tau}: {float(errs[0])} -> {float(errs[-1])}")

    def test_average_preserved_exactly(self, key):
        """The pairwise stale exchange moves mass symmetrically at a SHARED
        per-edge lag, so the node average is invariant step by step."""
        proc = _proc("hypercube", tau=3)
        x0 = jax.random.normal(key, (8, 16))
        xbar0 = np.asarray(jnp.mean(x0, 0))
        st = init_stale_state(x0, 3)
        for i in range(40):
            st = choco_stale_round(st, proc, 0.3, TopK(k=4),
                                   jax.random.PRNGKey(i))
        np.testing.assert_allclose(np.asarray(jnp.mean(st.x, 0)), xbar0,
                                   atol=1e-5)

    def test_tau_zero_equals_linkfail_p0(self, key):
        """tau = 0 forces every edge fresh: the stale round must reproduce
        the link-failure replica round at p = 0 (the same always-fresh
        Algorithm-2 form) step for step."""
        sched = _sched("ring")
        sp = StalenessProcess(sched, max_staleness=0)
        lf = LinkFailureProcess(sched, drop_prob=0.0)
        x0 = jax.random.normal(key, (8, 24))
        a = init_stale_state(x0, 0)
        b = init_process_state(x0, lf)
        comp = TopK(k=6)
        for i in range(6):
            k = jax.random.PRNGKey(i)
            a = choco_stale_round(a, sp, 0.3, comp, k)
            b = choco_process_round(b, lf, 0.3, comp, k)
            np.testing.assert_allclose(np.asarray(a.x), np.asarray(b.x),
                                       rtol=1e-6, atol=1e-7)

    def test_tau_zero_equals_static_efficient(self, key):
        """...and therefore also Algorithm 5 on the static W: with every
        copy fresh, sum_r v_r (x_hat_src - x_hat_i) == ((W - I) x_hat)_i."""
        topo = make_topology("hypercube", 8)
        sp = StalenessProcess(compile_schedule(topo), max_staleness=0)
        x0 = jax.random.normal(key, (8, 24))
        W = jnp.asarray(topo.W)
        a = init_stale_state(x0, 0)
        b = init_efficient_state(x0)
        comp = TopK(k=6)
        for i in range(5):
            a = choco_stale_round(a, sp, 0.3, comp, jax.random.PRNGKey(i))
            b = choco_gossip_round_efficient(b, W, 0.3, comp)
            np.testing.assert_allclose(np.asarray(a.x), np.asarray(b.x),
                                       rtol=1e-5, atol=1e-6)

    def test_exact_compressor_still_converges_under_staleness(self, key):
        proc = _proc("ring", tau=4)
        x0 = jax.random.normal(key, (8, 32))
        _, errs = run_choco_stale_gossip(x0, proc, 0.3, Identity(), 200)
        assert float(errs[-1]) < 1e-6 * float(errs[0])


# ---------------------------------------------------------------------------
# trainer / CLI fail-fast + gamma folding
# ---------------------------------------------------------------------------

class TestFailFast:
    def _trainer(self, **kw):
        from repro.configs.base import ChocoConfig, get_config
        from repro.models import build_model
        from repro.optim import constant_schedule, sgd
        from repro.train.trainer import DecentralizedTrainer
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        mode = kw.pop("mode", "choco")
        return DecentralizedTrainer(
            model=build_model(cfg), choco=ChocoConfig(**kw), mesh=mesh,
            n_nodes=1, optimizer=sgd(), lr_fn=constant_schedule(0.1),
            mode=mode)

    def test_staleness_with_plain_rejected(self):
        with pytest.raises(ValueError, match="choco engine"):
            self._trainer(topology="ring", topology_process="staleness",
                          mode="plain")

    def test_exchange_level_rejection(self):
        """make_gossip_exchange itself guards the plain engine (library
        users bypassing the trainer hit the same wall)."""
        from repro.comm.gossip import make_gossip_exchange
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with pytest.raises(ValueError, match="choco engine"):
            make_gossip_exchange(mode="plain", mesh=mesh, state_specs=None,
                                 axis="data", process=_proc("ring", n=8))

    def test_gamma_shrinks_with_staleness_bound(self):
        """Theorem-2 gamma must fold both the delay-averaged eigengap and
        the omega/(1+tau) staleness bound: larger tau -> smaller gamma
        (exactly the composition the trainer runs — the trainer-level twin
        is asserted in the distributed e2e below)."""
        from repro.core.choco_gossip import theorem2_stepsize
        omega = 0.25

        def gamma(tau):
            p = _proc("ring", tau=tau)
            delta, beta = p.expected_delta_beta()
            return theorem2_stepsize(delta, beta, p.effective_omega(omega))

        gammas = [gamma(tau) for tau in (0, 1, 3)]
        assert gammas[0] > gammas[1] > gammas[2] > 0.0

    @pytest.mark.parametrize("argv,msg", [
        (["--topology-process", "staleness", "--mode", "plain"], "choco"),
        (["--topology-process", "staleness", "--mode", "allreduce"],
         "allreduce"),
        (["--mode", "pushsum", "--topology", "directed_ring",
          "--topology-process", "staleness"], "topology-process"),
        (["--max-staleness", "2"], "staleness"),
        (["--topology-process", "staleness", "--max-staleness", "-1"],
         ">= 0"),
        (["--topology-process", "staleness", "--topology", "ring,torus",
          "--gossip-steps", "2"], "ambiguous"),
    ])
    def test_cli_fail_fast(self, argv, msg, capsys):
        """launch/train.py rejects bad async combinations before importing
        jax / touching devices (argparse.error -> SystemExit(2))."""
        from repro.launch.train import main
        with pytest.raises(SystemExit) as ei:
            main(["--arch", "qwen3-1.7b", "--smoke"] + argv)
        assert ei.value.code == 2
        assert msg in capsys.readouterr().err


# ---------------------------------------------------------------------------
# distributed equivalence + HLO audit (slow tier — 8 simulated host devices)
# ---------------------------------------------------------------------------

from test_distributed import run_sub  # noqa: E402  (shared subprocess runner)


@pytest.mark.slow
@pytest.mark.distributed
@pytest.mark.parametrize("topology", ["ring", "star"])
@pytest.mark.parametrize("tau", [1, 2])
def test_distributed_async_engine_matches_simulator(topology, tau):
    """Acceptance: the bounded-staleness engine (packed AND per-leaf)
    reproduces the delay-expanded matrix simulator per step given the same
    seed — per-edge delays are drawn identically on every node from the
    shared exchange key, with zero coordination bytes."""
    run_sub(f"""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.comm.schedule import compile_schedule
        from repro.comm.async_gossip import StalenessProcess
        from repro.core import make_topology, TopK
        from repro.core.choco_gossip import (choco_stale_round,
                                             init_stale_state)

        n, d, tau = 8, 96, {tau}
        topo = make_topology("{topology}", n)
        sched = compile_schedule(topo)
        proc = StalenessProcess(sched, max_staleness=tau)
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        comp = TopK(k=9)            # deterministic: no RNG divergence
        gamma = 0.3
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        R = sched.n_rounds

        st = init_stale_state(x0, tau)
        for i in range(6):
            st = choco_stale_round(st, proc, gamma, comp,
                                   jax.random.PRNGKey(i))

        for packed in (True, False):
            ex = jax.jit(make_gossip_exchange(
                mode="choco", mesh=mesh, state_specs={{"w": P("data", None)}},
                axis="data", compressor=comp, gamma=gamma, packed=packed,
                process=proc))
            x = {{"w": x0}}
            xh = [{{"w": jnp.zeros_like(x0)}} for _ in range(1 + tau)]
            s = [{{"w": jnp.zeros_like(x0)}} for _ in range(R * (1 + tau))]
            for i in range(6):
                x, xh, s = ex(jax.random.PRNGKey(i), x, xh, s)
            np.testing.assert_allclose(np.asarray(x["w"]), np.asarray(st.x),
                                       rtol=1e-4, atol=1e-5)
        print("ASYNC ENGINE == SIMULATOR")
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_async_permute_count_equals_linkfail():
    """Acceptance: staleness adds ZERO permute launches over the linkfail
    baseline — every compiled round ships every step either way, and the
    arrived-vs-stale selection is pure where-mask arithmetic over the ring
    slots (no control flow, no extra collectives)."""
    run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.comm.schedule import compile_schedule
        from repro.comm.async_gossip import StalenessProcess
        from repro.comm.stochastic import LinkFailureProcess
        from repro.core import make_topology, TopK
        from repro.analysis.hlo_audit import count_permute_launches

        def permutes(ex, *args):
            hlo = jax.jit(ex).lower(*args).compile().as_text()
            return count_permute_launches(hlo)

        n, d = 8, 256
        sched = compile_schedule(make_topology("ring", n))
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        comp = TopK(k=16)
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        R = sched.n_rounds
        k = jax.random.PRNGKey(0)

        lf = LinkFailureProcess(sched, drop_prob=0.1)
        ex_lf = make_gossip_exchange(
            mode="choco", mesh=mesh, state_specs=P("data", None),
            axis="data", compressor=comp, gamma=0.3, process=lf)
        n_lf = permutes(ex_lf, k, x0, jnp.zeros_like(x0),
                        [jnp.zeros_like(x0) for _ in range(R)])

        tau = 2
        sp = StalenessProcess(sched, max_staleness=tau)
        ex_as = make_gossip_exchange(
            mode="choco", mesh=mesh, state_specs=P("data", None),
            axis="data", compressor=comp, gamma=0.3, process=sp)
        n_as = permutes(ex_as, k, x0,
                        [jnp.zeros_like(x0) for _ in range(1 + tau)],
                        [jnp.zeros_like(x0) for _ in range(R * (1 + tau))])
        assert n_as == n_lf, (n_as, n_lf)
        print("ASYNC PERMUTES ==", n_as, "== LINKFAIL", n_lf)
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_trainer_async_e2e_and_staleness_change_restore():
    """Trainer end-to-end under bounded staleness on an 8-device mesh:
    finite decreasing loss, replica/ring state layout, and a staleness-bound
    change restoring via the elastic re-mix path (ring subtrees live under
    the reset prefixes, so the re-shaped lists restore clean + re-warm)."""
    run_sub("""
        import os, tempfile
        from repro.configs.base import get_config, ChocoConfig
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.data.synthetic import make_lm_batch_fn

        mesh = jax.make_mesh((8, 1), ("data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        m = build_model(cfg)
        nb = make_lm_batch_fn(cfg, 32, 2, 8)

        def trainer(tau):
            return DecentralizedTrainer(
                model=m, choco=ChocoConfig(
                    compressor="top_k", comp_kwargs=(("fraction", 0.05),),
                    topology="ring", topology_process="staleness",
                    max_staleness=tau),
                mesh=mesh, n_nodes=8, optimizer=sgd(),
                lr_fn=constant_schedule(0.05))

        # gamma folds the staleness bound (trainer-level twin of the
        # fast-tier formula test)
        assert trainer(0).gamma > trainer(2).gamma > 0.0

        tr = trainer(2)
        state = tr.init_state(jax.random.PRNGKey(0))
        R = tr.schedules[0].n_rounds
        assert len(state.x_hat) == 3 and len(state.s) == R * 3
        b = jax.tree.map(jnp.asarray, nb())
        step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                    jax.eval_shape(lambda: b))
        losses = []
        for i in range(8):
            state, mets = step(state, jax.tree.map(jnp.asarray, nb()))
            losses.append(float(mets["loss"]))
        assert all(np.isfinite(losses)) and losses[-1] < losses[0], losses

        d = os.path.join(tempfile.mkdtemp(), "step8")
        tr.save_checkpoint(d, state)
        same, man, warm = tr.restore_checkpoint(d)
        assert warm == 0, "same staleness bound must be resume-exact"
        assert man.fingerprint["max_staleness"] == 2

        t1 = trainer(1)
        restored, man, warm = t1.restore_checkpoint(d)
        assert warm > 0, "staleness-bound change must take the re-mix path"
        assert len(restored.x_hat) == 2 and len(restored.s) == R * 2
        p_old = jax.tree.leaves(state.params)[0]
        p_new = jax.tree.leaves(restored.params)[0]
        np.testing.assert_array_equal(np.asarray(p_old), np.asarray(p_new))
        restored = t1.consensus_warmup(restored, warm)
        total = sum(float(jnp.sum(jnp.abs(l)))
                    for tree in restored.x_hat
                    for l in jax.tree.leaves(tree))
        assert total > 0, "warmup must engage the async engine"
        print("TRAINER ASYNC OK", losses[0], "->", losses[-1])
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_launcher_max_staleness_e2e():
    """Full CLI path: --topology-process staleness --max-staleness trains
    through launch/train.py on a simulated 8-device mesh."""
    run_sub("""
        from repro.launch.train import main
        assert main(["--arch", "qwen3-1.7b", "--smoke", "--mesh", "8x1",
                     "--simulate-devices", "8", "--seq-len", "32",
                     "--batch-per-node", "2", "--compressor", "top_k",
                     "--fraction", "0.05", "--optimizer", "sgd",
                     "--lr", "0.05", "--steps", "4",
                     "--topology-process", "staleness",
                     "--max-staleness", "2"]) == 0
        print("CLI MAX-STALENESS OK")
    """)
