"""Bucketed flat-buffer gossip engine (comm/packing.py): spec construction,
pack/unpack round-trip, packed-vs-per-leaf compression equivalence, and the
paper's Assumption-1 contraction per bucket."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.packing import (bucket_dense, compress_packed,
                                make_bucket_spec, pack_leaves, pack_pytree,
                                packed_wire_bits, unpack_leaves, unpack_pytree)
from repro.core.compression import (BlockTopK, DensePayload, Identity, QSGD,
                                    RandK, SignNorm, TopK)

KEY = jax.random.PRNGKey(0)


def _tree(seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {"emb": jax.random.normal(ks[0], (64, 128), dtype),
            "w": jax.random.normal(ks[1], (33, 7), dtype),    # 231: unaligned
            "ln": jax.random.normal(ks[2], (96,), dtype),
            "b": jax.random.normal(ks[3], (4, 4, 8), dtype)}


def _flat(tree):
    return [l.ravel() for l in jax.tree_util.tree_leaves(tree)]


# -- spec ---------------------------------------------------------------------

def test_spec_dtype_homogeneous_buckets():
    tree = {"a": jnp.zeros((256,), jnp.float32),
            "b": jnp.zeros((300,), jnp.bfloat16),
            "c": jnp.zeros((128,), jnp.float32)}
    spec = make_bucket_spec(tree)
    assert spec.n_buckets == 2
    for slot in spec.slots:
        assert spec.buckets[slot.bucket].dtype == slot.dtype
        assert slot.offset % spec.align == 0          # lane-aligned segments
    by_dtype = {b.dtype.name: b for b in spec.buckets}
    assert by_dtype["float32"].logical == 256 + 128
    assert by_dtype["bfloat16"].logical == 300
    assert by_dtype["bfloat16"].size == 384           # padded to 128 lanes


def test_spec_routes_split_buckets():
    tree = {"a": jnp.zeros((256,)), "b": jnp.zeros((256,))}
    spec = make_bucket_spec(tree, routes=[("model",), ()])
    assert spec.n_buckets == 2
    assert make_bucket_spec(tree, routes=[(), ()]).n_buckets == 1


def test_spec_exact_small_leaf_routing():
    tree = {"big": jnp.zeros((9000,)), "tiny": jnp.zeros((64,))}
    spec = make_bucket_spec(tree, exact_small_leaves=True,
                            small_leaf_threshold=8_192)
    assert spec.n_buckets == 2
    kinds = {b.exact for b in spec.buckets}
    assert kinds == {True, False}


def test_spec_max_bucket_split():
    tree = [jnp.zeros((600,)) for _ in range(4)]
    spec = make_bucket_spec(tree, max_bucket_elems=1500)
    assert spec.n_buckets == 2                         # 2 x 640 per bucket
    assert all(b.size <= 1500 for b in spec.buckets)


# -- pack / unpack ------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pack_unpack_roundtrip_bit_for_bit(dtype):
    tree = _tree(3, dtype)
    spec = make_bucket_spec(tree)
    out = unpack_pytree(spec, pack_pytree(spec, tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pack_leaves_buffer_layout():
    tree = _tree(4)
    spec = make_bucket_spec(tree)
    bufs = pack_leaves(spec, _flat(tree))
    assert len(bufs) == spec.n_buckets
    for b, buf in zip(spec.buckets, bufs):
        assert buf.shape == (b.size,) and buf.dtype == b.dtype
    flats = _flat(tree)
    for slot in spec.slots:
        seg = bufs[slot.bucket][slot.offset:slot.offset + slot.size]
        np.testing.assert_array_equal(np.asarray(seg), np.asarray(flats[slot.leaf]))


# -- packed compression == per-leaf, bit for bit ------------------------------

def test_packed_blocktopk_equals_per_leaf_bit_for_bit():
    """Blockwise selection commutes with block-aligned packing: compressing
    the packed bucket once == compressing every leaf separately."""
    tree = _tree(5)
    comp = BlockTopK(k_per_block=4, block=128)
    spec = make_bucket_spec(tree, align=comp.block)
    flats = _flat(tree)
    _, q_packed = compress_packed(comp, None, spec, flats)
    for flat, q in zip(flats, q_packed):
        q_leaf = comp.compress(None, flat).dense()[: flat.size]
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q_leaf))


def test_packed_topk_single_leaf_equals_per_leaf_bit_for_bit():
    """A single-leaf bucket reduces the packed global top-k to the per-leaf
    path exactly (plumbing check: pad -> top_k -> scatter -> unpack)."""
    x = jax.random.normal(KEY, (513,))
    comp = TopK(k=19)
    spec = make_bucket_spec([x])
    _, q_packed = compress_packed(comp, None, spec, [x])
    q_leaf = comp.compress(None, x).dense()
    np.testing.assert_array_equal(np.asarray(q_packed[0]), np.asarray(q_leaf))


def test_packed_exact_bucket_ships_dense():
    tree = {"big": jax.random.normal(KEY, (9000,)),
            "tiny": jax.random.normal(jax.random.fold_in(KEY, 1), (64,))}
    spec = make_bucket_spec(tree, exact_small_leaves=True,
                            small_leaf_threshold=1_000)
    flats = _flat(tree)
    payloads, q = compress_packed(TopK(fraction=0.01), None, spec, flats)
    leaves = jax.tree_util.tree_leaves(tree)
    for slot in spec.slots:
        if spec.buckets[slot.bucket].exact:
            assert isinstance(payloads[slot.bucket], DensePayload)
            np.testing.assert_array_equal(np.asarray(q[slot.leaf]),
                                          np.asarray(leaves[slot.leaf].ravel()))
        else:
            assert int(jnp.sum(q[slot.leaf] != 0)) < slot.size * 0.05


# -- Assumption 1 per bucket --------------------------------------------------

def _bucket_contraction(comp, n_trials=1):
    """Monte-Carlo E||Q(x)-x||^2 over the packed engine's per-leaf output."""
    tree = _tree(7)
    spec = make_bucket_spec(tree, align=getattr(comp, "block", 128))
    flats = _flat(tree)
    d = sum(f.size for f in flats)
    errs = []
    for i in range(n_trials if comp.stochastic else 1):
        _, q = compress_packed(comp, jax.random.PRNGKey(100 + i), spec, flats)
        errs.append(sum(float(jnp.sum((qi - fi) ** 2))
                        for qi, fi in zip(q, flats)))
    lhs = float(np.mean(errs))
    rhs = (1 - comp.omega(d)) * sum(float(jnp.sum(f * f)) for f in flats)
    return lhs, rhs


def test_bucket_contraction_topk():
    lhs, rhs = _bucket_contraction(TopK(fraction=0.1))
    assert lhs <= rhs + 1e-6


def test_bucket_contraction_blocktopk():
    lhs, rhs = _bucket_contraction(BlockTopK(fraction=0.1))
    assert lhs <= rhs + 1e-6


def test_bucket_contraction_qsgd():
    lhs, rhs = _bucket_contraction(QSGD(16), n_trials=30)
    assert lhs <= rhs * 1.15 + 1e-6        # MC slack, as in test_compression


def test_bucket_contraction_sign():
    lhs, rhs = _bucket_contraction(SignNorm())
    assert lhs <= rhs + 1e-6


def test_packed_topk_absolute_k_is_per_leaf_budget():
    """Regression: TopK(k=K) must keep K coords PER LEAF in a multi-leaf
    bucket (as the per-leaf path does), not K per bucket."""
    tree = [jax.random.normal(jax.random.PRNGKey(i), (256,)) for i in range(3)]
    spec = make_bucket_spec(tree)
    assert spec.n_buckets == 1
    _, q = compress_packed(TopK(k=10), None, spec, tree)
    total_nnz = sum(int(jnp.sum(qi != 0)) for qi in q)
    assert total_nnz == 30


def test_packed_randk_budget_and_no_padding_samples():
    """Regression: RandK must resolve its budget per leaf and sample logical
    coordinates only — uniform sampling of the padded buffer ships
    guaranteed-zero padding positions and inflates k."""
    tree = [jax.random.normal(jax.random.PRNGKey(1), (300,)),
            jax.random.normal(jax.random.PRNGKey(2), (100,))]  # pads to 512
    spec = make_bucket_spec(tree)
    assert spec.n_buckets == 1 and spec.buckets[0].size == 512
    payloads, q = compress_packed(RandK(fraction=0.1), jax.random.PRNGKey(0),
                                  spec, tree)
    assert payloads[0].values.shape == (40,)           # 30 + 10, not 52
    idx = np.asarray(payloads[0].indices)
    logical = set(range(300)) | set(range(384, 484))   # slot layouts
    assert set(idx.tolist()) <= logical


def test_pack_align_must_cover_compressor_block():
    from repro.comm.gossip import _pack_align
    assert _pack_align(BlockTopK(fraction=0.1, block=256), None) == 256
    assert _pack_align(TopK(fraction=0.1), None) == 128
    with pytest.raises(ValueError):
        _pack_align(BlockTopK(fraction=0.1, block=256), 128)


def test_packed_qsgd_large_s_uses_int16():
    """Regression: s > 127 needs int16 codes — int8 clipping silently halves
    large coordinates."""
    x = jnp.zeros((256,)).at[7].set(10.0).at[100].set(0.1)
    spec = make_bucket_spec([x])
    payloads, q = compress_packed(QSGD(256, rescale=False),
                                  jax.random.PRNGKey(0), spec, [x])
    assert payloads[0].codes.dtype == jnp.int16
    # dominant coordinate reconstructs within ~1/s relative error
    assert abs(float(q[0][7]) - 10.0) < 0.1


def test_packed_quant_preserves_segment_layout():
    """Regression: interior segment padding must never shift or truncate the
    dense reconstruction — trimming the quant codes to the *logical* count
    would chop the tail of the bucket's last leaf."""
    # dict leaves sort alphabetically: the unaligned 231-leaf packs BETWEEN
    # the others, so its 25-element pad is interior, not trailing
    tree = {"a_big": jnp.ones((8192,)), "m_mid": jnp.ones((231,)),
            "z_tail": jnp.ones((128,))}
    spec = make_bucket_spec(tree)
    assert spec.buckets[0].size > spec.buckets[0].logical   # interior padding
    flats = _flat(tree)
    _, q = compress_packed(SignNorm(), None, spec, flats)
    for flat, qi in zip(flats, q):
        # all-ones input: sign codes are 1 everywhere, scale = mean|x| = 1
        np.testing.assert_array_equal(np.asarray(qi), np.ones(flat.size))


# -- wire accounting ----------------------------------------------------------

def test_packed_wire_bits_within_10pct_of_per_leaf():
    tree = {f"w{i}": jnp.zeros((512 + 128 * i, 16)) for i in range(6)}
    comp = TopK(fraction=0.01)
    per_leaf = sum(comp.wire_bits(l.size) for l in jax.tree_util.tree_leaves(tree))
    packed = packed_wire_bits(make_bucket_spec(tree), comp)
    assert 0.9 * per_leaf <= packed <= 1.1 * per_leaf


def test_packed_wire_bits_exact_bucket_counts_dense():
    tree = {"big": jnp.zeros((9000,)), "tiny": jnp.zeros((64,))}
    spec = make_bucket_spec(tree, exact_small_leaves=True,
                            small_leaf_threshold=1_000)
    comp = TopK(fraction=0.01)
    bits = packed_wire_bits(spec, comp)
    assert bits == comp.wire_bits(9000) + 64 * 32
