"""Engine-invariant registry tests (fast tier) + a live-compile check.

The registry (``repro.analysis.invariants.ENGINE_INVARIANTS``) is the
single statement of every structural claim a benchmark or test asserts
about a gossip engine's compiled form.  The fast tests pin the expression
evaluator's safety envelope, the lookup semantics, and the conformance of
the committed BENCH_*.json records; the slow test re-derives one registry
entry from a real 8-device compile so the registry can never drift from
the engines it describes.
"""
import json
import os
import textwrap

import pytest

from repro.analysis.findings import Finding
from repro.analysis.invariants import (CONTEXT_VARS, ENGINE_INVARIANTS,
                                       assert_invariant, check_invariant,
                                       evaluate_expectation, get_invariant,
                                       lint_bench_invariants)

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# --------------------------------------------------------------------------
# registry + evaluator
# --------------------------------------------------------------------------

def test_registry_is_wellformed_and_repo_records_conform():
    findings = lint_bench_invariants(ROOT)
    assert findings == [], [f.render() for f in findings]


def test_every_registered_expression_evaluates():
    for inv in ENGINE_INVARIANTS:
        for metric, expr in inv.expect:
            assert isinstance(evaluate_expectation(expr), int), (inv, metric)


def test_get_invariant_backend_wildcard_and_miss():
    assert get_invariant("choco_serial", "pallas").backend == "pallas"
    # the pipelined entry is backend="*": any backend resolves to it
    assert get_invariant("choco_pipelined", "pallas").backend == "*"
    with pytest.raises(KeyError):
        get_invariant("no_such_engine", "jnp")


def test_evaluate_expectation_arithmetic_and_rejections():
    assert evaluate_expectation("2 * buckets * steps",
                                dict(CONTEXT_VARS, buckets=3, steps=2)) == 12
    assert evaluate_expectation("0") == 0
    with pytest.raises(ValueError):
        evaluate_expectation("unknown_name + 1")
    with pytest.raises(ValueError):
        evaluate_expectation("__import__('os').system('true')")


def test_check_invariant_skips_unmeasured_and_flags_mismatch():
    inv = get_invariant("choco_pipelined", "jnp")
    ctx = dict(CONTEXT_VARS, baseline=16)
    # only one of the two metrics measured: the other must be skipped
    assert check_invariant(inv, {"permute_launches": 16}, ctx) == []
    violations = check_invariant(
        inv, {"permute_launches": 20, "dots_feeding_collective": 0}, ctx)
    assert len(violations) == 1
    assert "permute_launches = 20" in violations[0]
    assert "expected baseline = 16" in violations[0]


def test_assert_invariant_raises_with_pointed_message():
    with pytest.raises(AssertionError, match="pallas_calls = 5"):
        assert_invariant("choco_serial", "pallas", {"pallas_calls": 5},
                         dict(CONTEXT_VARS, buckets=2, steps=1))
    # the happy path is silent
    assert_invariant("choco_serial", "pallas", {"pallas_calls": 4},
                     dict(CONTEXT_VARS, buckets=2, steps=1))


# --------------------------------------------------------------------------
# doctored-record detection (the invariant lint pass's whole point)
# --------------------------------------------------------------------------

def _scratch_with_bench(tmp_path, overlap=None, fused=None):
    if overlap is not None:
        (tmp_path / "BENCH_overlap.json").write_text(json.dumps(overlap))
    if fused is not None:
        (tmp_path / "BENCH_fused.json").write_text(json.dumps(fused))
    return str(tmp_path)


def test_doctored_pipelined_gating_is_flagged(tmp_path):
    rec = {"serial": {"permute_launches": 16, "dots_total": 30,
                      "dots_feeding_collective": 30},
           "pipelined": {"permute_launches": 16, "dots_total": 30,
                         "dots_feeding_collective": 5}}
    findings = lint_bench_invariants(_scratch_with_bench(tmp_path, rec))
    assert len(findings) == 1 and isinstance(findings[0], Finding)
    assert findings[0].path == "BENCH_overlap.json"
    assert "dots_feeding_collective = 5" in findings[0].message


def test_doctored_permute_parity_is_flagged(tmp_path):
    rec = {"serial": {"permute_launches": 16, "dots_total": 30,
                      "dots_feeding_collective": 30},
           "pipelined": {"permute_launches": 24, "dots_total": 30,
                         "dots_feeding_collective": 0}}
    findings = lint_bench_invariants(_scratch_with_bench(tmp_path, rec))
    assert len(findings) == 1
    assert "permute_launches = 24" in findings[0].message
    assert "expected baseline = 16" in findings[0].message


def test_doctored_pallas_launch_count_is_flagged(tmp_path):
    rec = {"pallas": {"n_buckets": 2, "pallas_calls": 6}}
    findings = lint_bench_invariants(_scratch_with_bench(tmp_path,
                                                         fused=rec))
    assert len(findings) == 1
    assert findings[0].path == "BENCH_fused.json"
    assert "pallas_calls = 6" in findings[0].message
    assert "expected 2 * buckets * steps = 4" in findings[0].message


def test_doctored_straggler_parity_is_flagged(tmp_path):
    rec = {"straggler": {"global_staleness": 8, "straggler_staleness": 12}}
    (tmp_path / "BENCH_scenarios.json").write_text(json.dumps(rec))
    findings = lint_bench_invariants(str(tmp_path))
    assert len(findings) == 1
    assert findings[0].path == "BENCH_scenarios.json"
    assert "permute_launches = 12" in findings[0].message
    assert "expected baseline = 8" in findings[0].message
    # the committed-record shape passes
    rec["straggler"]["straggler_staleness"] = 8
    (tmp_path / "BENCH_scenarios.json").write_text(json.dumps(rec))
    assert lint_bench_invariants(str(tmp_path)) == []


def test_clean_scratch_records_pass(tmp_path):
    overlap = {"serial": {"permute_launches": 8, "dots_total": 12,
                          "dots_feeding_collective": 12},
               "pipelined": {"permute_launches": 8, "dots_total": 12,
                             "dots_feeding_collective": 0}}
    fused = {"pallas": {"n_buckets": 3, "pallas_calls": 6}}
    assert lint_bench_invariants(
        _scratch_with_bench(tmp_path, overlap, fused)) == []


# --------------------------------------------------------------------------
# live compile: one registry entry re-derived from a real engine
# --------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.distributed
def test_registry_matches_live_pipelined_compile():
    """The choco_pipelined registry entry holds on a real 8-device compile
    of the gossip exchange — the registry cannot drift from the engine."""
    from tests.test_pipelined import run_sub
    run_sub(textwrap.dedent("""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.core import TopK
        from repro.analysis.hlo_audit import collective_dependency_audit
        from repro.analysis.invariants import CONTEXT_VARS, assert_invariant

        n, d = 8, 512
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        z = jnp.zeros_like(x0)
        audits = {}
        for pipe in (False, True):
            ex = make_gossip_exchange(mode="choco", mesh=mesh,
                                      state_specs=P("data", None),
                                      axis="data", compressor=TopK(k=64),
                                      gamma=0.3, pipelined=pipe)
            hlo = jax.jit(ex).lower(jax.random.PRNGKey(1), x0, z,
                                    z).compile().as_text()
            audits[pipe] = collective_dependency_audit(hlo).as_dict()
        ctx = dict(CONTEXT_VARS, baseline=audits[False]["permute_launches"])
        assert_invariant("choco_pipelined", "jnp",
                         {"dots_feeding_collective":
                          audits[True]["dots_feeding_collective"],
                          "permute_launches":
                          audits[True]["permute_launches"]}, ctx)
        print("REGISTRY-LIVE OK", audits)
    """))
