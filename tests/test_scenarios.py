"""Convergence-contract tests over the declarative scenario matrix.

Tiering (see pytest.ini):
  * fast (unmarked): matrix-shape invariants + ONE representative contract
    triple (skewed CHOCO vs its no-gossip and IID controls) — seconds;
  * ``slow``: the full >= 12-scenario sweep with every contract;
  * ``slow + distributed``: per-edge straggler engine == simulator parity
    on the 8-device mesh (iterate for iterate).
"""
import numpy as np
import pytest

from scenarios import (BATCH, N_NODES, SCENARIOS, Scenario, get_scenario,
                       iid_control, no_gossip_control, run_scenario)
from test_distributed import run_sub  # noqa: E402  (shared subprocess runner)

# contract tolerances, calibrated against the observed noise floor of the
# reduced problem (~1e-4 in final loss between reseeded gossip runs; the
# no-gossip gap is ~2e-2 — two orders of magnitude of headroom)
IID_BAND = 0.01         # |loss(skewed CHOCO) - loss(IID CHOCO)| stays inside
NOGOSSIP_MARGIN = 5e-3  # loss(no-gossip) - loss(CHOCO) must exceed


# ---------------------------------------------------------------------------
# fast tier: the declarative matrix itself
# ---------------------------------------------------------------------------


class TestMatrixShape:
    def test_core_matrix_floor(self):
        """Acceptance floor: alpha in {0.1, 1, 100} x {ring, hypercube} x
        {topk, qsgd} — at least 12 core scenarios, all distinct."""
        names = [sc.name for sc in SCENARIOS]
        assert len(names) == len(set(names))
        assert len(SCENARIOS) >= 12
        for alpha in (0.1, 1.0, 100.0):
            for topo in ("ring", "hypercube"):
                for comp in ("topk", "qsgd"):
                    assert f"a{alpha:g}-{topo}-{comp}" in names

    def test_matrix_has_controls_k3_and_stragglers(self):
        names = [sc.name for sc in SCENARIOS]
        assert any(n.startswith("iid-") for n in names)
        assert any(n.endswith("-k3") for n in names)
        straggler = [sc for sc in SCENARIOS if sc.straggler_edges]
        assert straggler and all(sc.process == "staleness"
                                 for sc in straggler)

    def test_get_scenario_roundtrip_and_unknown(self):
        for sc in SCENARIOS:
            assert get_scenario(sc.name) is sc
        with pytest.raises(KeyError, match="unknown scenario"):
            get_scenario("nope")

    def test_controls_are_derived_not_listed(self):
        sc = get_scenario("a0.1-ring-topk")
        ng = no_gossip_control(sc)
        assert ng.gamma == 0.0 and ng.process is None and ng.alpha == sc.alpha
        iid = iid_control(sc)
        assert iid.alpha is None and iid.gamma == sc.gamma
        # derived controls never shadow a declared scenario
        names = {s.name for s in SCENARIOS}
        assert ng.name not in names and iid.name not in names


class TestRepresentativeContract:
    """One contract triple in the fast tier so a broken runner or a broken
    partitioner fails within seconds, not only in the slow sweep."""

    @pytest.fixture(scope="class")
    def triple(self):
        sc = get_scenario("a0.1-ring-topk")
        return {"choco": run_scenario(sc),
                "nogossip": run_scenario(no_gossip_control(sc)),
                "iid": run_scenario(iid_control(sc))}

    def test_skewed_choco_beats_no_gossip(self, triple):
        assert (triple["nogossip"]["final_loss"]
                > triple["choco"]["final_loss"] + NOGOSSIP_MARGIN), triple

    def test_skewed_choco_inside_iid_band(self, triple):
        gap = abs(triple["choco"]["final_loss"]
                  - triple["iid"]["final_loss"])
        assert gap < IID_BAND, triple

    def test_no_gossip_diverges_in_consensus(self, triple):
        assert (triple["nogossip"]["consensus_dist"]
                > 100 * triple["choco"]["consensus_dist"]), triple


# ---------------------------------------------------------------------------
# slow tier: the full sweep, every contract
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestFullSweep:
    @pytest.fixture(scope="class")
    def results(self):
        out = {sc.name: run_scenario(sc) for sc in SCENARIOS}
        # controls for the contract comparisons (skew alpha=0.1 cells)
        for name in ("a0.1-ring-topk", "a0.1-ring-qsgd",
                     "a0.1-hypercube-topk", "a0.1-hypercube-qsgd"):
            sc = get_scenario(name)
            out[name + "-nogossip"] = run_scenario(no_gossip_control(sc))
        return out

    def test_all_scenarios_converge(self, results):
        for name, r in results.items():
            assert np.isfinite(r["final_loss"]), (name, r)
            assert r["final_loss"] < 0.55, (name, r)     # well below ln 2

    def test_skewed_beats_no_gossip_everywhere(self, results):
        for name in ("a0.1-ring-topk", "a0.1-ring-qsgd",
                     "a0.1-hypercube-topk", "a0.1-hypercube-qsgd"):
            choco, ng = results[name], results[name + "-nogossip"]
            assert (ng["final_loss"]
                    > choco["final_loss"] + NOGOSSIP_MARGIN), (name, choco, ng)

    def test_skew_within_iid_band(self, results):
        """Final consensus-loss band vs the IID control, per cell."""
        for topo in ("ring", "hypercube"):
            for comp in ("topk", "qsgd"):
                iid = results[f"iid-{topo}-{comp}"]["final_loss"]
                for alpha in (0.1, 1.0, 100.0):
                    got = results[f"a{alpha:g}-{topo}-{comp}"]["final_loss"]
                    assert abs(got - iid) < IID_BAND, (topo, comp, alpha,
                                                       got, iid)

    def test_gossip_steps_3_narrows_skew_gap(self, results):
        """k=3 consensus rounds per step vs k=1 on the hardest skew: the
        consensus gap must shrink decisively, and the final loss must not
        regress beyond noise."""
        for comp in ("topk", "qsgd"):
            k1 = results[f"a0.1-ring-{comp}"]
            k3 = results[f"a0.1-ring-{comp}-k3"]
            assert (k3["consensus_dist"]
                    < 0.5 * k1["consensus_dist"]), (comp, k1, k3)
            assert (k3["final_loss"]
                    < k1["final_loss"] + 1e-3), (comp, k1, k3)

    def test_straggler_still_converges(self, results):
        """A maximally slow ring link under alpha=0.1 skew slows consensus
        but does not break the contract vs no communication at all."""
        straggler = results["a0.1-ring-topk-straggler"]
        uniform = results["a0.1-ring-topk-stale-uniform"]
        ng = results["a0.1-ring-topk-nogossip"]
        for r in (straggler, uniform):
            assert ng["final_loss"] > r["final_loss"] + NOGOSSIP_MARGIN, r
            assert ng["consensus_dist"] > 100 * r["consensus_dist"], r


# ---------------------------------------------------------------------------
# distributed tier: straggler engine == simulator, iterate for iterate
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.distributed
@pytest.mark.parametrize("probs", ["default", "custom"])
def test_distributed_straggler_engine_matches_simulator(probs):
    """Acceptance: with a single straggler edge the 8-device engine
    reproduces the extended matrix simulator iterate for iterate — the
    per-edge delay table is drawn identically on every node from the shared
    exchange key, exactly like the global-distribution case."""
    sprobs = ("None" if probs == "default" else "(0.1, 0.2, 0.7)")
    run_sub(f"""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.comm.schedule import compile_schedule
        from repro.comm.async_gossip import StalenessProcess
        from repro.core import make_topology, TopK
        from repro.core.choco_gossip import (choco_stale_round,
                                             init_stale_state)

        n, d, tau = 8, 96, 2
        sched = compile_schedule(make_topology("ring", n))
        proc = StalenessProcess(sched, max_staleness=tau,
                                straggler_edges=((0, 1), (4, 5)),
                                straggler_delay_probs={sprobs})
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        comp = TopK(k=9)            # deterministic: no RNG divergence
        gamma = 0.3
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        R = sched.n_rounds

        st = init_stale_state(x0, tau)
        for i in range(6):
            st = choco_stale_round(st, proc, gamma, comp,
                                   jax.random.PRNGKey(i))

        for packed in (True, False):
            ex = jax.jit(make_gossip_exchange(
                mode="choco", mesh=mesh, state_specs={{"w": P("data", None)}},
                axis="data", compressor=comp, gamma=gamma, packed=packed,
                process=proc))
            x = {{"w": x0}}
            xh = [{{"w": jnp.zeros_like(x0)}} for _ in range(1 + tau)]
            s = [{{"w": jnp.zeros_like(x0)}} for _ in range(R * (1 + tau))]
            for i in range(6):
                x, xh, s = ex(jax.random.PRNGKey(i), x, xh, s)
            np.testing.assert_allclose(np.asarray(x["w"]), np.asarray(st.x),
                                       rtol=1e-4, atol=1e-5)
        print("STRAGGLER ENGINE == SIMULATOR")
    """)
