"""Compression operators: Assumption 1 contraction property + wire formats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from optional_hypothesis import given, settings, st

from repro.core.compression import (Identity, RandK, TopK, BlockTopK, QSGD,
                                    SignNorm, RandomizedGossip, make_compressor)

DIMS = [16, 100, 1000]


def _rand(seed, d, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (d,)) * scale


def _mean_sq_err(comp, x, n_trials=20):
    """Monte-Carlo E||Q(x) - x||^2."""
    errs = []
    for i in range(n_trials if comp.stochastic else 1):
        k = jax.random.PRNGKey(100 + i)
        q = comp.apply(k, x)
        errs.append(float(jnp.sum((q - x) ** 2)))
    return np.mean(errs)


@pytest.mark.parametrize("d", DIMS)
@pytest.mark.parametrize("make", [
    lambda: Identity(),
    lambda: RandK(fraction=0.1),
    lambda: TopK(fraction=0.1),
    lambda: BlockTopK(fraction=0.1),
    lambda: QSGD(16),
    lambda: QSGD(127),
    lambda: SignNorm(),
    lambda: RandomizedGossip(0.3),
])
def test_contraction_property(d, make):
    """E||Q(x)-x||^2 <= (1 - omega) ||x||^2   (eq. 7)."""
    comp = make()
    x = _rand(d, d)
    omega = comp.omega(d)
    assert 0 < omega <= 1
    lhs = _mean_sq_err(comp, x, n_trials=50)
    rhs = (1 - omega) * float(jnp.sum(x * x))
    # MC slack for stochastic operators
    slack = 1.15 if comp.stochastic else 1.0 + 1e-5
    assert lhs <= rhs * slack + 1e-6, (comp.name, lhs, rhs)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 500), st.integers(0, 2 ** 31 - 1))
def test_topk_contraction_hypothesis(d, seed):
    comp = TopK(k=max(1, d // 10))
    x = _rand(seed % 1000, d)
    lhs = _mean_sq_err(comp, x)
    assert lhs <= (1 - comp.omega(d)) * float(jnp.sum(x * x)) + 1e-5


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 300), st.integers(1, 100))
def test_qsgd_contraction_hypothesis(d, s):
    comp = QSGD(s)
    x = _rand(d, d, scale=3.0)
    lhs = _mean_sq_err(comp, x, n_trials=30)
    assert lhs <= (1 - comp.omega(d)) * float(jnp.sum(x * x)) * 1.2 + 1e-5


def test_topk_selects_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    q = TopK(k=2).apply(None, x)
    np.testing.assert_allclose(np.asarray(q), [0, -5.0, 0, 3.0, 0])


def test_randk_payload_roundtrip():
    comp = RandK(fraction=0.25)
    x = _rand(1, 64)
    pl = comp.compress(jax.random.PRNGKey(2), x)
    assert pl.values.shape == (16,)
    dense = pl.dense()
    nz = jnp.nonzero(dense)[0]
    assert set(np.asarray(nz)) == set(np.asarray(pl.indices))


def test_qsgd_wire_bits_much_smaller():
    d = 10_000
    # 2s+1 = 33 levels + sign -> 7 bits/coord vs 32-bit floats
    assert QSGD(16).wire_bits(d) < 32 * d / 4
    assert TopK(fraction=0.01).wire_bits(d) < 32 * d / 40


@pytest.mark.parametrize("make", [
    lambda: Identity(),
    lambda: RandK(fraction=0.1),
    lambda: TopK(fraction=0.1),
    lambda: BlockTopK(fraction=0.1),
    lambda: QSGD(16),
    lambda: QSGD(127),
    lambda: SignNorm(),
])
@pytest.mark.parametrize("d", [100, 1000])
def test_wire_bits_matches_emitted_payload(make, d):
    """Regression: the analytic wire_bits(d) must equal the wire_bits() of
    the payload compress() actually emits.  (RandomizedGossip is excluded:
    its analytic figure is an expectation over the keep/drop coin, while any
    single payload is dense.)"""
    comp = make()
    pl = comp.compress(jax.random.PRNGKey(0), _rand(0, d))
    assert pl.wire_bits() == comp.wire_bits(d), comp.name


def test_unbiased_variants():
    d = 200
    x = _rand(3, d)
    comp = RandK(fraction=0.5, rescale=True)
    keys = [jax.random.PRNGKey(i) for i in range(300)]
    mean = jnp.mean(jnp.stack([comp.apply(k, x) for k in keys]), axis=0)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(x), atol=0.45)


def test_registry():
    assert make_compressor("top_k", fraction=0.01).name == "top_k"
    assert make_compressor("qsgd", s=16).name == "qsgd"
    with pytest.raises(ValueError):
        make_compressor("nope")
