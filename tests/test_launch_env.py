"""--simulate-devices must APPEND to XLA_FLAGS, never clobber them."""
from repro.launch.env import simulate_host_devices


def test_appends_to_preset_flags(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "--xla_dump_to=/tmp/dump --xla_foo=1")
    got = simulate_host_devices(8)
    toks = got.split()
    assert "--xla_dump_to=/tmp/dump" in toks
    assert "--xla_foo=1" in toks
    assert "--xla_force_host_platform_device_count=8" in toks


def test_replaces_stale_device_count(monkeypatch):
    monkeypatch.setenv(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=2 --xla_foo=1")
    toks = simulate_host_devices(8).split()
    assert toks.count("--xla_force_host_platform_device_count=8") == 1
    assert "--xla_force_host_platform_device_count=2" not in toks
    assert "--xla_foo=1" in toks


def test_unset_env(monkeypatch):
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    assert simulate_host_devices(4) == \
        "--xla_force_host_platform_device_count=4"
