"""CHOCO-Gossip (Theorem 2) + consensus baselines (paper §3, Figs 2-3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ring, fully_connected, TopK, QSGD, RandK, Identity,
                        run_choco_gossip, run_choco_gossip_efficient,
                        run_gossip_baseline, theorem2_stepsize, theorem2_rate,
                        auto_stepsize, choco_gossip_round, init_state)


def _setup(n=15, d=100, seed=0):
    topo = ring(n)
    x0 = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    return topo, jnp.asarray(topo.W), x0


def test_exact_gossip_linear_convergence():
    """Theorem 1: (E-G) contracts by (1 - gamma delta)^2 per round."""
    topo, W, x0 = _setup()
    _, errs = run_gossip_baseline("exact", x0, W, None, 400)
    assert errs[-1] < 1e-6 * errs[0]
    # measured rate at least as good as theory
    rate_emp = (errs[100] / errs[50]) ** (1 / 50)   # before the f32 floor
    assert rate_emp <= (1 - topo.delta) ** 2 + 1e-3


def test_choco_preserves_average():
    topo, W, x0 = _setup(n=8, d=32)
    state = init_state(x0)
    for i in range(5):
        state = choco_gossip_round(state, W, 0.05, TopK(fraction=0.2),
                                   jax.random.PRNGKey(i))
    np.testing.assert_allclose(np.asarray(jnp.mean(state.x, 0)),
                               np.asarray(jnp.mean(x0, 0)), atol=1e-5)


def test_choco_converges_with_biased_topk():
    """The paper's headline: linear convergence under *biased* compression."""
    topo, W, x0 = _setup()
    comp = TopK(fraction=0.1)
    gamma = auto_stepsize(topo, comp, 100)
    _, errs = run_choco_gossip(x0, W, max(gamma, 0.03), comp, 4000)
    assert errs[-1] < 1e-4 * errs[0]


def test_choco_converges_with_qsgd():
    topo, W, x0 = _setup()
    _, errs = run_choco_gossip(x0, W, 1.0, QSGD(256), 400)
    _, errs_exact = run_gossip_baseline("exact", x0, W, None, 400)
    # qsgd_256 should track exact gossip closely (paper Fig 2 left)
    assert errs[-1] < 10 * max(float(errs_exact[-1]), 1e-10)


def test_choco_theorem2_rate_bound():
    """Error contracts at least as fast as (1 - delta^2 omega / 82)."""
    topo, W, x0 = _setup(n=9, d=50)
    comp = RandK(fraction=0.2)
    gamma = theorem2_stepsize(topo.delta, topo.beta, 0.2)
    _, errs = run_choco_gossip(x0, W, gamma, comp, 3000,
                               key=jax.random.PRNGKey(1))
    bound = theorem2_rate(topo.delta, 0.2)
    # e_T <= bound^T e_0 — use the paper's Lyapunov which upper-bounds the
    # x-error; compare cumulative decay with generous slack
    assert errs[-1] <= (bound ** 3000) * errs[0] * 1e3 + 1e-10


def test_choco_efficient_equivalent():
    """Algorithm 1 == Algorithm 5 (memory-efficient form)."""
    topo, W, x0 = _setup(n=7, d=40)
    comp = TopK(fraction=0.3)
    _, e1 = run_choco_gossip(x0, W, 0.1, comp, 200)
    _, e2 = run_choco_gossip_efficient(x0, W, 0.1, comp, 200)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=2e-2, atol=1e-4)


def test_q1_gossip_loses_average():
    """(Q1-G) does not preserve the average (paper §3.3)."""
    topo, W, x0 = _setup(n=8, d=32)
    comp = QSGD(4, rescale=False)
    X = x0
    key = jax.random.PRNGKey(0)
    from repro.core.baselines import q1_gossip_round
    for i in range(20):
        X = q1_gossip_round(X, W, comp, jax.random.fold_in(key, i))
    drift = float(jnp.linalg.norm(jnp.mean(X, 0) - jnp.mean(x0, 0)))
    assert drift > 1e-3


def test_q2_gossip_plateaus():
    """(Q2-G) stalls at a noise floor; CHOCO goes below it (Fig 2)."""
    topo, W, x0 = _setup()
    comp = QSGD(16, rescale=False)
    _, errs_q2 = run_gossip_baseline("q2", x0, W, comp, 2000)
    _, errs_choco = run_choco_gossip(x0, W, 0.3, QSGD(16), 2000)
    assert errs_choco[-1] < errs_q2[-1] / 10


def test_identity_recovers_exact_gossip():
    topo, W, x0 = _setup(n=6, d=20)
    _, e_choco = run_choco_gossip(x0, W, 0.9, Identity(), 100)
    assert e_choco[-1] < 1e-4 * e_choco[0]


def test_fully_connected_one_shot_exact():
    """Complete graph + exact communication: consensus in one round."""
    n, d = 8, 16
    x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    W = jnp.asarray(fully_connected(n).W)
    _, errs = run_gossip_baseline("exact", x0, W, None, 2)
    assert errs[0] < 1e-10
