"""Pipelined gossip engine (comm/pipelined.py) + PR-6 satellite fixes.

Fast tier: the pipelined matrix recursion's invariants (mean preservation,
convergence, equivalence to the depth-1 bounded-staleness algebra it is
derived from), per-bucket Theorem-2 gamma resolution (GammaSpec /
bucket_omegas), and the `_local_shape` non-divisible-shard guard.

Slow tier (8-device subprocesses, tests/test_distributed.py pattern): the
shard_map engine == matrix simulator per step (packed and per-leaf), the
per-bucket gamma engine against independent per-bucket simulators, the
compressor-fingerprint restore regression, and the dependency audit proving
the pipelined collective is independent of the batch (the overlap property
benchmarks/bench_overlap.py quantifies on compiled HLO).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(ROOT, "src")


def run_sub(body: str, timeout=560):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep + ROOT)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# fast tier — matrix simulator + gamma plumbing + shard-shape guard
# ---------------------------------------------------------------------------

def test_gamma_spec_value_is_scaled_theorem2():
    from repro.core.choco_gossip import GammaSpec, theorem2_stepsize
    gs = GammaSpec(delta=0.4, beta=0.7, omega_scale=0.5)
    assert gs.value(0.25) == pytest.approx(theorem2_stepsize(0.4, 0.7, 0.125))
    assert (GammaSpec(delta=0.4, beta=0.7).value(0.25)
            == pytest.approx(theorem2_stepsize(0.4, 0.7, 0.25)))


def test_bucket_omegas_per_bucket_vs_worst():
    """bucket_omegas gives each bucket its own Assumption-1 omega (exact
    buckets = 1); bucket_omega_worst is the min over COMPRESSED buckets."""
    import jax
    import jax.numpy as jnp
    from repro.comm.packing import (bucket_omega_worst, bucket_omegas,
                                    make_bucket_spec)
    from repro.core import TopK
    leaves = [jax.ShapeDtypeStruct((4096,), jnp.float32),
              jax.ShapeDtypeStruct((64,), jnp.float32)]
    spec = make_bucket_spec(leaves, align=128, exact_small_leaves=True,
                            small_leaf_threshold=1024)
    assert len(spec.buckets) == 2
    comp = TopK(fraction=0.05)
    oms = bucket_omegas(spec, comp)
    assert len(oms) == len(spec.buckets)
    exact = [b.exact for b in spec.buckets]
    for om, ex in zip(oms, exact):
        if ex:
            assert om == 1.0
        else:
            assert 0.0 < om < 1.0
    assert bucket_omega_worst(spec, comp) == min(
        om for om, ex in zip(oms, exact) if not ex)


def test_resolve_leaf_gammas_maps_buckets_to_leaves():
    import jax
    import jax.numpy as jnp
    from repro.comm.gossip import _resolve_leaf_gammas
    from repro.comm.packing import bucket_omegas, make_bucket_spec
    from repro.core import TopK
    from repro.core.choco_gossip import GammaSpec
    leaves = [jax.ShapeDtypeStruct((4096,), jnp.float32),
              jax.ShapeDtypeStruct((64,), jnp.float32)]
    spec = make_bucket_spec(leaves, align=128, exact_small_leaves=True,
                            small_leaf_threshold=1024)
    comp = TopK(fraction=0.05)
    gs = GammaSpec(delta=0.4, beta=0.9)
    gammas = _resolve_leaf_gammas(gs, spec, comp)
    oms = bucket_omegas(spec, comp)
    by_bucket = [gs.value(om) for om in oms]
    expect = [by_bucket[slot.bucket]
              for slot in sorted(spec.slots, key=lambda sl: sl.leaf)]
    assert gammas == expect
    # exact leaf contracts at omega=1, strictly faster than the top-k leaf
    assert max(gammas) > min(gammas)
    # a float passes through untouched (legacy single global gamma)
    assert _resolve_leaf_gammas(0.25, spec, comp) == 0.25


def test_local_shape_divides_or_raises():
    from jax.sharding import PartitionSpec as P
    from repro.train.trainer import _local_shape
    assert _local_shape((8, 64), P("data", None), {"data": 4}) == (2, 64)
    assert _local_shape((16, 3), P(("pod", "data"), None),
                        {"pod": 2, "data": 4}) == (2, 3)
    assert _local_shape((5, 7), P(None, None), {"data": 4}) == (5, 7)
    with pytest.raises(ValueError, match="not\\s+divisible"):
        _local_shape((6, 64), P("data", None), {"data": 4})
    with pytest.raises(ValueError, match="not\\s+divisible"):
        # the old code silently floored this to (1,) via max(1, 1 // 4)
        _local_shape((1,), P("data"), {"data": 4})


def test_pipelined_simulator_preserves_mean_and_converges():
    import jax
    import jax.numpy as jnp
    from repro.core import TopK, make_topology
    from repro.core.choco_gossip import run_choco_pipelined_gossip
    topo = make_topology("ring", 8)
    W = jnp.asarray(topo.W)
    comp = TopK(k=24)
    # practical stepsize (the Theorem-2 bound is orders of magnitude too
    # conservative on ring(8) to show contraction within a unit test)
    gamma = 0.3
    x0 = jax.random.normal(jax.random.PRNGKey(0), (8, 96))
    st, errs = run_choco_pipelined_gossip(x0, W, gamma, comp, steps=200)
    np.testing.assert_allclose(np.mean(np.asarray(st.x), axis=0),
                               np.mean(np.asarray(x0), axis=0),
                               rtol=1e-4, atol=1e-5)
    assert float(errs[-1]) < 0.05 * float(errs[0])


def test_pipelined_recursion_equals_depth1_stale():
    """The compact (x, x_hat, s) pipelined carry IS the bounded-staleness
    engine at deterministic delay 1: against the delay-expanded ring
    simulator driven by pipeline_delay_process, iterates must agree (the
    depth-1 rings collapse into the carry)."""
    import jax
    import jax.numpy as jnp
    from repro.comm.pipelined import pipeline_delay_process
    from repro.comm.schedule import compile_schedule
    from repro.core import TopK, make_topology
    from repro.core.choco_gossip import (run_choco_pipelined_gossip,
                                         run_choco_stale_gossip)
    topo = make_topology("ring", 8)
    proc = pipeline_delay_process(compile_schedule(topo))
    assert proc.max_staleness == 1
    assert proc.freshness == pytest.approx(0.5)
    assert proc.effective_omega(0.3) == pytest.approx(0.15)
    comp = TopK(k=9)                       # deterministic: no RNG divergence
    x0 = jax.random.normal(jax.random.PRNGKey(1), (8, 96))
    st_stale, _ = run_choco_stale_gossip(x0, proc, 0.2, comp, steps=7)
    st_pipe, _ = run_choco_pipelined_gossip(x0, jnp.asarray(topo.W), 0.2,
                                            comp, steps=7)
    np.testing.assert_allclose(np.asarray(st_stale.x), np.asarray(st_pipe.x),
                               rtol=1e-5, atol=1e-6)


def test_pipeline_gossip_config_default_off():
    from repro.configs.base import ChocoConfig
    assert ChocoConfig().pipeline_gossip is False


def test_trainer_rejects_gamma_spec_on_per_leaf_engine():
    from repro.comm.gossip import make_choco_schedule_fn
    from repro.comm.schedule import compile_schedule
    from repro.core import TopK, make_topology
    from repro.core.choco_gossip import GammaSpec
    sched = compile_schedule(make_topology("ring", 8))
    with pytest.raises(ValueError, match="packed"):
        make_choco_schedule_fn(axes=("data",), sizes=(8,),
                               schedules=(sched,), compressor=TopK(k=4),
                               gamma=GammaSpec(delta=0.3, beta=0.9),
                               packed=False)


# ---------------------------------------------------------------------------
# slow tier — 8-device engine parity, trainer restore, dependency audit
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.distributed
@pytest.mark.parametrize("packed", [True, False])
def test_pipelined_engine_matches_matrix_simulator(packed):
    """Per-step parity of the shard_map pipelined engine (stochastic top_k,
    engine key folds replicated on the simulator side) with the
    choco_pipelined_round recursion."""
    run_sub(f"""
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.comm.gossip import make_gossip_exchange
        from repro.comm.schedule import compile_schedule
        from repro.core import make_topology
        from repro.core.choco_gossip import (PipelinedGossipState,
                                             init_pipelined_state)
        from repro.core.compression import make_compressor

        N, D, STEPS = 8, 96, 5
        topo = make_topology("ring", N)
        sched = compile_schedule(topo)
        W = jnp.asarray(topo.W, jnp.float32)
        comp = make_compressor("top_k", fraction=0.25)
        gamma = 0.3
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        key = jax.random.PRNGKey(0)
        x0 = jax.random.normal(jax.random.fold_in(key, 1), (N, D))

        ex = make_gossip_exchange(
            mode="choco", mesh=mesh, state_specs=P("data", None),
            axis="data", compressor=comp, gamma=gamma, schedules=(sched,),
            packed={packed}, pipelined=True)
        x, hat, s = x0, jnp.zeros_like(x0), jnp.zeros_like(x0)
        st = init_pipelined_state(x0)
        for t in range(STEPS):
            gk = jax.random.fold_in(key, 100 + t)
            x, hat, s = ex(gk, x, hat, s)
            pk = jax.vmap(lambda i: jax.random.fold_in(gk, i))(jnp.arange(N))
            q = jax.vmap(comp)(pk, st.x - st.x_hat)
            st = PipelinedGossipState(
                x=st.x + gamma * (st.s - st.x_hat),
                x_hat=st.x_hat + q, s=st.s + W @ q)
            np.testing.assert_allclose(np.asarray(x), np.asarray(st.x),
                                       rtol=1e-4, atol=1e-5)
            np.testing.assert_allclose(np.asarray(hat), np.asarray(st.x_hat),
                                       rtol=1e-4, atol=1e-5)
        print("MATCH")
    """)


@pytest.mark.slow
@pytest.mark.distributed
@pytest.mark.parametrize("pipelined", [False, True])
def test_per_bucket_gamma_engine_matches_per_bucket_simulator(pipelined):
    """GammaSpec on the packed engine: a two-leaf tree (large top-k bucket +
    exact small bucket) must evolve as two INDEPENDENT matrix recursions,
    each at its own bucket's Theorem-2 gamma — the satellite-2 bugfix (one
    worst-case global gamma would damp the exact leaf)."""
    run_sub(f"""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.comm.packing import bucket_omegas, make_bucket_spec
        from repro.comm.schedule import compile_schedule
        from repro.core import TopK, make_topology
        from repro.core.choco_gossip import (GammaSpec, PipelinedGossipState,
                                             init_pipelined_state,
                                             init_efficient_state,
                                             choco_gossip_round_efficient)
        from repro.core.compression import Identity

        N, DBIG, DSMALL, STEPS = 8, 1024, 64, 4
        topo = make_topology("ring", N)
        sched = compile_schedule(topo)
        W = jnp.asarray(topo.W, jnp.float32)
        comp = TopK(fraction=0.05)          # deterministic
        gs = GammaSpec(delta=topo.delta, beta=topo.beta,
                       omega_scale={0.5 if pipelined else 1.0})
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        k0 = jax.random.PRNGKey(3)
        big = jax.random.normal(jax.random.fold_in(k0, 0), (N, DBIG))
        small = jax.random.normal(jax.random.fold_in(k0, 1), (N, DSMALL))

        leaves = [jax.ShapeDtypeStruct((DBIG,), jnp.float32),
                  jax.ShapeDtypeStruct((DSMALL,), jnp.float32)]
        spec = make_bucket_spec(leaves, align=128, exact_small_leaves=True,
                                small_leaf_threshold=256)
        oms = bucket_omegas(spec, comp)
        by_bucket = [gs.value(om) for om in oms]
        slot = sorted(spec.slots, key=lambda sl: sl.leaf)
        g_big, g_small = (by_bucket[slot[0].bucket],
                          by_bucket[slot[1].bucket])
        assert g_small > g_big, (g_small, g_big)

        ex = make_gossip_exchange(
            mode="choco", mesh=mesh,
            state_specs={{"big": P("data", None), "small": P("data", None)}},
            axis="data", compressor=comp, gamma=gs, schedules=(sched,),
            packed=True, exact_small_leaves=True, small_leaf_threshold=256,
            pipelined={pipelined})
        z = lambda t: jax.tree.map(jnp.zeros_like, t)
        x = {{"big": big, "small": small}}
        hat, s = z(x), z(x)
        # independent per-bucket simulators: top-k on the big leaf (the
        # packed bucket budget equals the per-leaf budget: one slot), exact
        # (Identity) on the small leaf
        from repro.core.compression import _resolve_k
        kb = _resolve_k(DBIG, None, 0.05)   # the compressor's own fraction->k
        sims = {{"big": (TopK(k=kb), g_big), "small": (Identity(), g_small)}}
        if {pipelined}:
            st = {{n: init_pipelined_state(v) for n, v in x.items()}}
        else:
            st = {{n: init_efficient_state(v) for n, v in x.items()}}
        for t in range(STEPS):
            gk = jax.random.fold_in(k0, 100 + t)
            x, hat, s = ex(gk, x, hat, s)
            for n, (c, g) in sims.items():
                if {pipelined}:
                    q = jax.vmap(c)(jax.random.split(gk, N), st[n].x - st[n].x_hat)
                    st[n] = PipelinedGossipState(
                        x=st[n].x + g * (st[n].s - st[n].x_hat),
                        x_hat=st[n].x_hat + q, s=st[n].s + W @ q)
                else:
                    st[n] = choco_gossip_round_efficient(st[n], W, g, c)
            for n in x:
                np.testing.assert_allclose(
                    np.asarray(x[n]), np.asarray(st[n].x),
                    rtol=1e-4, atol=1e-5, err_msg=f"leaf {{n}} step {{t}}")
        print("MATCH")
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_fingerprint_compression_change_routes_elastic():
    """Satellite-1 regression: resuming with a different compression ratio
    (or packing layout) is NOT resume-exact — x_hat/s re-zero and consensus
    warmup engages; an identical config stays warmup-0; a pre-PR-6 manifest
    (keys absent) stays resume-exact."""
    run_sub("""
        import json, os, tempfile
        from repro.configs.base import ChocoConfig, get_config
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import make_optimizer, cosine_schedule
        from repro.launch.mesh import make_mesh
        from repro.checkpoint.manifest import manifest_path

        cfg = get_config("qwen3-1.7b", smoke=True)
        model = build_model(cfg)
        mesh = make_mesh((8, 1), ("data", "model"))

        def trainer(frac):
            return DecentralizedTrainer(
                model=model,
                choco=ChocoConfig(compressor="top_k",
                                  comp_kwargs=(("fraction", frac),),
                                  gossip_axis="data"),
                mesh=mesh, n_nodes=8, optimizer=make_optimizer("momentum"),
                lr_fn=cosine_schedule(0.1, warmup=10, total=100),
                mode="choco")

        ta = trainer(0.05)
        fp = ta.fingerprint()
        assert fp["compressor_config"] == {"fraction": 0.05}, fp
        assert fp["packed_gossip"] is True and fp["pipeline_gossip"] is False

        state = ta.init_state(jax.random.PRNGKey(0))
        d = tempfile.mkdtemp()
        path = os.path.join(d, "step0")
        ta.save_checkpoint(path, state)

        _, _, warm_same = ta.restore_checkpoint(path)
        assert warm_same == 0, warm_same

        tb = trainer(0.2)          # different ratio -> different omega
        st_b, _, warm_diff = tb.restore_checkpoint(path)
        assert warm_diff > 0, warm_diff
        assert float(jnp.sum(jnp.abs(
            jax.tree.leaves(st_b.x_hat)[0]))) == 0.0   # EF state re-zeroed

        # pre-PR-6 manifest: drop the new fingerprint keys -> resume-exact
        mp = manifest_path(path)
        man = json.load(open(mp))
        for k in ("compressor_config", "packed_gossip", "pack_align",
                  "pipeline_gossip"):
            man["fingerprint"].pop(k, None)
        json.dump(man, open(mp, "w"))
        _, _, warm_legacy = ta.restore_checkpoint(path)
        assert warm_legacy == 0, warm_legacy
        print("OK")
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_pipelined_collective_is_batch_independent():
    """The overlap property as a dependency fact on the compiled HLO of the
    qwen3-1.7b smoke train step (benchmarks/bench_overlap.py audit): in the
    serial engine every forward/backward dot feeds the collective-permute;
    in the pipelined engine none do — so an async backend may schedule the
    whole transfer concurrently with the backward pass.  Launch counts must
    match (pipelining adds zero collectives)."""
    out = run_sub("""
        import json
        from repro.configs.base import ChocoConfig, get_config
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import make_optimizer, cosine_schedule
        from repro.data.synthetic import make_lm_batch_fn
        from repro.launch.mesh import make_mesh
        from repro.analysis.hlo_audit import collective_dependency_audit

        cfg = get_config("qwen3-1.7b", smoke=True)
        model = build_model(cfg)
        mesh = make_mesh((8, 1), ("data", "model"))
        nb = make_lm_batch_fn(cfg, 64, 2, 8, 1.0)
        res = {}
        for pipe in (False, True):
            tr = DecentralizedTrainer(
                model=model,
                choco=ChocoConfig(compressor="top_k",
                                  comp_kwargs=(("fraction", 0.05),),
                                  gossip_axis="data", pipeline_gossip=pipe),
                mesh=mesh, n_nodes=8, optimizer=make_optimizer("momentum"),
                lr_fn=cosine_schedule(0.1, warmup=10, total=100),
                mode="choco")
            state = tr.init_state(jax.random.PRNGKey(0))
            batch = jax.tree.map(jnp.asarray, nb())
            step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                        jax.eval_shape(lambda: batch))
            hlo = step.lower(state, batch).compile().as_text()
            res["pipelined" if pipe else "serial"] = collective_dependency_audit(hlo).as_dict()
        print("AUDIT=" + json.dumps(res))
    """)
    import json
    res = json.loads([l for l in out.splitlines()
                      if l.startswith("AUDIT=")][-1][len("AUDIT="):])
    serial, pipe = res["serial"], res["pipelined"]
    assert serial["permute_launches"] == pipe["permute_launches"] > 0
    assert serial["dots_total"] == pipe["dots_total"] > 0
    assert serial["dots_feeding_collective"] == serial["dots_total"]
    assert pipe["dots_feeding_collective"] == 0


@pytest.mark.slow
@pytest.mark.distributed
def test_pipelined_trainer_end_to_end_converges():
    """Full pipelined trainer on the smoke config: loss decreases and the
    tau=1 gamma is strictly below the serial trainer's (omega folds to
    omega/2 and (W+I)/2 halves the eigengap)."""
    run_sub("""
        from repro.configs.base import ChocoConfig, get_config
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import make_optimizer, cosine_schedule
        from repro.data.synthetic import make_lm_batch_fn
        from repro.launch.mesh import make_mesh
        from repro.core.choco_gossip import GammaSpec

        cfg = get_config("qwen3-1.7b", smoke=True)
        model = build_model(cfg)
        mesh = make_mesh((8, 1), ("data", "model"))

        def trainer(pipe):
            return DecentralizedTrainer(
                model=model,
                choco=ChocoConfig(compressor="top_k",
                                  comp_kwargs=(("fraction", 0.05),),
                                  gossip_axis="data", pipeline_gossip=pipe),
                mesh=mesh, n_nodes=8, optimizer=make_optimizer("momentum"),
                lr_fn=cosine_schedule(0.1, warmup=2, total=12),
                mode="choco")

        ts, tp = trainer(False), trainer(True)
        assert tp.gamma < ts.gamma, (tp.gamma, ts.gamma)
        assert isinstance(tp.gamma_spec, GammaSpec)
        assert tp.gamma_spec.omega_scale == 0.5

        nb = make_lm_batch_fn(cfg, 64, 2, 8, 1.0)
        state = tp.init_state(jax.random.PRNGKey(0))
        batch0 = jax.tree.map(jnp.asarray, nb())
        step = tp.jitted_train_step(jax.eval_shape(lambda: state),
                                    jax.eval_shape(lambda: batch0))
        losses = []
        for _ in range(12):
            state, mets = step(state, jax.tree.map(jnp.asarray, nb()))
            losses.append(float(mets["loss"]))
        assert losses[-1] < losses[0] - 0.5, losses
        print("OK", losses[0], losses[-1])
    """)
