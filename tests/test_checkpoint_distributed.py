"""Resume-exactness and elastic restore on a simulated 8-device mesh.

Acceptance for the sharded checkpoint subsystem:
  * checkpoint at step k then resume is BIT-identical to the uninterrupted
    run on the same mesh (params, x_hat, s, optimizer moments) — the CHOCO
    error-feedback state survives restarts exactly, as Theorem 2 requires;
  * elastic restore n=4 -> n=8 runs end-to-end with the re-derived
    Theorem-2 gamma: params cyclic-tiled, x_hat/s re-zeroed, and after the
    logged consensus warmup the tiled state is no worse-mixed than a fresh
    init put through the same warmup;
  * the launcher's --resume treats --steps as the TOTAL budget (the cosine
    schedule continues from the manifest step instead of replaying from 0).
"""
import pytest

from test_distributed import run_sub

pytestmark = [pytest.mark.slow, pytest.mark.distributed]


def test_resume_bit_exact_same_mesh():
    run_sub("""
        import tempfile
        from repro.configs.base import get_config, ChocoConfig
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import momentum_sgd, cosine_schedule
        from repro.data.synthetic import make_lm_batch_fn

        mesh = jax.make_mesh((8, 1), ("data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        m = build_model(cfg)

        def make_trainer():
            # bfloat16 EF state: the bit-exact check covers the manifest's
            # uint16 bit-cast round trip, not just f32 passthrough
            return DecentralizedTrainer(model=m, choco=ChocoConfig(
                    compressor="top_k", comp_kwargs=(("fraction", 0.05),),
                    state_dtype="bfloat16"),
                mesh=mesh, n_nodes=8, optimizer=momentum_sgd(),
                lr_fn=cosine_schedule(0.1, warmup=2, total=6))

        tr = make_trainer()
        nb = make_lm_batch_fn(cfg, 32, 2, 8)
        batches = [jax.tree.map(jnp.asarray, nb()) for _ in range(6)]
        st0 = tr.init_state(jax.random.PRNGKey(0))
        shapes = (jax.eval_shape(lambda: st0), jax.eval_shape(lambda: batches[0]))
        step = tr.jitted_train_step(*shapes)

        ref = tr.init_state(jax.random.PRNGKey(0))
        for b in batches:
            ref, _ = step(ref, b)
        ref = jax.device_get(ref)

        state = st0
        for b in batches[:3]:
            state, _ = step(state, b)
        ckpt = tempfile.mkdtemp() + "/step3"
        tr.save_checkpoint(ckpt, state, metadata={"arch": cfg.name})

        tr2 = make_trainer()
        got, man, warmup = tr2.restore_checkpoint(ckpt)
        assert warmup == 0 and man.step == 3, (warmup, man.step)
        assert man.fingerprint["n_nodes"] == 8
        step2 = tr2.jitted_train_step(*shapes)
        for b in batches[3:]:
            got, _ = step2(got, b)
        got = jax.device_get(got)

        def bits(x):
            return np.asarray(x).reshape(-1).view(np.uint8)
        for name in ("params", "x_hat", "s", "opt"):
            for a, b in zip(jax.tree.leaves(getattr(ref, name)),
                            jax.tree.leaves(getattr(got, name))):
                np.testing.assert_array_equal(bits(a), bits(b), err_msg=name)
        assert int(ref.step) == int(got.step) == 6
        np.testing.assert_array_equal(np.asarray(ref.key), np.asarray(got.key))
        print("RESUME BIT-EXACT")
    """)


def test_elastic_restore_4_to_8():
    run_sub("""
        import tempfile
        from repro.configs.base import get_config, ChocoConfig
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.data.synthetic import make_lm_batch_fn

        cfg = get_config("qwen3-1.7b", smoke=True)
        m = build_model(cfg)
        choco = lambda: ChocoConfig(compressor="top_k",
                                    comp_kwargs=(("fraction", 0.05),))

        mesh4 = jax.make_mesh((4, 2), ("data", "model"))
        tr4 = DecentralizedTrainer(model=m, choco=choco(), mesh=mesh4,
                                   n_nodes=4, optimizer=sgd(),
                                   lr_fn=constant_schedule(0.05))
        nb4 = make_lm_batch_fn(cfg, 32, 2, 4)
        st = tr4.init_state(jax.random.PRNGKey(0))
        b4 = jax.tree.map(jnp.asarray, nb4())
        step4 = tr4.jitted_train_step(jax.eval_shape(lambda: st),
                                      jax.eval_shape(lambda: b4))
        for i in range(4):
            st, _ = step4(st, jax.tree.map(jnp.asarray, nb4()))
        ck = tempfile.mkdtemp() + "/step4"
        tr4.save_checkpoint(ck, st)
        old = jax.device_get(st)

        mesh8 = jax.make_mesh((8, 1), ("data", "model"))
        tr8 = DecentralizedTrainer(model=m, choco=choco(), mesh=mesh8,
                                   n_nodes=8, optimizer=sgd(),
                                   lr_fn=constant_schedule(0.05))
        # gamma re-derived from the NEW graph (ring n=8) by __post_init__
        got, man, warmup = tr8.restore_checkpoint(ck)
        assert man.n_nodes == 4 and warmup > 0, (man.n_nodes, warmup)
        assert 0 < tr8.gamma < 1 and tr8.gamma != tr4.gamma

        # cyclic tile: new node j holds old node j % 4, bit for bit
        for po, pn in zip(jax.tree.leaves(old.params),
                          jax.tree.leaves(got.params)):
            np.testing.assert_array_equal(np.asarray(pn),
                                          np.asarray(po)[np.arange(8) % 4])
        # stale public copies re-zeroed; step survives
        for l in jax.tree.leaves(got.x_hat) + jax.tree.leaves(got.s):
            assert float(jnp.sum(jnp.abs(l.astype(jnp.float32)))) == 0.0
        assert int(got.step) == 4

        def cerr(state):
            rows = jnp.concatenate(
                [jnp.reshape(l, (8, -1)).astype(jnp.float32)
                 for l in jax.tree.leaves(state.params)], axis=1)
            mu = jnp.mean(rows, 0, keepdims=True)
            return float(jnp.mean(jnp.sum((rows - mu) ** 2, -1)))

        fresh = tr8.init_state(jax.random.PRNGKey(1))
        e_fresh0 = cerr(fresh)
        warmed = tr8.consensus_warmup(got, warmup)
        e_warm = cerr(warmed)
        warmed_fresh = tr8.consensus_warmup(fresh, warmup)
        e_fresh = cerr(warmed_fresh)
        print("consensus err after warmup: elastic", e_warm,
              "fresh", e_fresh, "(fresh pre-warmup", e_fresh0, ")")
        # acceptance: contraction no worse than fresh init after the warmup
        assert e_warm <= e_fresh + 1e-6, (e_warm, e_fresh)

        # end-to-end: training continues under the new mesh / gamma
        nb8 = make_lm_batch_fn(cfg, 32, 2, 8)
        b8 = jax.tree.map(jnp.asarray, nb8())
        step8 = tr8.jitted_train_step(jax.eval_shape(lambda: warmed),
                                      jax.eval_shape(lambda: b8))
        s8 = warmed
        for i in range(3):
            s8, mets = step8(s8, jax.tree.map(jnp.asarray, nb8()))
        assert np.isfinite(float(mets["loss"]))
        assert int(s8.step) == 7
        print("ELASTIC 4->8 OK")
    """)


def test_launcher_resume_total_steps():
    """--steps is the TOTAL budget: a resumed run trains steps-resumed more
    steps with the cosine schedule anchored at the manifest step (the
    pre-fix launcher re-ran the full --steps at terminal LR); an exhausted
    budget fails fast."""
    run_sub("""
        import os, tempfile
        from repro.launch.train import main
        from repro.checkpoint.manifest import read_manifest

        d = tempfile.mkdtemp()
        base = ["--arch", "qwen3-1.7b", "--smoke", "--mesh", "8x1",
                "--simulate-devices", "8", "--seq-len", "32",
                "--batch-per-node", "2", "--compressor", "top_k",
                "--fraction", "0.05", "--optimizer", "sgd", "--lr", "0.05",
                "--checkpoint-dir", d, "--checkpoint-every", "2"]
        assert main(base + ["--steps", "4"]) == 0
        ck4 = os.path.join(d, "step4")
        assert read_manifest(ck4).step == 4

        # resume with TOTAL budget 6 -> exactly 2 more steps, lands on 6
        assert main(base + ["--steps", "6", "--resume", ck4]) == 0
        assert read_manifest(os.path.join(d, "step6")).step == 6

        # budget already consumed: fail fast instead of terminal-LR retrain
        try:
            main(base + ["--steps", "4", "--resume", ck4])
            raise AssertionError("expected SystemExit")
        except SystemExit as e:
            assert "TOTAL step budget" in str(e), e
        print("CLI RESUME OK")
    """)
