"""Optional `hypothesis` import shim.

CI installs hypothesis (requirements-test.txt); bare environments may not
have it.  Property-based tests decorated with the stub `given` are skipped,
while plain parametrized tests in the same module still collect and run.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*args, **kwargs):
        del args, kwargs
        return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)

    def settings(*args, **kwargs):
        del args, kwargs
        return lambda fn: fn

    class _Strategies:
        """Stand-in for hypothesis.strategies: every strategy builder returns
        None (never evaluated — the test is skipped before being called)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
