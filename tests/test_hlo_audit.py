"""Parser-regression tests for the shared IR auditors (fast tier).

The HLO/jaxpr parsers in ``repro.analysis.hlo_audit`` /
``repro.analysis.jaxpr_audit`` back every structural claim the benchmarks
and invariant suite make (permute launches, wire-gating matmuls, HBM
streams, pallas launches).  These tests feed them HAND-WRITTEN fixtures —
fusion-nested permutes, async start/done pairs, while-loop callees,
int16/bf16 stream lines, duck-typed nested jaxprs — so a parser regression
is caught without compiling anything or touching a device.
"""
import textwrap

from repro.analysis.hlo_audit import (STREAM_THRESHOLD,
                                      collective_dependency_audit,
                                      count_dots, count_permute_launches,
                                      entry_stream_audit, hlo_computations)
from repro.analysis.jaxpr_audit import count_pallas_calls, count_primitive

import pytest

# --------------------------------------------------------------------------
# fixtures
# --------------------------------------------------------------------------

#: a permute hidden inside a fusion computation, plus a while loop whose
#: body carries the only dot — exercises computation splitting, callee
#: descent (body=/condition=/calls=), and entry-only counting
FUSION_NESTED = textwrap.dedent("""\
    HloModule fusion_nested

    %fused_comp (fp0: f32[128,128]) -> f32[128,128] {
      %fp0 = f32[128,128]{1,0} parameter(0)
      ROOT %cp = f32[128,128]{1,0} collective-permute(f32[128,128]{1,0} %fp0), source_target_pairs={{0,1},{1,0}}
    }

    %while_body (warg: f32[128,128]) -> f32[128,128] {
      %warg = f32[128,128]{1,0} parameter(0)
      ROOT %dot.body = f32[128,128]{1,0} dot(f32[128,128]{1,0} %warg, f32[128,128]{1,0} %warg), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }

    %while_cond (carg: f32[128,128]) -> pred[] {
      %carg = f32[128,128]{1,0} parameter(0)
      ROOT %lt = pred[] constant(true)
    }

    ENTRY %main (p: f32[128,128]) -> f32[128,128] {
      %p = f32[128,128]{1,0} parameter(0)
      %w = f32[128,128]{1,0} while(f32[128,128]{1,0} %p), condition=%while_cond, body=%while_body
      %fus = f32[128,128]{1,0} fusion(f32[128,128]{1,0} %w), kind=kCustom, calls=%fused_comp
      ROOT %out = f32[128,128]{1,0} add(f32[128,128]{1,0} %fus, f32[128,128]{1,0} %w)
    }
    """)

#: one entry-level permute fed by a fusion whose callee holds a dot, plus
#: an independent dot that must NOT land in the operand closure
DEPENDENCY = textwrap.dedent("""\
    HloModule dependency

    %layers (a: f32[64,64]) -> f32[64,64] {
      %a = f32[64,64]{1,0} parameter(0)
      ROOT %dot.inner = f32[64,64]{1,0} dot(f32[64,64]{1,0} %a, f32[64,64]{1,0} %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }

    ENTRY %step (x: f32[64,64], y: f32[64,64]) -> f32[64,64] {
      %x = f32[64,64]{1,0} parameter(0)
      %y = f32[64,64]{1,0} parameter(1)
      %dot.free = f32[64,64]{1,0} dot(f32[64,64]{1,0} %y, f32[64,64]{1,0} %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %h = f32[64,64]{1,0} fusion(f32[64,64]{1,0} %x), kind=kLoop, calls=%layers
      %q = f32[64,64]{1,0} add(f32[64,64]{1,0} %h, f32[64,64]{1,0} %x)
      %cp.1 = f32[64,64]{1,0} collective-permute(f32[64,64]{1,0} %q), source_target_pairs={{0,1},{1,0}}
      ROOT %r = f32[64,64]{1,0} add(f32[64,64]{1,0} %cp.1, f32[64,64]{1,0} %dot.free)
    }
    """)

#: an async start/done pair — one launch, not two
ASYNC_PAIR = textwrap.dedent("""\
    HloModule async_pair

    ENTRY %async (p: f32[32]) -> f32[32] {
      %p = f32[32]{0} parameter(0)
      %cps = (f32[32]{0}, f32[32]{0}) collective-permute-start(f32[32]{0} %p), source_target_pairs={{0,1}}
      ROOT %cpd = f32[32]{0} collective-permute-done((f32[32]{0}, f32[32]{0}) %cps)
    }
    """)

#: full-size f32 / bf16 / s16 stream lines above and below the threshold,
#: plus the plumbing (parameters, get-tuple-element, ROOT tuple) that must
#: never count
STREAMS = textwrap.dedent("""\
    HloModule streams

    ENTRY %main (p0: f32[16384], p1: bf16[32768]) -> (f32[16384]) {
      %p0 = f32[16384]{0} parameter(0)
      %p1 = bf16[32768]{0} parameter(1)
      %a = f32[16384]{0} add(f32[16384]{0} %p0, f32[16384]{0} %p0)
      %c = bf16[32768]{0} convert(f32[16384]{0} %a)
      %d = s16[16384]{0} convert(f32[16384]{0} %a)
      %small = f32[128]{0} slice(f32[16384]{0} %a), slice={[0:128]}
      %g = f32[16384]{0} get-tuple-element((f32[16384]{0}) %t), index=0
      ROOT %tuple.9 = (f32[16384]{0}) tuple(f32[16384]{0} %a)
    }
    """)


# --------------------------------------------------------------------------
# hlo_computations / permute counting
# --------------------------------------------------------------------------

def test_computation_split_keys_entry_twice():
    comps = hlo_computations(FUSION_NESTED)
    assert "__entry__" in comps and "main" in comps
    assert comps["__entry__"] is comps["main"]
    assert set(comps) >= {"fused_comp", "while_body", "while_cond"}


def test_fusion_nested_permute_counts_whole_module_not_entry():
    assert count_permute_launches(FUSION_NESTED) == 1
    assert count_permute_launches(FUSION_NESTED, entry_only=True) == 0


def test_async_start_done_pair_counts_once():
    assert count_permute_launches(ASYNC_PAIR) == 1
    assert count_permute_launches(ASYNC_PAIR, entry_only=True) == 1


def test_count_dots_descends_into_while_callees():
    comps = hlo_computations(FUSION_NESTED)
    # the only dot lives in the while body, reached via body=%while_body
    assert count_dots(comps, "__entry__") == 1
    assert count_dots(comps, "while_body") == 1
    assert count_dots(comps, "fused_comp") == 0


# --------------------------------------------------------------------------
# collective_dependency_audit
# --------------------------------------------------------------------------

def test_dependency_audit_separates_feeding_from_free_dots():
    audit = collective_dependency_audit(DEPENDENCY)
    assert audit.permute_launches == 1
    assert audit.dots_total == 2          # dot.free + layers' dot.inner
    # only the fusion on the permute's operand path gates the wire
    assert audit.dots_feeding_collective == 1
    assert audit.as_dict() == {"permute_launches": 1, "dots_total": 2,
                               "dots_feeding_collective": 1}


def test_dependency_audit_zero_when_no_permute_in_entry():
    audit = collective_dependency_audit(FUSION_NESTED)
    # the permute is fusion-nested, not an entry def: nothing to gate
    assert audit.permute_launches == 0
    assert audit.dots_feeding_collective == 0
    assert audit.dots_total == 1


# --------------------------------------------------------------------------
# entry_stream_audit
# --------------------------------------------------------------------------

def test_stream_audit_default_f32_only():
    rec = entry_stream_audit(STREAMS)
    # %a: 1 write + 2 reads; %c and %d: their f32 operand is the line's
    # FIRST f32 match, so it counts as the write slot (documented quirky
    # semantics, load-bearing for BENCH_fused.json bit-reproducibility);
    # %small's def is sub-threshold but its operand read is full-size;
    # %g / parameters / ROOT tuple skipped.
    assert rec == {"streams": 6, "reads": 3, "writes": 3,
                   "bytes": 6 * 16384 * 4}


def test_stream_audit_sees_bf16_and_s16_when_asked():
    rec = entry_stream_audit(STREAMS, dtypes=("f32", "bf16", "s16"))
    # vs the f32 audit: %c now writes bf16[32768] and reads f32[16384];
    # %d writes s16[16384] and reads f32[16384]
    assert rec["writes"] == 3 and rec["reads"] == 5
    assert rec["streams"] == 8
    assert rec["bytes"] == (16384 * 4 * 6       # the six f32 streams
                            + 32768 * 2         # bf16 write
                            + 16384 * 2)        # s16 write


def test_stream_audit_threshold_is_inclusive():
    rec = entry_stream_audit(STREAMS, threshold=STREAM_THRESHOLD + 1)
    # only the bf16 line is above 16384 elements, and it's dtype-filtered
    assert rec == {"streams": 0, "reads": 0, "writes": 0, "bytes": 0}


def test_stream_audit_rejects_unknown_dtype():
    with pytest.raises(ValueError, match="f4"):
        entry_stream_audit(STREAMS, dtypes=("f4",))


# --------------------------------------------------------------------------
# jaxpr audit (duck-typed — no jax import needed)
# --------------------------------------------------------------------------

class _Prim:
    def __init__(self, name):
        self.name = name


class _Eqn:
    def __init__(self, name, params=None):
        self.primitive = _Prim(name)
        self.params = params or {}


class _Jaxpr:
    def __init__(self, eqns):
        self.eqns = eqns


class _Closed:
    def __init__(self, jaxpr):
        self.jaxpr = jaxpr


def test_count_pallas_calls_recurses_through_nested_params():
    inner = _Jaxpr([_Eqn("pallas_call"), _Eqn("add")])
    # nested as: raw jaxpr, ClosedJaxpr-ish wrapper, and a list of both —
    # the three shapes scan/cond/pjit params actually take
    outer = _Jaxpr([
        _Eqn("pallas_call"),
        _Eqn("scan", {"jaxpr": _Closed(inner)}),
        _Eqn("cond", {"branches": [_Closed(inner), inner]}),
        _Eqn("mul", {"irrelevant": 7}),
    ])
    assert count_pallas_calls(outer) == 1 + 1 + 2


def test_count_primitive_counts_other_primitives_too():
    inner = _Jaxpr([_Eqn("ppermute")])
    outer = _Jaxpr([_Eqn("ppermute"), _Eqn("pjit", {"jaxpr": inner})])
    assert count_primitive(outer, "ppermute") == 2
    assert count_primitive(outer, "pallas_call") == 0
