"""Regression tests for the §Perf optimizations (EXPERIMENTS.md):
chunked attention, bf16 error-feedback state, GQA-native decode, seq-parallel
KV layout, exact_small_leaves, torus gossip, int8 qsgd wire."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str, timeout=420):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("arch", [
    "yi-9b",
    # fwd+bwd through the chunked scan is 15-25s each on the bigger
    # configs — slow tier; yi-9b keeps the parity check in the fast tier
    pytest.param("gemma2-9b", marks=pytest.mark.slow),
    pytest.param("qwen3-moe-30b-a3b", marks=pytest.mark.slow),
])
def test_chunked_attention_matches_naive(arch):
    """attn_impl=chunked (flash-style scan) == naive attention, fwd + bwd."""
    cfg = get_config(arch, smoke=True)
    cfg_c = dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=8)
    m1, m2 = build_model(cfg), build_model(cfg_c)
    params = m1.init(KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    l1, _ = jax.jit(m1.loss)(params, batch)
    l2, _ = jax.jit(m2.loss)(params, batch)
    assert abs(float(l1) - float(l2)) < 0.02, arch
    g1 = jax.grad(lambda p: m1.loss(p, batch)[0])(params)
    g2 = jax.grad(lambda p: m2.loss(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=0.05, rtol=0.1)


def test_qsgd_int8_wire_format():
    from repro.core.compression import QSGD
    pl = QSGD(16).compress(KEY, jax.random.normal(KEY, (256,)))
    assert pl.codes.dtype == jnp.int8
    pl = QSGD(256).compress(KEY, jax.random.normal(KEY, (256,)))
    assert pl.codes.dtype == jnp.int16


def test_chunked_leaf_compression_matches_direct():
    """Row-block compression (huge-leaf path) preserves the contraction."""
    from repro.comm.gossip import _compress_leaf, BLOCK_COMPRESS_SIZE
    from repro.core.compression import TopK
    d = BLOCK_COMPRESS_SIZE + 12345        # forces the chunked path
    x = jax.random.normal(KEY, (d,))
    comp = TopK(fraction=0.01)
    pl, dfn = _compress_leaf(comp, None, x)
    q = dfn(pl)
    assert q.shape == x.shape
    err = float(jnp.sum((q - x) ** 2))
    assert err <= (1 - comp.omega(d)) * float(jnp.sum(x * x)) * 1.01
    # per-row k: the padded tail row keeps all its real coords (they beat the
    # zero padding), so the bound is k_per_row * n_rows
    nnz = int(jnp.sum(q != 0))
    k_per_row = -(-BLOCK_COMPRESS_SIZE // 100)
    assert 0 < nnz <= 2 * k_per_row


@pytest.mark.slow
@pytest.mark.distributed
def test_bf16_ef_state_trainer():
    run_sub("""
        from repro.configs.base import get_config, ChocoConfig
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.data.synthetic import make_lm_batch_fn

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("yi-9b", smoke=True)
        m = build_model(cfg)
        tr = DecentralizedTrainer(model=m,
            choco=ChocoConfig(state_dtype="bfloat16"), mesh=mesh, n_nodes=4,
            optimizer=sgd(), lr_fn=constant_schedule(0.05))
        state = tr.init_state(jax.random.PRNGKey(0))
        assert jax.tree.leaves(state.x_hat)[0].dtype == jnp.bfloat16
        nb = make_lm_batch_fn(cfg, 32, 4, 4)
        b = jax.tree.map(jnp.asarray, nb())
        step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                    jax.eval_shape(lambda: b))
        losses = []
        for i in range(15):
            state, mets = step(state, jax.tree.map(jnp.asarray, nb()))
            losses.append(float(mets["loss"]))
        assert all(np.isfinite(losses))
        assert losses[-1] < losses[0]
        print("BF16 STATE OK", losses[0], "->", losses[-1])
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_torus_gossip_trainer():
    run_sub("""
        from repro.configs.base import get_config, ChocoConfig
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.data.synthetic import make_lm_batch_fn

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        m = build_model(cfg)
        tr = DecentralizedTrainer(model=m,
            choco=ChocoConfig(topology="torus"), mesh=mesh, n_nodes=4,
            optimizer=sgd(), lr_fn=constant_schedule(0.05))
        assert tr.torus and tr.gossip_axis == ("pod", "data")
        state = tr.init_state(jax.random.PRNGKey(0))
        nb = make_lm_batch_fn(cfg, 32, 4, 4)
        b = jax.tree.map(jnp.asarray, nb())
        step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                    jax.eval_shape(lambda: b))
        losses = []
        for i in range(10):
            state, mets = step(state, jax.tree.map(jnp.asarray, nb()))
            losses.append(float(mets["loss"]))
        assert all(np.isfinite(losses)) and losses[-1] < losses[0]
        print("TORUS OK")
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_exact_small_leaves_ships_dense():
    run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.core import TopK
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        x = {"big": jax.random.normal(jax.random.PRNGKey(0), (4, 4096)),
             "small": jax.random.normal(jax.random.PRNGKey(1), (4, 16))}
        zeros = jax.tree.map(jnp.zeros_like, x)
        ex = make_gossip_exchange(mode="choco", mesh=mesh,
                                  state_specs={"big": P("data", None),
                                               "small": P("data", None)},
                                  axis="data", compressor=TopK(fraction=0.01),
                                  gamma=0.1, exact_small_leaves=True,
                                  small_leaf_threshold=64)
        xn, xh, s = ex(jax.random.PRNGKey(0), x, zeros, jax.tree.map(jnp.zeros_like, x))
        # small leaf shipped exactly: x_hat == x after one round
        np.testing.assert_allclose(np.asarray(xh["small"]), np.asarray(x["small"]),
                                   rtol=1e-6)
        # big leaf compressed: x_hat sparse
        nnz = int(jnp.sum(xh["big"] != 0))
        assert nnz < x["big"].size * 0.05
        print("SMALL LEAVES OK")
    """)


def test_decode_gqa_native_uniform_positions():
    """Scalar-position cache write: all batch rows share the decode slot."""
    cfg = get_config("yi-9b", smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    B, s = 3, 10
    toks = jax.random.randint(KEY, (B, s), 0, cfg.vocab_size)
    cache = m.init_cache(B, s)
    dec = jax.jit(m.decode_step)
    for t in range(s):
        lg, cache = dec(params, toks[:, t:t + 1], cache, jnp.full((B,), t, jnp.int32))
    logits_pre, _ = jax.jit(m.prefill)(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(logits_pre, np.float32),
                               atol=0.05, rtol=0.05)
