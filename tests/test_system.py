"""End-to-end behaviour tests for the paper's system: the full CHOCO-SGD
pipeline reproduces the paper's qualitative claims on logistic regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ring, TopK, QSGD, Identity, run_choco_sgd,
                        experiment_lr_schedule, run_choco_gossip,
                        run_gossip_baseline)
from repro.data.synthetic import make_logreg


@pytest.fixture(scope="module")
def logreg():
    return make_logreg("epsilon", n_nodes=9, sorted_assignment=True,
                       m=1152, d=128, seed=3)


def _run(problem, comp, gamma, steps=1500, seed=0):
    grad_fn = problem.make_grad_fn(batch_size=4)
    lr = experiment_lr_schedule(1, 300.0, 300.0)
    x0 = jnp.zeros((9, problem.d))
    W = jnp.asarray(ring(9).W)
    _, trace = run_choco_sgd(x0, W, grad_fn, comp, lr, gamma, steps,
                             key=jax.random.PRNGKey(seed),
                             eval_fn=problem.full_loss)
    return np.asarray(trace)


def test_choco_sgd_with_1pct_compression_tracks_exact(logreg):
    """Paper Fig 5: CHOCO top-k performs close to exact Algorithm 3 in
    iterations while sending ~1-10% of the bits."""
    exact = _run(logreg, Identity(), 1.0)
    choco = _run(logreg, TopK(fraction=0.1), 0.2)
    assert choco[-1] < exact[-1] + 0.02          # tracks exact communication
    assert choco[-1] < choco[0] - 0.2            # and actually optimises


def test_choco_sgd_qsgd_quantization(logreg):
    choco = _run(logreg, QSGD(16), 0.5)
    assert np.isfinite(choco).all()
    assert choco[-1] < choco[0] - 0.2


def test_transmitted_bits_accounting(logreg):
    """CHOCO rand/top-1% transmits ~2 orders of magnitude fewer bits per
    round than exact gossip (the paper's headline claim)."""
    d = 10_000
    exact_bits = Identity().wire_bits(d)
    topk_bits = TopK(fraction=0.01).wire_bits(d)
    assert exact_bits / topk_bits >= 50


def test_consensus_figure2_ordering():
    """Fig 2: CHOCO(qsgd) converges linearly; Q1/Q2 plateau above it."""
    n, d = 25, 200
    topo = ring(n)
    W = jnp.asarray(topo.W)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    _, e_choco = run_choco_gossip(x0, W, 1.0, QSGD(256), 600)
    _, e_q1 = run_gossip_baseline("q1", x0, W, QSGD(256, rescale=False), 600)
    _, e_q2 = run_gossip_baseline("q2", x0, W, QSGD(256, rescale=False), 600)
    assert e_choco[-1] < e_q1[-1] / 100
    assert e_choco[-1] < e_q2[-1] / 100


def test_heterogeneous_beats_isolated_training(logreg):
    """Sorted data: a node sees one label only; without communication the
    global loss stalls — CHOCO-SGD with 90% sparsification still solves it."""
    choco = _run(logreg, TopK(fraction=0.1), 0.2)
    grad_fn = logreg.make_grad_fn(batch_size=4)
    lr = experiment_lr_schedule(1, 300.0, 300.0)
    _, iso = run_choco_sgd(jnp.zeros((9, logreg.d)), jnp.eye(9), grad_fn,
                           Identity(), lr, 1.0, 1500, eval_fn=logreg.full_loss)
    assert choco[-1] < float(iso[-1]) - 0.005
