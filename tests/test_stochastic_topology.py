"""Stochastic topology subsystem: randomized matchings, link failures, and
directed push-sum (comm/stochastic.py, comm/pushsum.py).

Fast tier: process construction + expected-W algebra + seed determinism +
matrix-simulator convergence + fail-fast wiring.  The distributed
engine == simulator equivalence tests live at the bottom under the standard
``slow``/``distributed`` markers (subprocess with 8 simulated host devices),
so the fast inner loop (-m "not slow") never compiles shard_map graphs.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.topology import (DirectedTopology, beta_norm, directed_ring,
                                 is_directed, make_topology, random_digraph,
                                 ring, spectral_gap)
from repro.core.compression import Identity, TopK
from repro.core.choco_gossip import (init_pushsum_state, pushsum_debias,
                                     pushsum_gossip_round, run_pushsum_gossip)
from repro.comm.schedule import compile_directed_schedule, compile_schedule
from repro.comm.stochastic import (LinkFailureProcess, MatchingProcess,
                                   SAMPLE_SALT, choco_process_round,
                                   init_process_state, make_topology_process,
                                   run_choco_gossip_process)

from optional_hypothesis import HAVE_HYPOTHESIS, given, settings, st

TOPOS = ["ring", "hypercube", "star", "chain", "torus", "fully_connected"]


def _sched(name, n=8):
    return compile_schedule(make_topology(name, n))


# ---------------------------------------------------------------------------
# directed topologies + directed schedule compiler
# ---------------------------------------------------------------------------

class TestDirectedTopology:
    @pytest.mark.parametrize("n", [1, 2, 4, 8])
    def test_directed_ring_column_stochastic(self, n):
        topo = directed_ring(n)
        A = topo.A
        np.testing.assert_allclose(A.sum(0), 1.0, atol=1e-12)
        assert np.all(A >= 0)
        if n > 2:
            assert not np.allclose(A, A.T), "directed ring must be asymmetric"

    @pytest.mark.parametrize("seed", [0, 3, 7])
    def test_random_digraph_column_stochastic_connected(self, seed):
        topo = random_digraph(8, 0.3, seed=seed)
        np.testing.assert_allclose(topo.A.sum(0), 1.0, atol=1e-12)
        # ring backbone guarantees strong connectivity -> positive gap
        assert 0.0 < topo.delta <= 1.0

    def test_directed_names_registered(self):
        for name in ("directed_ring", "random_digraph"):
            assert is_directed(name)
            assert isinstance(make_topology(name, 8), DirectedTopology)
        assert not is_directed("ring")

    @pytest.mark.parametrize("topo_fn", [
        lambda: directed_ring(8),
        lambda: random_digraph(8, 0.4, seed=1),
        lambda: random_digraph(6, 0.7, seed=2),
    ])
    def test_directed_schedule_reconstructs_A(self, topo_fn):
        topo = topo_fn()
        sched = compile_directed_schedule(topo)
        np.testing.assert_allclose(sched.mixing_matrix(), topo.A, atol=1e-12)
        # every round is a partial permutation: distinct srcs, distinct dsts
        for rnd in sched.rounds:
            srcs = [s for s, _ in rnd.perm]
            dsts = [d for _, d in rnd.perm]
            assert len(set(srcs)) == len(srcs)
            assert len(set(dsts)) == len(dsts)

    def test_symmetric_compiler_rejects_directed_W(self):
        topo = directed_ring(8)
        fake = make_topology("ring", 8)
        with pytest.raises(ValueError, match="push-sum"):
            compile_schedule(
                type(fake)("directed", topo.A, fake.neighbors))


# ---------------------------------------------------------------------------
# matching process
# ---------------------------------------------------------------------------

class TestMatchingProcess:
    @pytest.mark.parametrize("name", TOPOS)
    @pytest.mark.parametrize("sampler", ["uniform", "weighted"])
    def test_expected_matrix_equals_static_W(self, name, sampler):
        """Tentpole algebra: sum_r p_r W_r == W exactly (the rounds
        partition W's off-diagonal mass and scaling by 1/p_r cancels)."""
        topo = make_topology(name, 8)
        proc = MatchingProcess(compile_schedule(topo), sampler=sampler)
        np.testing.assert_allclose(proc.expected_matrix(), topo.W,
                                   atol=1e-12)

    @pytest.mark.parametrize("name", TOPOS)
    def test_branch_matrices_are_doubly_stochastic(self, name):
        proc = MatchingProcess(_sched(name))
        for M in proc.branch_matrices():
            np.testing.assert_allclose(M.sum(1), 1.0, atol=1e-12)
            np.testing.assert_allclose(M.sum(0), 1.0, atol=1e-12)
            assert M.min() >= -1e-12

    def test_empirical_round_frequencies_match_probs(self):
        proc = MatchingProcess(_sched("star"), sampler="weighted")
        key = jax.random.PRNGKey(0)
        idx = np.asarray([int(proc.round_index(jax.random.fold_in(key, i), 0))
                          for i in range(2000)])
        freq = np.bincount(idx, minlength=proc.n_rounds) / len(idx)
        np.testing.assert_allclose(freq, proc.probs, atol=0.05)

    def test_unknown_sampler_rejected(self):
        with pytest.raises(ValueError, match="sampler"):
            MatchingProcess(_sched("ring"), sampler="zipf")

    def test_single_node_schedule_rejected(self):
        topo = make_topology("ring", 1)
        with pytest.raises(ValueError, match="at least one round"):
            MatchingProcess(compile_schedule(topo))


class TestLinkFailureProcess:
    def test_drop_prob_validation(self):
        with pytest.raises(ValueError, match="drop_prob"):
            LinkFailureProcess(_sched("ring"), drop_prob=1.0)
        with pytest.raises(ValueError, match="drop_prob"):
            LinkFailureProcess(_sched("ring"), drop_prob=-0.1)

    @pytest.mark.parametrize("name", TOPOS)
    def test_sampled_matrix_row_stochastic_symmetric(self, name):
        topo = make_topology(name, 8)
        proc = LinkFailureProcess(compile_schedule(topo), drop_prob=0.4)
        for i in range(5):
            W = np.asarray(proc.sample_matrix(jax.random.PRNGKey(i), 0))
            np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-6)
            np.testing.assert_allclose(W, W.T, atol=1e-6)
            assert W.min() >= -1e-6

    def test_p_zero_is_static_W(self):
        topo = make_topology("hypercube", 8)
        proc = LinkFailureProcess(compile_schedule(topo), drop_prob=0.0)
        W = np.asarray(proc.sample_matrix(jax.random.PRNGKey(0), 0))
        np.testing.assert_allclose(W, topo.W, atol=1e-6)

    def test_expected_matrix_interpolates_to_identity(self):
        topo = make_topology("ring", 8)
        p = 0.3
        proc = LinkFailureProcess(compile_schedule(topo), drop_prob=p)
        np.testing.assert_allclose(
            proc.expected_matrix(), (1 - p) * topo.W + p * np.eye(8),
            atol=1e-12)
        delta, beta = proc.expected_delta_beta()
        assert delta == pytest.approx((1 - p) * spectral_gap(topo.W),
                                      abs=1e-9)
        assert beta == pytest.approx((1 - p) * beta_norm(topo.W), abs=1e-9)

    def test_registry(self):
        sched = _sched("ring")
        assert make_topology_process("matching", sched).kind == "matching"
        assert make_topology_process(
            "linkfail", sched, edge_drop_prob=0.2).drop_prob == 0.2
        with pytest.raises(ValueError, match="unknown topology process"):
            make_topology_process("quantum", sched)


# ---------------------------------------------------------------------------
# seed reproducibility: the no-communication determinism contract
# ---------------------------------------------------------------------------

class TestSeedReproducibility:
    def test_round_index_pure_function_of_key(self):
        """Two independently-built identical processes, eager and jitted,
        sample the same round sequence — this is what lets every node (and
        every engine: packed / per-leaf / plain / simulator) agree on the
        sampled round with zero communication."""
        p1 = MatchingProcess(_sched("hypercube"))
        p2 = MatchingProcess(_sched("hypercube"))
        jit_idx = jax.jit(lambda k, t: p1.round_index(k, t),
                          static_argnums=1)
        key = jax.random.PRNGKey(42)
        for step in range(20):
            ek = jax.random.fold_in(key, step)
            for t in range(3):
                a = int(p1.round_index(ek, t))
                assert a == int(p2.round_index(ek, t))
                assert a == int(jit_idx(ek, t))

    def test_round_sequence_varies_over_steps(self):
        proc = MatchingProcess(_sched("hypercube"))
        key = jax.random.PRNGKey(0)
        idx = {int(proc.round_index(jax.random.fold_in(key, i), 0))
               for i in range(50)}
        assert len(idx) > 1, "sampler is stuck on one round"

    def test_edge_mask_deterministic_and_salted(self):
        proc = LinkFailureProcess(_sched("torus"), drop_prob=0.5)
        key = jax.random.PRNGKey(7)
        m1 = np.asarray(proc.edge_mask(key, 0))
        m2 = np.asarray(proc.edge_mask(key, 0))
        np.testing.assert_array_equal(m1, m2)
        # the in-step round index t enters the fold salt: with 12+ edges at
        # p = 0.5 a colliding draw has probability 2^-12
        masks = np.stack([np.asarray(proc.edge_mask(key, t))
                          for t in range(4)])
        assert (masks != masks[0]).any()

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), t=st.integers(0, 7))
    def test_sampling_reproducible_property(self, seed, t):
        proc = MatchingProcess(_sched("star"))
        lf = LinkFailureProcess(_sched("star"), drop_prob=0.3)
        key = jax.random.PRNGKey(seed)
        assert int(proc.round_index(key, t)) == int(proc.round_index(key, t))
        np.testing.assert_array_equal(np.asarray(lf.edge_mask(key, t)),
                                      np.asarray(lf.edge_mask(key, t)))
        # the sample fold is salted away from the raw key stream
        assert SAMPLE_SALT > 0


# ---------------------------------------------------------------------------
# matrix-simulator convergence (the sound replica algorithm)
# ---------------------------------------------------------------------------

class TestProcessSimulator:
    # 250 sampled rounds x 8 graph/process combos ~= 2.5 min: slow tier
    # (fast-tier mixing signal stays via test_matching_beats_nothing_baseline)
    @pytest.mark.slow
    @pytest.mark.parametrize("name", ["ring", "hypercube", "star", "torus"])
    @pytest.mark.parametrize("kind", ["matching", "linkfail"])
    def test_consensus_converges(self, name, kind, key):
        proc = make_topology_process(kind, _sched(name),
                                     edge_drop_prob=0.3)
        x0 = jax.random.normal(key, (8, 32))
        gamma = 0.4 if kind == "matching" else 0.3
        _, errs = run_choco_gossip_process(x0, proc, gamma, TopK(k=8), 250)
        assert float(errs[-1]) < 1e-4 * float(errs[0]), (
            f"{name}/{kind}: {float(errs[0])} -> {float(errs[-1])}")

    def test_average_preserved_exactly(self, key):
        """Every sampled update moves mass along doubly-stochastic rows:
        the node average is invariant step by step."""
        proc = MatchingProcess(_sched("hypercube"))
        x0 = jax.random.normal(key, (8, 16))
        xbar0 = np.asarray(jnp.mean(x0, 0))
        st = init_process_state(x0, proc)
        for i in range(40):
            st = choco_process_round(st, proc, 0.4, TopK(k=4),
                                     jax.random.PRNGKey(i))
        np.testing.assert_allclose(np.asarray(jnp.mean(st.x, 0)), xbar0,
                                   atol=1e-5)

    def test_matching_beats_nothing_baseline(self, key):
        """Sanity: sampling one round per step still mixes (vs zero rounds)."""
        proc = MatchingProcess(_sched("ring"))
        x0 = jax.random.normal(key, (8, 32))
        _, errs = run_choco_gossip_process(x0, proc, 0.4, Identity(), 150)
        assert float(errs[-1]) < 0.05 * float(errs[0])

    # 150 sampled Algorithm-4 rounds per kind ~= 18s: slow tier
    @pytest.mark.slow
    @pytest.mark.parametrize("kind", ["matching", "linkfail"])
    def test_blackbox_averaging_scheme_contracts(self, kind, key):
        """Algorithm-4 composition point (core/consensus.py): the stochastic
        process plugs in as an AveragingScheme whose auxiliary Y carries the
        reference state; average preserved, consensus contracts."""
        from repro.core import stochastic_choco_averaging
        proc = make_topology_process(kind, _sched("hypercube"),
                                     edge_drop_prob=0.2)
        sch = stochastic_choco_averaging(proc, TopK(k=8), 32, gamma=0.35)
        assert 0.0 < sch.p < 1.0
        x0 = jax.random.normal(key, (8, 32))
        xbar = np.asarray(jnp.mean(x0, 0))
        X, Y = x0, init_process_state(x0, proc).refs
        for i in range(150):
            X, Y = sch.h(X, Y, jax.random.PRNGKey(i))
        np.testing.assert_allclose(np.asarray(jnp.mean(X, 0)), xbar,
                                   atol=1e-5)
        err = float(jnp.mean(jnp.sum((X - xbar) ** 2, -1)))
        assert err < 1e-5


# ---------------------------------------------------------------------------
# push-sum simulator
# ---------------------------------------------------------------------------

class TestPushSum:
    @pytest.mark.parametrize("topo_fn,gamma", [
        (lambda: directed_ring(8), 0.5),
        (lambda: random_digraph(8, 0.4, seed=1), 0.5),
    ])
    def test_compressed_pushsum_converges_to_average(self, topo_fn, gamma,
                                                     key):
        topo = topo_fn()
        x0 = jax.random.normal(key, (8, 32))
        A = jnp.asarray(topo.A)
        final, errs = run_pushsum_gossip(x0, A, gamma, TopK(k=16), 400)
        assert float(errs[-1]) < 1e-6, float(errs[-1])
        # weight mass is conserved: 1^T w = n exactly (column-stochastic A)
        assert float(jnp.sum(final.w)) == pytest.approx(8.0, abs=1e-4)

    def test_identity_compressor_is_lazy_pushsum(self, key):
        """With Q = identity the recursion collapses to
        x' = ((1-g) I + g A) x — verify against the closed form."""
        topo = random_digraph(8, 0.5, seed=3)
        A = jnp.asarray(topo.A)
        g = 0.7
        x0 = jax.random.normal(key, (8, 8))
        st = init_pushsum_state(x0)
        x_ref, w_ref = x0, jnp.ones((8, 1))
        M = (1 - g) * jnp.eye(8) + g * A
        for _ in range(20):
            st = pushsum_gossip_round(st, A, g, Identity())
            x_ref, w_ref = M @ x_ref, M @ w_ref
        np.testing.assert_allclose(np.asarray(st.x), np.asarray(x_ref),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(st.w), np.asarray(w_ref),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(pushsum_debias(st)),
            np.asarray(st.x / st.w), atol=0)

    def test_plain_averaging_never_reaches_consensus_on_digraph(self, key):
        """The fail-fast rationale: feeding a column-stochastic A to the
        symmetric averaging x' = A x converges to the Perron direction
        pi * (1^T x0) — nodes NEVER agree (unless pi is uniform), which is
        exactly the bias the push-sum weight column corrects."""
        topo = random_digraph(8, 0.4, seed=5)
        A = jnp.asarray(topo.A)
        x = jax.random.normal(key, (8, 4))
        for _ in range(300):
            x = A @ x
        spread = float(jnp.max(jnp.abs(x - jnp.mean(x, 0, keepdims=True))))
        assert spread > 1e-2          # stuck on the non-uniform Perron vector
        # push-sum on the SAME graph does reach the true average
        _, errs = run_pushsum_gossip(jax.random.normal(key, (8, 4)),
                                     A, 0.5, Identity(), 300)
        assert float(errs[-1]) < 1e-8


# ---------------------------------------------------------------------------
# trainer / CLI fail-fast
# ---------------------------------------------------------------------------

class TestFailFast:
    def _trainer(self, **kw):
        from repro.configs.base import ChocoConfig, get_config
        from repro.models import build_model
        from repro.optim import constant_schedule, sgd
        from repro.train.trainer import DecentralizedTrainer
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        mode = kw.pop("mode", "choco")
        return DecentralizedTrainer(
            model=build_model(cfg), choco=ChocoConfig(**kw), mesh=mesh,
            n_nodes=1, optimizer=sgd(), lr_fn=constant_schedule(0.1),
            mode=mode)

    def test_directed_topology_needs_pushsum(self):
        with pytest.raises(ValueError, match="push-sum"):
            self._trainer(topology="directed_ring", mode="choco")
        with pytest.raises(ValueError, match="push-sum"):
            self._trainer(topology="random_digraph", mode="plain")

    def test_process_with_time_varying_sequence_rejected(self):
        with pytest.raises(ValueError, match="ambiguous"):
            self._trainer(topology="ring,hypercube",
                          topology_process="matching", gossip_steps=2)

    def test_process_with_directed_rejected(self):
        with pytest.raises(ValueError, match="push-sum|directed"):
            self._trainer(topology="directed_ring",
                          topology_process="matching", mode="pushsum")

    def test_process_with_allreduce_rejected(self):
        with pytest.raises(ValueError, match="allreduce|gossip graph"):
            self._trainer(topology="ring", topology_process="linkfail",
                          mode="allreduce")

    @pytest.mark.parametrize("argv,msg", [
        (["--topology", "directed_ring"], "pushsum"),
        (["--mode", "pushsum", "--topology", "ring,hypercube",
          "--gossip-steps", "2"], "time-varying"),
        (["--mode", "pushsum", "--topology", "directed_ring",
          "--topology-process", "matching"], "topology-process"),
        (["--mode", "pushsum", "--topology", "directed_ring",
          "--gossip-engine", "per-leaf"], "packed"),
        (["--topology-process", "matching", "--topology", "ring,torus",
          "--gossip-steps", "2"], "ambiguous"),
        (["--edge-drop-prob", "0.3"], "linkfail"),
        (["--topology-process", "linkfail", "--edge-drop-prob", "1.5"],
         "0, 1"),
        (["--matching-sampler", "weighted"], "matching"),
        (["--keep-checkpoints", "0", "--checkpoint-dir", "/tmp/x"], ">= 1"),
        (["--keep-checkpoints", "2"], "checkpoint-dir"),
    ])
    def test_cli_fail_fast(self, argv, msg, capsys):
        """launch/train.py rejects bad combinations before importing jax /
        touching devices (argparse.error -> SystemExit(2))."""
        from repro.launch.train import main
        with pytest.raises(SystemExit) as ei:
            main(["--arch", "qwen3-1.7b", "--smoke"] + argv)
        assert ei.value.code == 2
        assert msg.split("|")[0] in capsys.readouterr().err


# ---------------------------------------------------------------------------
# distributed equivalence (slow tier — 8 simulated host devices)
# ---------------------------------------------------------------------------

from test_distributed import run_sub  # noqa: E402  (shared subprocess runner)


@pytest.mark.slow
@pytest.mark.distributed
@pytest.mark.parametrize("topology", ["ring", "hypercube", "star"])
@pytest.mark.parametrize("kind", ["matching", "linkfail"])
def test_distributed_process_engine_matches_simulator(topology, kind):
    """Acceptance: the replica-based process engine (packed AND per-leaf)
    reproduces the matrix simulator per step given the same seed — the
    sampled round / edge mask is drawn identically on every node from the
    shared exchange key, with zero communication."""
    run_sub(f"""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.comm.schedule import compile_schedule
        from repro.comm.stochastic import (make_topology_process,
                                           choco_process_round,
                                           init_process_state)
        from repro.core import make_topology, TopK

        n, d = 8, 96
        topo = make_topology("{topology}", n)
        sched = compile_schedule(topo)
        proc = make_topology_process("{kind}", sched, edge_drop_prob=0.3)
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        comp = TopK(k=9)            # deterministic: no RNG divergence
        gamma = 0.3
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        R = sched.n_rounds

        st = init_process_state(x0, proc)
        for i in range(6):
            st = choco_process_round(st, proc, gamma, comp,
                                     jax.random.PRNGKey(i))

        for packed in (True, False):
            ex = jax.jit(make_gossip_exchange(
                mode="choco", mesh=mesh, state_specs={{"w": P("data", None)}},
                axis="data", compressor=comp, gamma=gamma, packed=packed,
                process=proc))
            x = {{"w": x0}}
            if proc.kind == "matching":
                xh = [{{"w": jnp.zeros_like(x0)}} for _ in range(R)]
            else:
                xh = {{"w": jnp.zeros_like(x0)}}
            s = [{{"w": jnp.zeros_like(x0)}} for _ in range(R)]
            for i in range(6):
                x, xh, s = ex(jax.random.PRNGKey(i), x, xh, s)
            np.testing.assert_allclose(np.asarray(x["w"]), np.asarray(st.x),
                                       rtol=1e-4, atol=1e-5)
        print("PROCESS ENGINE == SIMULATOR")
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_distributed_plain_matching_is_exact_sampled_gossip():
    """Plain engine + matching process: x' = W_t x with the sampled branch
    matrix, bit-for-bit the same branch on every node."""
    run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.comm.schedule import compile_schedule
        from repro.comm.stochastic import MatchingProcess
        from repro.core import make_topology

        n, d = 8, 32
        topo = make_topology("hypercube", n)
        proc = MatchingProcess(compile_schedule(topo))
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        Ms = jnp.asarray(proc.branch_matrices())

        ex = make_gossip_exchange(mode="plain", mesh=mesh,
                                  state_specs=P("data", None), axis="data",
                                  process=proc)
        x, ref = x0, x0
        for i in range(8):
            k = jax.random.PRNGKey(i)
            x, _, _ = ex(k, x, x * 0, x * 0)
            ref = Ms[proc.round_index(k, 0)] @ ref
        np.testing.assert_allclose(np.asarray(x), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)
        print("PLAIN MATCHING OK")
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_distributed_matching_single_permute_launch():
    """Flagship perf claim: a sampled-matching gossip round executes ONE
    round's permutes regardless of the schedule's round count.  In the
    compiled HLO every collective-permute lives inside a conditional branch
    computation (lax.switch — one branch executes per step) and the ENTRY
    computation carries zero unconditional permutes, so there is no fan-out
    of the full 7-round fully-connected schedule."""
    run_sub("""
        import re
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.comm.schedule import compile_schedule
        from repro.comm.stochastic import MatchingProcess
        from repro.core import make_topology, TopK

        n = 8
        topo = make_topology("fully_connected", n)   # 7 static rounds
        sched = compile_schedule(topo)
        proc = MatchingProcess(sched)
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        ex = make_gossip_exchange(
            mode="choco", mesh=mesh, state_specs=P("data", None),
            axis="data", compressor=TopK(k=16), gamma=0.3, process=proc)
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, 256))
        xh = [jnp.zeros_like(x0) for _ in range(sched.n_rounds)]
        s = [jnp.zeros_like(x0) for _ in range(sched.n_rounds)]
        lowered = jax.jit(ex).lower(jax.random.PRNGKey(0), x0, xh, s)
        hlo = lowered.compile().as_text()

        # split the HLO module into computations; permutes must live ONLY
        # in (conditional branch) sub-computations, never in ENTRY
        comps, cur = {}, None
        for line in hlo.splitlines():
            m = re.match(r"^(ENTRY )?%?([\\w.\\-]+)\\s*\\(", line)
            if m and line.rstrip().endswith("{"):
                cur = (("ENTRY " if m.group(1) else "") + m.group(2))
                comps[cur] = []
            elif cur is not None:
                comps[cur].append(line)
        is_permute = lambda l: ("collective-permute" in l
                                and "-done" not in l)
        entry = next(k for k in comps if k.startswith("ENTRY"))
        entry_permutes = sum(is_permute(l) for l in comps[entry])
        entry_conds = sum("conditional" in l for l in comps[entry])
        branch_counts = [sum(is_permute(l) for l in v)
                         for k, v in comps.items()
                         if k != entry and sum(is_permute(l) for l in v)]
        assert entry_permutes == 0, entry_permutes
        assert entry_conds >= 1, "matching must lower to lax.switch"
        assert len(branch_counts) == sched.n_rounds, branch_counts
        # one round's payload per branch: a small constant (vals + idx
        # permutes, possibly split by SPMD), NOT the whole schedule
        assert max(branch_counts) <= 4, branch_counts
        print("SINGLE-LAUNCH OK entry=0 branches:", branch_counts)
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_distributed_pushsum_directed_ring_e2e():
    """Acceptance: compressed push-sum on a directed ring over an 8-device
    simulated mesh converges to the TRUE average (de-biased x/w) and matches
    the matrix simulator."""
    run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.comm.pushsum import debias
        from repro.comm.schedule import compile_directed_schedule
        from repro.core import directed_ring, TopK
        from repro.core.choco_gossip import (init_pushsum_state,
                                             pushsum_gossip_round)

        n, d = 8, 96
        topo = directed_ring(n)
        sched = compile_directed_schedule(topo)
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        comp = TopK(k=24)
        gamma = 0.5
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        xbar = np.asarray(jnp.mean(x0, 0))

        st = init_pushsum_state(x0)
        A = jnp.asarray(topo.A)
        for i in range(6):
            st = pushsum_gossip_round(st, A, gamma, comp)

        ex = jax.jit(make_gossip_exchange(
            mode="pushsum", mesh=mesh, state_specs={"p": P("data", None)},
            axis="data", compressor=comp, gamma=gamma,
            schedules=(sched,), weight_specs=P("data", None)))
        x = {"p": x0}
        xh = {"p": jnp.zeros_like(x0)}
        s = {"p": jnp.zeros_like(x0)}
        w = jnp.ones((n, 1))
        for i in range(6):
            x, xh, s, w = ex(jax.random.PRNGKey(i), x, xh, s, w)
        np.testing.assert_allclose(np.asarray(x["p"]), np.asarray(st.x),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(w), np.asarray(st.w),
                                   rtol=1e-4, atol=1e-5)

        # run to convergence: de-biased estimate hits the true average
        # (directed ring delta = 0.076 — the slow-mixing worst case; the
        # initial consensus error is ~d = 96, so 1e-4 is a 6-decade drop)
        for i in range(6, 300):
            x, xh, s, w = ex(jax.random.PRNGKey(i), x, xh, s, w)
        z = np.asarray(debias(x, w)["p"])
        err = np.mean(np.sum((z - xbar) ** 2, axis=-1))
        assert err < 1e-4, err
        # mass conservation on the wire: 1^T w == n
        np.testing.assert_allclose(float(jnp.sum(w)), n, atol=1e-3)
        print("PUSHSUM E2E OK", err)
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_trainer_process_and_pushsum_e2e():
    """Trainer end-to-end: matching + linkfail processes and push-sum mode
    all train with finite decreasing loss on an 8-device mesh."""
    run_sub("""
        from repro.configs.base import get_config, ChocoConfig
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.data.synthetic import make_lm_batch_fn

        mesh = jax.make_mesh((8, 1), ("data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        m = build_model(cfg)
        nb = make_lm_batch_fn(cfg, 32, 2, 8)

        cases = [
            ("choco", ChocoConfig(compressor="top_k",
                                  comp_kwargs=(("fraction", 0.05),),
                                  topology="hypercube",
                                  topology_process="matching")),
            ("choco", ChocoConfig(compressor="top_k",
                                  comp_kwargs=(("fraction", 0.05),),
                                  topology="ring",
                                  topology_process="linkfail",
                                  edge_drop_prob=0.25)),
            ("pushsum", ChocoConfig(compressor="top_k",
                                    comp_kwargs=(("fraction", 0.05),),
                                    topology="directed_ring",
                                    consensus_gamma=0.4)),
            # plain + process: no replicas — x_hat/s stay single trees
            ("plain", ChocoConfig(topology="hypercube",
                                  topology_process="matching")),
        ]
        for mode, choco in cases:
            tr = DecentralizedTrainer(model=m, choco=choco, mesh=mesh,
                                      n_nodes=8, optimizer=sgd(),
                                      lr_fn=constant_schedule(0.05), mode=mode)
            state = tr.init_state(jax.random.PRNGKey(0))
            b = jax.tree.map(jnp.asarray, nb())
            step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                        jax.eval_shape(lambda: b))
            losses = []
            for i in range(10):
                state, mets = step(state, jax.tree.map(jnp.asarray, nb()))
                losses.append(float(mets["loss"]))
            assert all(np.isfinite(losses)), (mode, losses)
            assert losses[-1] < losses[0], (mode, losses)
            print(mode, choco.topology_process or choco.topology,
                  "LOSS", losses[0], "->", losses[-1])
        print("TRAINER PROCESS/PUSHSUM OK")
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_static_paths_unchanged_regression():
    """PR 2 bit-match guarantee: building an exchange WITHOUT a process (the
    static path) takes the exact pre-existing code path — verified by the
    engine==legacy tests in test_distributed.py; here we additionally pin
    that a process=None exchange and a drop_prob=0 linkfail exchange agree
    on the final consensus point (same algorithm family, same fixed W)."""
    run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.comm.schedule import compile_schedule
        from repro.comm.stochastic import LinkFailureProcess
        from repro.core import make_topology, TopK

        n, d = 8, 64
        topo = make_topology("hypercube", n)
        sched = compile_schedule(topo)
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        comp = TopK(k=16)
        gamma = 0.3
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        xbar = np.asarray(jnp.mean(x0, 0))

        # static engine
        ex0 = jax.jit(make_gossip_exchange(mode="choco", mesh=mesh,
                                           state_specs=P("data", None),
                                           axis="data", compressor=comp,
                                           gamma=gamma, schedules=(sched,)))
        x, xh, s = x0, jnp.zeros_like(x0), jnp.zeros_like(x0)
        for i in range(120):
            x, xh, s = ex0(jax.random.PRNGKey(i), x, xh, s)
        err_static = np.mean(np.sum((np.asarray(x) - xbar) ** 2, -1))

        # p=0 linkfail: every round always live, same fixed W
        proc = LinkFailureProcess(sched, drop_prob=0.0)
        ex1 = jax.jit(make_gossip_exchange(mode="choco", mesh=mesh,
                                           state_specs=P("data", None),
                                           axis="data", compressor=comp,
                                           gamma=gamma, process=proc))
        x = x0
        xh = jnp.zeros_like(x0)
        s = [jnp.zeros_like(x0) for _ in range(sched.n_rounds)]
        for i in range(120):
            x, xh, s = ex1(jax.random.PRNGKey(i), x, xh, s)
        err_p0 = np.mean(np.sum((np.asarray(x) - xbar) ** 2, -1))

        assert err_static < 1e-6 and err_p0 < 1e-6, (err_static, err_p0)
        print("STATIC/P0 OK", err_static, err_p0)
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_checkpoint_restore_across_process_change():
    """A checkpoint saved WITHOUT a topology process restores into a
    matching-process trainer via the elastic re-mix path: params/opt are
    read back exactly, the re-shaped x_hat/s reference lists are zero-filled
    (structural drift under reset prefixes is not a mismatch), and the
    consensus warmup re-seeds them under the process engine."""
    run_sub("""
        import tempfile, os
        from repro.configs.base import get_config, ChocoConfig
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.data.synthetic import make_lm_batch_fn

        mesh = jax.make_mesh((8, 1), ("data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        m = build_model(cfg)
        nb = make_lm_batch_fn(cfg, 32, 2, 8)

        def trainer(proc):
            return DecentralizedTrainer(
                model=m, choco=ChocoConfig(
                    compressor="top_k", comp_kwargs=(("fraction", 0.05),),
                    topology="hypercube", topology_process=proc),
                mesh=mesh, n_nodes=8, optimizer=sgd(),
                lr_fn=constant_schedule(0.05))

        t0 = trainer(None)
        state = t0.init_state(jax.random.PRNGKey(0))
        b = jax.tree.map(jnp.asarray, nb())
        step = t0.jitted_train_step(jax.eval_shape(lambda: state),
                                    jax.eval_shape(lambda: b))
        for i in range(3):
            state, _ = step(state, jax.tree.map(jnp.asarray, nb()))
        d = os.path.join(tempfile.mkdtemp(), "step3")
        t0.save_checkpoint(d, state)

        t1 = trainer("matching")
        restored, man, warmup = t1.restore_checkpoint(d)
        assert warmup > 0, "process change must take the re-mix path"
        # params read back exactly
        p_old = jax.tree.leaves(state.params)[0]
        p_new = jax.tree.leaves(restored.params)[0]
        np.testing.assert_array_equal(np.asarray(p_old), np.asarray(p_new))
        # re-shaped reference lists start zeroed
        assert isinstance(restored.x_hat, list)
        for tree in restored.x_hat:
            for leaf in jax.tree.leaves(tree):
                assert float(jnp.sum(jnp.abs(leaf))) == 0.0
        restored = t1.consensus_warmup(restored, warmup)
        # warmup engaged the process engine: refs are no longer all-zero
        total = sum(float(jnp.sum(jnp.abs(l)))
                    for tree in restored.x_hat
                    for l in jax.tree.leaves(tree))
        assert total > 0
        # and training continues
        step1 = t1.jitted_train_step(jax.eval_shape(lambda: restored),
                                     jax.eval_shape(lambda: b))
        for i in range(2):
            restored, mets = step1(restored, jax.tree.map(jnp.asarray, nb()))
        assert np.isfinite(float(mets["loss"]))
        print("PROCESS-CHANGE RESTORE OK")
    """)
