"""Schedule compiler unit tests (fast tier): the permutation-round
decomposition must reconstruct W exactly for every topology family, stay
pure trace-free Python (no jax), and compile deterministically — schedule
compilation runs at trainer-build time and must never regress jit compile
times (it is baked into the step as constants)."""
import ast
import inspect

import numpy as np
import pytest

import repro.comm.schedule as schedule_mod
from repro.comm.schedule import compile_schedule, compile_schedules
from repro.core.topology import (Topology, _from_adjacency, chain,
                                 fully_connected, hypercube, ring, star,
                                 torus2d)

ALL_TOPOLOGIES = [
    ("ring", lambda: ring(8)),
    ("ring2", lambda: ring(2)),
    ("ring25", lambda: ring(25)),
    ("torus", lambda: torus2d(2, 4)),
    ("torus44", lambda: torus2d(4, 4)),
    ("torus35", lambda: torus2d(3, 5)),
    ("hypercube", lambda: hypercube(8)),
    ("hypercube16", lambda: hypercube(16)),
    ("star", lambda: star(8)),
    ("chain", lambda: chain(8)),
    ("fully_connected", lambda: fully_connected(8)),
]


@pytest.mark.parametrize("name,topo_fn", ALL_TOPOLOGIES)
def test_schedule_reconstructs_W_exactly(name, topo_fn):
    """W = diag(self_weights) + sum_r weight_r * P_r, element-exact."""
    topo = topo_fn()
    sched = compile_schedule(topo)
    np.testing.assert_allclose(sched.mixing_matrix(), topo.W,
                               rtol=0, atol=1e-12)


@pytest.mark.parametrize("name,topo_fn", ALL_TOPOLOGIES)
def test_rounds_are_valid_partial_permutations(name, topo_fn):
    """Every round must be ppermute-able: each node is the source of at most
    one pair and the destination of at most one pair."""
    sched = compile_schedule(topo_fn())
    for rnd in sched.rounds:
        srcs = [s for s, _ in rnd.perm]
        dsts = [d for _, d in rnd.perm]
        assert len(set(srcs)) == len(srcs), rnd.perm
        assert len(set(dsts)) == len(dsts), rnd.perm
        assert all(0 <= i < sched.n for i in srcs + dsts)


def test_round_counts_are_optimal_and_deterministic():
    """Round count = number of collective-permute rounds per gossip step;
    these are the launch-count contracts EXPERIMENTS.md §Perf E records."""
    expected = {
        "ring": (ring(8), 2),               # +1 / -1 shifts
        "ring2": (ring(2), 1),              # single edge
        "ring1": (ring(1), 0),              # trivial
        "torus2x4": (torus2d(2, 4), 3),     # 1 (rows=2) + 2 (cols=4)
        "torus4x4": (torus2d(4, 4), 4),     # 2 per axis
        "hypercube8": (hypercube(8), 3),    # log2(8) dimension exchanges
        "hypercube16": (hypercube(16), 4),
        "fc8": (fully_connected(8), 7),     # n - 1 shifts
        "star8": (star(8), 7),              # hub is in every matching
        "chain8": (chain(8), 2),            # alternating matchings
    }
    for label, (topo, n_rounds) in expected.items():
        a = compile_schedule(topo)
        b = compile_schedule(topo)
        assert a.n_rounds == n_rounds, (label, a.n_rounds)
        assert a == b, f"{label}: compilation is not deterministic"


def test_schedule_compiler_is_trace_free():
    """The compiler must stay pure Python: no jax import anywhere in the
    module (so it can never trace or add compile time), and everything in a
    compiled schedule is a plain python int/float/tuple, never an array."""
    tree = ast.parse(inspect.getsource(schedule_mod))
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            names = [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom):
            names = [node.module or ""]
        else:
            continue
        for mod in names:
            assert not mod.split(".")[0] == "jax", \
                f"schedule compiler imports jax via {mod!r}"

    sched = compile_schedule(star(8))     # exercises per-node weights too
    assert isinstance(sched.n, int)
    assert all(isinstance(w, float) for w in sched.self_weights)
    for rnd in sched.rounds:
        assert all(isinstance(i, int) for p in rnd.perm for i in p)
        assert rnd.weight is None or isinstance(rnd.weight, float)
        assert rnd.weights is None or all(
            isinstance(w, float) for w in rnd.weights)


def test_general_graph_via_edge_coloring():
    """An arbitrary symmetric Metropolis-Hastings W (no family fast path)
    compiles through greedy edge coloring and still reconstructs exactly,
    with at most 2*max_degree - 1 rounds (greedy bound)."""
    rng = np.random.RandomState(3)
    n = 12
    adj = (rng.rand(n, n) < 0.3).astype(int)
    adj = np.maximum(adj, adj.T)
    np.fill_diagonal(adj, 0)
    adj[0, 1] = adj[1, 0] = 1              # keep it connected enough
    topo = _from_adjacency("erdos", adj)
    sched = compile_schedule(topo)
    np.testing.assert_allclose(sched.mixing_matrix(), topo.W,
                               rtol=0, atol=1e-12)
    max_deg = int(adj.sum(1).max())
    assert sched.n_rounds <= 2 * max_deg - 1


def test_torus_grid_override():
    """The trainer maps the torus onto the (pod, data) mesh grid; the same
    topology must compile against a caller-supplied factorization."""
    topo = torus2d(2, 8)
    sched = compile_schedule(topo, grid=(2, 8))
    np.testing.assert_allclose(sched.mixing_matrix(), topo.W, atol=1e-12)
    assert sched.n_rounds == 3             # 1 (rows=2) + 2 (cols=8)
    # wrong grid: the structured decomposition mismatches W, and the
    # compiler must fall back to edge coloring rather than mis-compile
    sched_bad_grid = compile_schedule(topo, grid=(4, 4))
    np.testing.assert_allclose(sched_bad_grid.mixing_matrix(), topo.W,
                               atol=1e-12)


def test_time_varying_sequence():
    scheds = compile_schedules([ring(8), hypercube(8)])
    assert [s.name for s in scheds] == ["ring", "hypercube"]
    with pytest.raises(ValueError):
        compile_schedules([ring(8), ring(4)])
    with pytest.raises(ValueError):
        compile_schedules([])


def test_asymmetric_W_rejected():
    W = np.eye(3)
    W[0, 1] = 0.5
    bad = Topology("bad", W, ((0, 1), (1,), (2,)))
    with pytest.raises(ValueError):
        compile_schedule(bad)


def test_uniform_weight_collapse():
    """Uniform-averaging families must compile to scalar (python float)
    round weights — the engine keeps them weak-typed so the schedule-driven
    ring/torus paths stay bit-identical to the pre-schedule engines."""
    for topo in (ring(8), torus2d(2, 4), hypercube(8), fully_connected(8)):
        sched = compile_schedule(topo)
        assert sched.self_weight is not None, topo.name
        assert all(r.weight is not None for r in sched.rounds), topo.name
    # star/chain have non-uniform diagonals (Metropolis-Hastings)
    assert compile_schedule(star(8)).self_weight is None
    assert compile_schedule(chain(8)).self_weight is None
