"""Declarative non-IID scenario matrix with executable convergence contracts.

Shared by the test tiers (tests/test_scenarios.py) and the benchmark driver
(benchmarks/bench_scenarios.py): a :class:`Scenario` is one point of
(partition alpha, topology, compressor, process, gossip_steps), and
:func:`run_scenario` runs CHOCO-SGD on the paper's logistic-regression
problem (reduced size) under that configuration, returning the final
consensus loss and diagnostics.  The contracts — "skewed CHOCO beats the
no-gossip negative control", "more gossip steps narrow the skew gap" — are
plain asserts over those numbers, so "when does CHOCO break" is a CI
answer, not an anecdote.

Design notes:

  * data comes from ``make_logreg(..., skew_alpha=...)``
    (``repro/data/partition.py`` Dirichlet shards); ``alpha=None`` is the
    IID shuffled control;
  * static-topology scenarios run a jit-scanned generalization of
    Algorithm 6 with ``gossip_steps`` Algorithm-5 rounds per SGD step;
    ``gamma=0`` degenerates to pure local SGD — the no-gossip negative
    control (each node walks to ITS shard's optimum, so the averaged
    model is bad exactly when shards disagree);
  * staleness/straggler scenarios run the delay-expanded simulator
    (``choco_stale_round``) between SGD half-steps, with per-edge delays
    drawn through the same shared-key contract the distributed engine
    uses — the engine-vs-simulator parity contract lives in the
    distributed tier of tests/test_scenarios.py;
  * consensus gamma follows the paper's §5.3 practice (tuned constant per
    compressor class, far above the conservative Theorem-2 floor) so the
    contracts resolve within CI-sized step budgets.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.topology import make_topology
from repro.core.compression import make_compressor
from repro.core.choco_gossip import (EfficientGossipState,
                                     choco_gossip_round_efficient,
                                     choco_stale_round, init_stale_state)
from repro.comm.schedule import compile_schedule
from repro.comm.async_gossip import StalenessProcess
from repro.data.synthetic import make_logreg

# problem size: small enough for the fast tier, large enough that the
# sorted/shuffled gap is structural (d >> n, m_per ~128)
N_NODES = 8
M, D = 1024, 128
BATCH = 8
DATASET = "epsilon"
STEPS = 600


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One point of the non-IID scenario matrix."""
    name: str
    alpha: Optional[float]           # Dirichlet concentration; None = IID
    topology: str = "ring"
    compressor: str = "top_k"
    comp_kwargs: Tuple[Tuple[str, object], ...] = (("fraction", 0.25),)
    process: Optional[str] = None    # None | "staleness"
    max_staleness: int = 1
    straggler_edges: Optional[Tuple[Tuple[int, int], ...]] = None
    straggler_delay_probs: Optional[Tuple[float, ...]] = None
    gossip_steps: int = 1
    gamma: float = 0.4               # tuned consensus stepsize (paper §5.3)
    seed: int = 0


def _comp(sc: Scenario):
    return make_compressor(sc.compressor, **dict(sc.comp_kwargs))


_COMPRESSORS = (
    ("topk", "top_k", (("fraction", 0.25),)),
    ("qsgd", "qsgd", (("s", 8),)),
)


def _core_matrix() -> Tuple[Scenario, ...]:
    """The >= 12 acceptance scenarios: alpha x topology x compressor."""
    out = []
    for alpha in (0.1, 1.0, 100.0):
        for topo in ("ring", "hypercube"):
            for cname, comp, kw in _COMPRESSORS:
                out.append(Scenario(
                    name=f"a{alpha:g}-{topo}-{cname}", alpha=alpha,
                    topology=topo, compressor=comp, comp_kwargs=kw))
    return tuple(out)


def _controls() -> Tuple[Scenario, ...]:
    """IID controls: one per (topology, compressor) cell."""
    return tuple(
        Scenario(name=f"iid-{topo}-{cname}", alpha=None, topology=topo,
                 compressor=comp, comp_kwargs=kw)
        for topo in ("ring", "hypercube")
        for cname, comp, kw in _COMPRESSORS)


def _multi_gossip() -> Tuple[Scenario, ...]:
    """Hashemi et al. 2020 prediction: k=3 rounds/step rescue the hardest
    skew — paired against the k=1 members of the core matrix."""
    return tuple(
        Scenario(name=f"a0.1-{topo}-{cname}-k3", alpha=0.1, topology=topo,
                 compressor=comp, comp_kwargs=kw, gossip_steps=3)
        for topo in ("ring",)
        for cname, comp, kw in _COMPRESSORS)


def _stragglers() -> Tuple[Scenario, ...]:
    """Per-edge heterogeneity: one maximally slow ring link under skew."""
    return (
        Scenario(name="a0.1-ring-topk-straggler", alpha=0.1,
                 process="staleness", max_staleness=2,
                 straggler_edges=((0, 1),)),
        Scenario(name="a0.1-ring-topk-stale-uniform", alpha=0.1,
                 process="staleness", max_staleness=2),
    )


SCENARIOS: Tuple[Scenario, ...] = (
    _core_matrix() + _controls() + _multi_gossip() + _stragglers())

#: the no-gossip negative control shares everything with its scenario but
#: gamma: local SGD never communicates, so consensus loss floors at the
#: disagreement of the per-shard optima
def no_gossip_control(sc: Scenario) -> Scenario:
    """The scenario's negative control: same data/topology, gamma = 0."""
    return dataclasses.replace(sc, name=sc.name + "-nogossip", gamma=0.0,
                               process=None)


def iid_control(sc: Scenario) -> Scenario:
    """The scenario's IID control: same pipeline, shuffled shards."""
    return dataclasses.replace(sc, name=sc.name + "-iid", alpha=None)


def get_scenario(name: str) -> Scenario:
    """Look a declarative scenario up by name."""
    for sc in SCENARIOS:
        if sc.name == name:
            return sc
    raise KeyError(f"unknown scenario {name!r}; have "
                   f"{[s.name for s in SCENARIOS]}")


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def _lr(t):
    # experiment-style decaying stepsize (paper §5.3 eta = m a / (t + b)
    # shape), tuned so the contracts separate within STEPS: by 600 steps the
    # no-gossip control trails CHOCO by ~4% relative loss (vs ~1e-4 noise)
    return 400.0 / (t.astype(jnp.float32) + 200.0)


@partial(jax.jit, static_argnames=("grad_fn", "compressor", "k", "steps"))
def _run_static(x0, W, grad_fn, compressor, gamma, k, steps, key):
    """CHOCO-SGD with k Algorithm-5 gossip rounds per SGD step (matrix
    form, jit-scanned).  gamma = 0 is the no-gossip negative control."""
    n = x0.shape[0]

    def body(carry, inp):
        t, skey = inp
        x, x_hat, s = carry
        gkeys = jax.random.split(jax.random.fold_in(skey, 0), n)
        G = jax.vmap(grad_fn)(x, jnp.arange(n), gkeys)
        x = x - _lr(t) * G
        st = EfficientGossipState(x=x, x_hat=x_hat, s=s)
        for r in range(k):
            st = choco_gossip_round_efficient(
                st, W, gamma, compressor,
                jax.random.fold_in(skey, 1 + r))
        return (st.x, st.x_hat, st.s), None

    keys = jax.random.split(key, steps)
    ts = jnp.arange(steps)
    init = (x0, jnp.zeros_like(x0), jnp.zeros_like(x0))
    (x, _, _), _ = jax.lax.scan(body, init, (ts, keys))
    return x


def _run_staleness(sc: Scenario, x0, grad_fn, compressor, steps, key):
    """CHOCO-SGD with the bounded-staleness simulator as the gossip stage
    (per-edge delays through the shared-key contract; straggler edges get
    their own distribution)."""
    proc = StalenessProcess(
        compile_schedule(make_topology(sc.topology, N_NODES)),
        max_staleness=sc.max_staleness,
        straggler_edges=sc.straggler_edges,
        straggler_delay_probs=sc.straggler_delay_probs)
    n = x0.shape[0]
    st = init_stale_state(x0, sc.max_staleness)

    @jax.jit
    def grad_half(x, t, skey):
        gkeys = jax.random.split(jax.random.fold_in(skey, 0), n)
        G = jax.vmap(grad_fn)(x, jnp.arange(n), gkeys)
        return x - _lr(t) * G

    for t in range(steps):
        skey = jax.random.fold_in(key, t)
        st = st._replace(x=grad_half(st.x, jnp.asarray(t), skey))
        ek = jax.random.fold_in(skey, 1)
        ck = (jax.random.fold_in(ek, 1) if compressor.stochastic else None)
        st = choco_stale_round(st, proc, sc.gamma, compressor, ek,
                               t=0, comp_key=ck)
    return st.x


def run_scenario(sc: Scenario, steps: int = STEPS) -> dict:
    """Run one scenario; returns the contract observables.

    ``final_loss`` is the full-dataset loss of the NODE-AVERAGED model
    (the paper's consensus-loss axis), ``node_loss_spread`` the max-min
    spread of the per-node full losses (diag/node_loss_spread's offline
    twin), ``consensus_dist`` sum_i ||x_i - xbar||^2.
    """
    problem = make_logreg(DATASET, N_NODES, m=M, d=D, seed=sc.seed,
                          skew_alpha=sc.alpha)
    grad_fn = problem.make_grad_fn(batch_size=BATCH)
    comp = _comp(sc)
    x0 = jnp.zeros((N_NODES, problem.d), jnp.float32)
    key = jax.random.PRNGKey(sc.seed + 17)
    if sc.process == "staleness":
        x = _run_staleness(sc, x0, grad_fn, comp, steps, key)
    else:
        W = jnp.asarray(make_topology(sc.topology, N_NODES).W, jnp.float32)
        x = _run_static(x0, W, grad_fn, comp, sc.gamma, sc.gossip_steps,
                        steps, key)
    xbar = jnp.mean(x, axis=0)
    node_losses = jnp.stack([problem.full_loss(x[i])
                             for i in range(N_NODES)])
    return {
        "scenario": sc.name,
        "final_loss": float(problem.full_loss(xbar)),
        "node_loss_spread": float(jnp.max(node_losses)
                                  - jnp.min(node_losses)),
        "consensus_dist": float(jnp.sum((x - xbar[None, :]) ** 2)),
    }
