"""Checkpoint round-trips, including CHOCO error-feedback state.

Fast tier: everything here runs on the single real CPU device (the sharded
format degenerates to one shard file, exercising the same manifest /
validation / bit-cast code paths).  Multi-device resume-exactness and
elastic restore live in test_checkpoint_distributed.py (slow/distributed).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointing import (restore_pytree, restore_sharded,
                                            save_pytree, save_sharded,
                                            load_metadata)
from repro.checkpoint.elastic import (consensus_warmup_rounds, elastic_ratio,
                                      remap_rows, source_rows)
from repro.checkpoint.manifest import (ElasticRestoreError, ManifestError,
                                       ShardCoverageError, TreeMismatchError,
                                       is_sharded_checkpoint, read_manifest)


def _tree():
    return {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.zeros((), jnp.int32)}}


# ---------------------------------------------------------------------------
# legacy flat npz
# ---------------------------------------------------------------------------

def test_roundtrip(tmp_path):
    tree = _tree()
    p = str(tmp_path / "ckpt")
    save_pytree(p, tree, metadata={"step": 7})
    got = restore_pytree(p, jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert load_metadata(p)["step"] == 7


def test_trainstate_roundtrip(tmp_path):
    from repro.train.trainer import TrainState
    from repro.optim import sgd
    params = {"w": jnp.ones((3, 4))}
    st = TrainState(params=params,
                    x_hat=jax.tree.map(lambda x: x * 0.5, params),
                    s=jax.tree.map(lambda x: x * 0.1, params),
                    opt=sgd().init(params),
                    step=jnp.int32(42), key=jax.random.PRNGKey(1))
    p = str(tmp_path / "state")
    save_pytree(p, st, metadata={"step": 42})
    got = restore_pytree(p, jax.eval_shape(lambda: st))
    assert int(got.step) == 42
    np.testing.assert_allclose(np.asarray(got.x_hat["w"]), 0.5)
    np.testing.assert_allclose(np.asarray(got.s["w"]), 0.1)


def test_restore_pytree_typed_validation(tmp_path):
    """The bare `assert` (stripped under python -O) is gone: missing, extra,
    and shape-mismatched keys raise one TreeMismatchError enumerating all."""
    tree = _tree()
    p = str(tmp_path / "ckpt")
    save_pytree(p, tree)
    like = {"a": jax.ShapeDtypeStruct((3, 3), jnp.float32),       # wrong shape
            "nested": {"c": jax.ShapeDtypeStruct((), jnp.int32),  # b missing
                       "d": jax.ShapeDtypeStruct((2,), jnp.float32)}}  # new
    with pytest.raises(TreeMismatchError) as ei:
        restore_pytree(p, like)
    err = ei.value
    assert not isinstance(err, AssertionError) and not isinstance(err, KeyError)
    assert err.missing == ("nested__d",)
    assert err.extra == ("nested__b",)
    assert [m[0] for m in err.mismatched] == ["a"]
    for frag in ("nested__d", "nested__b", "(3, 3)", "(2, 3)"):
        assert frag in str(err), (frag, str(err))


# ---------------------------------------------------------------------------
# sharded manifest-driven format
# ---------------------------------------------------------------------------

def test_sharded_roundtrip_and_manifest(tmp_path):
    tree = _tree()
    d = str(tmp_path / "ck")
    save_sharded(d, tree, step=11,
                 fingerprint={"n_nodes": 2, "topology": "ring"},
                 metadata={"arch": "t"})
    assert is_sharded_checkpoint(d)
    man = read_manifest(d)
    assert man.step == 11 and man.n_nodes == 2
    assert man.fingerprint["topology"] == "ring"
    # true dtype recorded, bf16 bit-cast to uint16 on disk (not widened f32)
    assert man.leaves["nested__b"].dtype == "bfloat16"
    assert man.leaves["nested__b"].storage == "uint16"
    assert man.leaves["a"].shape == (2, 3)

    got = restore_sharded(d, jax.eval_shape(lambda: tree))
    assert got["nested"]["b"].dtype == jnp.bfloat16
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(
            np.asarray(a).reshape(-1).view(np.uint8),
            np.asarray(b).reshape(-1).view(np.uint8))


def test_sharded_restore_under_shardings(tmp_path):
    """Restore builds leaves directly under the target NamedShardings —
    degenerate 1-device mesh here; real 8-device placement is covered by the
    distributed suite."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.arange(12, dtype=jnp.float32).reshape(1, 12)}
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    d = str(tmp_path / "ck")
    save_sharded(d, jax.device_put(tree, shardings), step=0)
    got = restore_sharded(d, jax.eval_shape(lambda: tree), shardings)
    assert got["w"].sharding == shardings["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_sharded_state_dtype_mismatch_regression(tmp_path):
    """bf16 manifest round-trip: restoring a bfloat16-state checkpoint into
    a float32 target (state_dtype drift) is a typed dtype error naming the
    leaf — never a silent cast of bit-cast uint16 payloads."""
    d = str(tmp_path / "ck")
    save_sharded(d, {"x_hat": jnp.ones((4,), jnp.bfloat16)}, step=0)
    like = {"x_hat": jax.ShapeDtypeStruct((4,), jnp.float32)}
    with pytest.raises(TreeMismatchError) as ei:
        restore_sharded(d, like)
    assert ("x_hat", "dtype", "bfloat16", "float32") in ei.value.mismatched


def test_sharded_missing_extra_shape_enumerated(tmp_path):
    d = str(tmp_path / "ck")
    save_sharded(d, _tree(), step=0)
    like = {"a": jax.ShapeDtypeStruct((9, 9), jnp.float32),
            "nested": {"b": jax.ShapeDtypeStruct((4,), jnp.bfloat16)},
            "zzz": jax.ShapeDtypeStruct((1,), jnp.int32)}
    with pytest.raises(TreeMismatchError) as ei:
        restore_sharded(d, like)
    err = ei.value
    assert err.missing == ("zzz",)
    assert err.extra == ("nested__c",)
    assert ("a", "shape", "(2, 3)", "(9, 9)") in err.mismatched


def test_sharded_incomplete_checkpoint(tmp_path):
    d = str(tmp_path / "nope")
    os.makedirs(d)
    assert not is_sharded_checkpoint(d)
    with pytest.raises(ManifestError):
        read_manifest(d)


def test_sharded_coverage_error(tmp_path):
    """A deleted shard file is a ShardCoverageError naming the leaf, not a
    zero-filled array."""
    d = str(tmp_path / "ck")
    tree = {"w": jnp.ones((4, 4), jnp.float32)}
    save_sharded(d, tree, step=0)
    for f in os.listdir(d):
        if f.endswith(".index.json"):
            os.remove(os.path.join(d, f))
    with pytest.raises(ShardCoverageError, match="w"):
        restore_sharded(d, jax.eval_shape(lambda: tree))


# ---------------------------------------------------------------------------
# elastic restore policy
# ---------------------------------------------------------------------------

def test_elastic_remap_policy():
    old = np.arange(4 * 3, dtype=np.float32).reshape(4, 3)
    # grow: cyclic tile, new[j] = old[j % n_old]
    grown = remap_rows(old, 8)
    np.testing.assert_array_equal(grown, old[np.arange(8) % 4])
    # shrink: strided mean, new[j] = mean(old[j::n_new])
    shrunk = remap_rows(old, 2)
    np.testing.assert_allclose(shrunk, np.stack([old[[0, 2]].mean(0),
                                                 old[[1, 3]].mean(0)]))
    # tile then shrink round-trips
    np.testing.assert_allclose(remap_rows(remap_rows(old, 8), 4), old)
    # source_rows agrees with remap_rows
    for j in range(8):
        assert source_rows(j, 4, 8) == (j % 4,)
    assert source_rows(1, 4, 2) == (1, 3)
    with pytest.raises(ElasticRestoreError):
        elastic_ratio(4, 6)


def test_elastic_restore_remap_and_reset(tmp_path):
    """Full elastic restore through the sharded reader: params re-mapped
    across the node dim, x_hat/s re-zeroed (old public copies are invalid
    under the new W)."""
    old = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
           "x_hat": {"w": jnp.full((2, 3), 7.0)},
           "s": {"w": jnp.full((2, 3), 3.0)},
           "step": jnp.int32(5)}
    d = str(tmp_path / "ck")
    save_sharded(d, old, step=5, fingerprint={"n_nodes": 2})
    like = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((4,) + tuple(l.shape[1:]), l.dtype)
        if l.ndim else jax.ShapeDtypeStruct((), l.dtype), old)
    got = restore_sharded(d, like, node_remap=(2, 4),
                          reset_prefixes=("x_hat", "s"))
    np.testing.assert_array_equal(
        got["params"]["w"], np.asarray(old["params"]["w"])[np.arange(4) % 2])
    assert not np.any(got["x_hat"]["w"]) and not np.any(got["s"]["w"])
    assert int(got["step"]) == 5


def test_elastic_reset_keys_exempt_from_dtype_check(tmp_path):
    """state_dtype change + elastic restore: x_hat/s are zero-filled in the
    TARGET dtype without reading saved bytes, so their saved dtype must not
    fail validation (params still validate strictly)."""
    old = {"params": {"w": jnp.ones((2, 3), jnp.float32)},
           "x_hat": {"w": jnp.ones((2, 3), jnp.bfloat16)}}
    d = str(tmp_path / "ck")
    save_sharded(d, old, step=0)
    like = {"params": {"w": jax.ShapeDtypeStruct((4, 3), jnp.float32)},
            "x_hat": {"w": jax.ShapeDtypeStruct((4, 3), jnp.float32)}}
    got = restore_sharded(d, like, node_remap=(2, 4),
                          reset_prefixes=("x_hat",))
    assert got["x_hat"]["w"].dtype == np.float32
    assert not np.any(got["x_hat"]["w"])


def test_consensus_warmup_rounds():
    # fully-connected mixes in one round; harder graphs need more, capped
    assert consensus_warmup_rounds(1.0) == 1
    assert consensus_warmup_rounds(0.5) < consensus_warmup_rounds(0.1)
    assert consensus_warmup_rounds(1e-6) == 64
    with pytest.raises(ElasticRestoreError):
        consensus_warmup_rounds(0.0)


# ---------------------------------------------------------------------------
# retention / GC (save_sharded keep_last)
# ---------------------------------------------------------------------------

def test_keep_last_gc_deletes_oldest(tmp_path):
    from repro.checkpoint.checkpointing import gc_checkpoints
    base = tmp_path / "ckpts"
    for step in (10, 20, 30):
        save_sharded(str(base / f"step{step}"), _tree(), step=step)
    # keep_last applied on the 4th save: only the newest 2 survive
    save_sharded(str(base / "step40"), _tree(), step=40, keep_last=2)
    kept = sorted(p.name for p in base.iterdir())
    assert kept == ["step30", "step40"], kept
    # every survivor is still a complete, restorable checkpoint
    for name in kept:
        restore_sharded(str(base / name), _tree())
    # idempotent: nothing more to delete
    assert gc_checkpoints(str(base), 2) == []


def test_keep_last_never_deletes_step_being_written(tmp_path):
    base = tmp_path / "ckpts"
    save_sharded(str(base / "step5"), _tree(), step=5)
    # keep_last=1 with the new save protected: the NEW dir survives even
    # though an adversarial ordering might sort it for deletion
    save_sharded(str(base / "step9"), _tree(), step=9, keep_last=1)
    assert sorted(p.name for p in base.iterdir()) == ["step9"]
    restore_sharded(str(base / "step9"), _tree())


def test_keep_last_ignores_torn_dirs_and_foreign_files(tmp_path):
    from repro.checkpoint.checkpointing import gc_checkpoints
    base = tmp_path / "ckpts"
    base.mkdir()
    (base / "torn").mkdir()                      # no manifest: never touched
    (base / "torn" / "shards-p00000.npz").write_bytes(b"x")
    (base / "notes.txt").write_text("keep me")
    save_sharded(str(base / "step1"), _tree(), step=1)
    save_sharded(str(base / "step2"), _tree(), step=2, keep_last=1)
    names = sorted(p.name for p in base.iterdir())
    assert names == ["notes.txt", "step2", "torn"], names
    with pytest.raises(ValueError, match="keep_last"):
        gc_checkpoints(str(base), 0)
