"""Checkpoint round-trips, including CHOCO error-feedback state."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointing import (save_pytree, restore_pytree,
                                            load_metadata)


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.zeros((), jnp.int32)}}
    p = str(tmp_path / "ckpt")
    save_pytree(p, tree, metadata={"step": 7})
    got = restore_pytree(p, jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert load_metadata(p)["step"] == 7


def test_trainstate_roundtrip(tmp_path):
    from repro.train.trainer import TrainState
    from repro.optim import sgd
    params = {"w": jnp.ones((3, 4))}
    st = TrainState(params=params,
                    x_hat=jax.tree.map(lambda x: x * 0.5, params),
                    s=jax.tree.map(lambda x: x * 0.1, params),
                    opt=sgd().init(params),
                    step=jnp.int32(42), key=jax.random.PRNGKey(1))
    p = str(tmp_path / "state")
    save_pytree(p, st, metadata={"step": 42})
    got = restore_pytree(p, jax.eval_shape(lambda: st))
    assert int(got.step) == 42
    np.testing.assert_allclose(np.asarray(got.x_hat["w"]), 0.5)
    np.testing.assert_allclose(np.asarray(got.s["w"]), 0.1)
