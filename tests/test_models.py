"""Per-architecture smoke tests (reduced configs): forward/train step on CPU,
shape + finiteness assertions, prefill/decode consistency (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, ARCH_IDS
from repro.models import build_model

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def _batch(cfg):
    if cfg.family == "audio":
        return {"frame_embeds": jax.random.normal(KEY, (B, S, cfg.frontend.embed_dim)),
                "targets": jnp.zeros((B, S), jnp.int32),
                "mask": jnp.ones((B, S))}
    if cfg.family == "vlm":
        return {"patch_embeds": jax.random.normal(
                    KEY, (B, cfg.frontend.n_tokens, cfg.frontend.embed_dim)),
                "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
                "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


# the two recurrent-family smokes (mamba/rwkv scans) dominate fast-tier
# walltime — slow tier; every other family stays fast
_SLOW_SMOKES = {"zamba2-1.2b", "rwkv6-3b"}


@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_SMOKES else a
    for a in ARCH_IDS])
def test_smoke_forward_and_train_step(arch):
    """One forward + one SGD train step on the reduced config; asserts output
    shapes and no NaNs (assignment requirement)."""
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    m = build_model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)

    loss, metrics = jax.jit(m.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch

    grads = jax.jit(jax.grad(lambda p: m.loss(p, batch)[0]))(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g))), (arch, path)
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2, _ = jax.jit(m.loss)(new_params, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "hubert-xlarge"])
def test_prefill_decode_consistency(arch):
    """Token-by-token decode reproduces the full-sequence last-token logits."""
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    s = 12
    toks = jax.random.randint(KEY, (B, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch = {"patch_embeds": jax.random.normal(
                     KEY, (B, cfg.frontend.n_tokens, cfg.frontend.embed_dim)),
                 "tokens": toks}
        logits_pre, _ = jax.jit(m.prefill)(params, batch)
        return  # decode continuation exercised for pure-text archs below
    logits_pre, caches = jax.jit(m.prefill)(params, {"tokens": toks})
    assert logits_pre.shape == (B, 1, cfg.vocab_size)

    cache = m.init_cache(B, s)
    dec = jax.jit(m.decode_step)
    lg = None
    for t in range(s):
        lg, cache = dec(params, toks[:, t:t + 1], cache,
                        jnp.full((B,), t, jnp.int32))
    a = np.asarray(lg, np.float32)
    b = np.asarray(logits_pre, np.float32)
    scale = max(np.abs(b).max(), 1.0)
    assert np.max(np.abs(a - b)) / scale < 0.05, arch


def test_audio_prefill_runs():
    cfg = get_config("hubert-xlarge", smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    logits, _ = jax.jit(m.prefill)(
        params, {"frame_embeds": jax.random.normal(KEY, (B, S, cfg.frontend.embed_dim))})
    assert logits.shape == (B, 1, cfg.vocab_size)


def test_param_count_formulas():
    """Analytic n_params() tracks the actual initialised count (smoke cfgs)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        m = build_model(cfg)
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(m.init(KEY)))
        predicted = cfg.n_params()
        tol = 0.6 if cfg.family in ("ssm", "hybrid") else 0.35
        assert abs(actual - predicted) / actual < tol, \
            (arch, actual, predicted)
        # exact counter must match the real init bit-for-bit
        from repro.models.transformer import count_params
        assert count_params(cfg) == actual, arch


def test_full_config_param_counts():
    """Full configs hit their nameplate sizes."""
    expect = {"yi-9b": 8.8e9, "qwen3-moe-30b-a3b": 30.5e9,
              "llama4-maverick-400b-a17b": 398e9, "gemma2-9b": 9.2e9,
              "rwkv6-3b": 2.9e9, "llava-next-mistral-7b": 7.3e9,
              "gemma-7b": 8.5e9}
    from repro.models.transformer import count_params
    for arch, n in expect.items():
        got = count_params(get_config(arch))
        assert abs(got - n) / n < 0.12, (arch, got, n)


def test_gemma2_local_global_pattern():
    from repro.models.transformer import block_pattern
    cfg = get_config("gemma2-9b")
    pattern, repeat, tail = block_pattern(cfg)
    assert pattern == ("dense_local", "dense_global") and repeat == 21


def test_zamba2_shared_block_pattern():
    from repro.models.transformer import block_pattern
    cfg = get_config("zamba2-1.2b")
    pattern, repeat, tail = block_pattern(cfg)
    assert pattern == ("mamba",) * 5 + ("shared",)
    assert repeat == 6 and tail == ("mamba", "mamba")
    assert 6 * repeat + len(tail) == cfg.n_layers
