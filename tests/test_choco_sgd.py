"""CHOCO-SGD (Theorem 4) + optimization baselines on logistic regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ring, fully_connected, TopK, QSGD, Identity,
                        run_choco_sgd, experiment_lr_schedule,
                        theorem4_lr_schedule, theorem4_a, auto_gamma,
                        plain_dsgd_step, centralized_sgd_step,
                        DCDState, dcd_sgd_step, ECDState, ecd_sgd_step)
from repro.data.synthetic import make_logreg


def _quadratic(n=9, d=30, noise=0.05, seed=0):
    C = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    opt = jnp.mean(C, 0)

    def grad_fn(x, i, k):
        return (x - C[i]) + noise * jax.random.normal(k, x.shape)

    def subopt(xbar):
        return 0.5 * float(jnp.sum((xbar - opt) ** 2))
    return C, grad_fn, subopt


def test_choco_sgd_converges_quadratic():
    C, grad_fn, subopt = _quadratic()
    topo = ring(9)
    W = jnp.asarray(topo.W)
    lr = experiment_lr_schedule(1, 1.0, 20.0)
    st, trace = run_choco_sgd(jnp.zeros_like(C), W, grad_fn, TopK(fraction=0.2),
                              lr, 0.2, 1500,
                              eval_fn=lambda xb: jnp.sum((xb - jnp.mean(C, 0)) ** 2))
    assert float(trace[-1]) < 1e-2 * float(trace[0])


def test_choco_sgd_consensus_across_nodes():
    C, grad_fn, _ = _quadratic()
    topo = ring(9)
    lr = experiment_lr_schedule(1, 1.0, 20.0)
    st, _ = run_choco_sgd(jnp.zeros_like(C), jnp.asarray(topo.W), grad_fn,
                          TopK(fraction=0.2), lr, 0.2, 1500)
    spread = float(jnp.mean(jnp.sum((st.x - jnp.mean(st.x, 0)) ** 2, -1)))
    assert spread < 0.05


def test_choco_sgd_logreg_beats_noncommunicating():
    """On *sorted* (heterogeneous) data a node cannot learn alone —
    gossip must transfer information (paper §5.3)."""
    prob = make_logreg("epsilon", n_nodes=9, sorted_assignment=True,
                       m=1024, d=64)
    grad_fn = prob.make_grad_fn(batch_size=4)
    topo = ring(9)
    lr = experiment_lr_schedule(1, 300.0, 300.0)
    x0 = jnp.zeros((9, prob.d))
    _, trace = run_choco_sgd(x0, jnp.asarray(topo.W), grad_fn,
                             TopK(fraction=0.1), lr, 0.2, 1500,
                             eval_fn=prob.full_loss)
    # no-communication baseline: W = I
    _, trace_iso = run_choco_sgd(x0, jnp.eye(9), grad_fn, Identity(),
                                 lr, 1.0, 1500, eval_fn=prob.full_loss)
    assert float(trace[-1]) < float(trace_iso[-1]) - 1e-3


def test_theorem4_parameters():
    a = theorem4_a(delta=0.1, omega=0.01, kappa=10.0)
    assert a >= 410 / (0.01 * 0.01) * 0.9999
    lr = theorem4_lr_schedule(mu=1.0, a=a)
    assert float(lr(jnp.int32(0))) <= 4 / a * 1.0000001
    g = auto_gamma(0.1, 1.5, 0.01)
    assert 0 < g < 1


def test_plain_dsgd_matches_centralized_on_complete_graph():
    """Algorithm 3 on the complete graph == mini-batch SGD (Remark in §5.3)."""
    n, d = 8, 16
    C, grad_fn, _ = _quadratic(n, d, noise=0.0)
    W = jnp.asarray(fully_connected(n).W)
    X = jnp.zeros((n, d))
    x_c = jnp.zeros((d,))
    key = jax.random.PRNGKey(0)
    for i in range(50):
        k = jax.random.fold_in(key, i)
        X = plain_dsgd_step(X, W, grad_fn, 0.1, k)
        x_c = centralized_sgd_step(x_c, grad_fn, n, 0.1, k)
    np.testing.assert_allclose(np.asarray(X[0]), np.asarray(x_c), atol=1e-5)


def test_dcd_sgd_converges_mild_compression():
    """DCD works with high-precision compression (paper's observation)."""
    C, grad_fn, subopt = _quadratic(noise=0.02)
    W = jnp.asarray(ring(9).W)
    st = DCDState(x=jnp.zeros_like(C))
    key = jax.random.PRNGKey(1)
    for i in range(400):
        st = dcd_sgd_step(st, W, grad_fn, QSGD(127, rescale=False),
                          0.05, jax.random.fold_in(key, i))
    assert subopt(jnp.mean(st.x, 0)) < 0.1


def test_ecd_sgd_fragile_under_aggressive_compression():
    """ECD-SGD degrades/diverges under coarse compression while CHOCO
    converges (paper §5.3: "ECD ... always performs worse ... often
    diverges")."""
    C, grad_fn, subopt = _quadratic(noise=0.02)
    topo = ring(9)
    W = jnp.asarray(topo.W)
    comp = QSGD(2, rescale=False)
    st = ECDState(x=jnp.zeros_like(C), x_tilde=jnp.zeros_like(C),
                  t=jnp.zeros((), jnp.int32))
    key = jax.random.PRNGKey(1)
    for i in range(300):
        st = ecd_sgd_step(st, W, grad_fn, comp, 0.05, jax.random.fold_in(key, i))
    x = np.asarray(jnp.mean(st.x, 0))
    ecd_err = subopt(jnp.mean(st.x, 0)) if np.isfinite(x).all() else np.inf

    lr = experiment_lr_schedule(1, 1.0, 20.0)
    _, trace = run_choco_sgd(jnp.zeros_like(C), W, grad_fn, QSGD(2), lr,
                             0.2, 300,
                             eval_fn=lambda xb: jnp.sum((xb - jnp.mean(C, 0)) ** 2))
    choco_err = 0.5 * float(trace[-1])
    assert choco_err < max(ecd_err, 1e-6) * 10 or choco_err < 0.05


def test_ecd_sgd_runs():
    C, grad_fn, subopt = _quadratic(noise=0.02)
    W = jnp.asarray(ring(9).W)
    st = ECDState(x=jnp.zeros_like(C), x_tilde=jnp.zeros_like(C),
                  t=jnp.zeros((), jnp.int32))
    key = jax.random.PRNGKey(1)
    for i in range(50):
        st = ecd_sgd_step(st, W, grad_fn, QSGD(127, rescale=False), 0.01,
                          jax.random.fold_in(key, i))
    assert np.isfinite(float(jnp.sum(st.x)))
