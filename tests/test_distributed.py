"""Distributed gossip / trainer tests.

These need >1 device, so each test runs a short script in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (never set globally —
the assignment requires smoke tests to see 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

# every test here compiles shard_map graphs in an 8-device subprocess:
# deselect with -m "not slow" for the fast inner loop (see pytest.ini)
pytestmark = [pytest.mark.slow, pytest.mark.distributed]

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str, timeout=420):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("packed", [True, False])
def test_distributed_choco_matches_matrix_simulator(packed):
    """The shard_map/ppermute gossip — both the bucketed flat-buffer engine
    and the legacy per-leaf exchange — reproduces the (n,d) matrix simulator
    (injecting identical compressor randomness via fold-ins is impractical,
    so we use the deterministic top_k operator)."""
    run_sub(f"""
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.comm.gossip import make_gossip_exchange
        from repro.core.choco_gossip import (choco_gossip_round_efficient,
                                             init_efficient_state)
        from repro.core import ring, TopK

        n, d = 8, 96
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        comp = TopK(k=9)            # deterministic: no RNG divergence
        gamma = 0.07
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))

        # matrix simulator (W = uniform ring)
        W = jnp.asarray(ring(n).W)
        st = init_efficient_state(x0)
        for _ in range(5):
            st = choco_gossip_round_efficient(st, W, gamma, comp)

        # distributed: leaves (n, d) sharded over 'data'
        specs = {{"w": P("data", None)}}
        ex = make_gossip_exchange(mode="choco", mesh=mesh, state_specs=specs,
                                  axis="data", compressor=comp, gamma=gamma,
                                  packed={packed})
        x = {{"w": x0}}
        xh = {{"w": jnp.zeros_like(x0)}}
        s = {{"w": jnp.zeros_like(x0)}}
        for i in range(5):
            x, xh, s = ex(jax.random.PRNGKey(i), x, xh, s)
        np.testing.assert_allclose(np.asarray(x["w"]), np.asarray(st.x),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(xh["w"]), np.asarray(st.x_hat),
                                   rtol=1e-4, atol=1e-5)
        print("MATCH")
    """)


def test_distributed_packed_multi_leaf_matches_per_leaf():
    """Bucketed engine == legacy per-leaf exchange, bit for bit, on a
    multi-leaf tree with unaligned sizes (blockwise operator commutes with
    the engine's block-aligned packing)."""
    run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.core import BlockTopK

        n = 8
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        tree0 = {"a": jax.random.normal(jax.random.PRNGKey(1), (n, 384)),
                 "b": jax.random.normal(jax.random.PRNGKey(2), (n, 130)),
                 "c": jax.random.normal(jax.random.PRNGKey(3), (n, 512))}
        specs = {k: P("data", None) for k in tree0}
        comp = BlockTopK(k_per_block=5, block=128)
        outs = {}
        for packed in (True, False):
            ex = make_gossip_exchange(mode="choco", mesh=mesh,
                                      state_specs=specs, axis="data",
                                      compressor=comp, gamma=0.07,
                                      packed=packed)
            x = dict(tree0)
            xh = jax.tree.map(jnp.zeros_like, tree0)
            s = jax.tree.map(jnp.zeros_like, tree0)
            for i in range(3):
                x, xh, s = ex(jax.random.PRNGKey(i), x, xh, s)
            outs[packed] = (x, xh, s)
        for j in range(3):
            for k in tree0:
                np.testing.assert_array_equal(np.asarray(outs[True][j][k]),
                                              np.asarray(outs[False][j][k]))
        print("PACKED == PER-LEAF")
    """)


def test_distributed_allreduce_is_exact_mean():
    run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        x0 = jax.random.normal(jax.random.PRNGKey(0), (8, 33))
        ex = make_gossip_exchange(mode="allreduce", mesh=mesh,
                                  state_specs=P("data", None), axis="data")
        x, _, _ = ex(jax.random.PRNGKey(0), x0, x0 * 0, x0 * 0)
        np.testing.assert_allclose(np.asarray(x),
                                   np.broadcast_to(np.asarray(x0).mean(0), x0.shape),
                                   rtol=1e-5, atol=1e-6)
        print("OK")
    """)


def test_trainer_choco_loss_decreases():
    run_sub("""
        from repro.configs.base import get_config, ChocoConfig, InputShape
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.launch.specs import train_batch_specs
        from repro.data.synthetic import make_lm_batch_fn

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        m = build_model(cfg)
        tr = DecentralizedTrainer(model=m, choco=ChocoConfig(
                compressor="top_k", comp_kwargs=(("fraction", 0.05),)),
            mesh=mesh, n_nodes=4, optimizer=sgd(),
            lr_fn=constant_schedule(0.05))
        state = tr.init_state(jax.random.PRNGKey(0))
        next_batch = make_lm_batch_fn(cfg, seq_len=32, batch_per_node=4,
                                      n_nodes=4, heterogeneity=1.0)
        b0 = jax.tree.map(jnp.asarray, next_batch())
        step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                    jax.eval_shape(lambda: b0))
        losses = []
        for i in range(30):
            state, mets = step(state, jax.tree.map(jnp.asarray, next_batch()))
            losses.append(float(mets["loss"]))
        assert all(np.isfinite(losses)), losses
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses
        # x_hat tracks params (error feedback engages)
        xh = jnp.concatenate([a.ravel() for a in jax.tree.leaves(state.x_hat)])
        assert float(jnp.sum(jnp.abs(xh))) > 0
        print("LOSS", losses[0], "->", losses[-1])
    """)


def test_trainer_modes_plain_and_allreduce():
    run_sub("""
        from repro.configs.base import get_config, ChocoConfig
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.data.synthetic import make_lm_batch_fn

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("yi-9b", smoke=True)
        m = build_model(cfg)
        next_batch = make_lm_batch_fn(cfg, 32, 4, 4)
        for mode in ("plain", "allreduce"):
            tr = DecentralizedTrainer(model=m, choco=ChocoConfig(), mesh=mesh,
                                      n_nodes=4, optimizer=sgd(),
                                      lr_fn=constant_schedule(0.05), mode=mode)
            state = tr.init_state(jax.random.PRNGKey(0))
            b = jax.tree.map(jnp.asarray, next_batch())
            step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                        jax.eval_shape(lambda: b))
            for i in range(5):
                state, mets = step(state, jax.tree.map(jnp.asarray, next_batch()))
            assert np.isfinite(float(mets["loss"])), mode
            if mode == "allreduce":
                # exact averaging keeps replicas identical
                p = jax.tree.leaves(state.params)[0]
                np.testing.assert_allclose(np.asarray(p[0]), np.asarray(p[1]),
                                           rtol=1e-4, atol=1e-5)
        print("MODES OK")
    """)


def test_multipod_style_gossip_axis():
    """2-node gossip over 'pod' with FSDP over 'data' (multi-pod layout)."""
    run_sub("""
        from repro.configs.base import get_config, ChocoConfig
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.data.synthetic import make_lm_batch_fn

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        m = build_model(cfg)
        tr = DecentralizedTrainer(model=m,
            choco=ChocoConfig(gossip_axis="pod",
                              compressor="top_k", comp_kwargs=(("fraction", 0.1),)),
            mesh=mesh, n_nodes=2, optimizer=sgd(), lr_fn=constant_schedule(0.05))
        state = tr.init_state(jax.random.PRNGKey(0))
        next_batch = make_lm_batch_fn(cfg, 32, 4, 2)
        b = jax.tree.map(jnp.asarray, next_batch())
        step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                    jax.eval_shape(lambda: b))
        for i in range(3):
            state, mets = step(state, jax.tree.map(jnp.asarray, next_batch()))
        assert np.isfinite(float(mets["loss"]))
        print("MULTIPOD OK", float(mets["loss"]))
    """)


@pytest.mark.parametrize("topology", ["hypercube", "star", "chain",
                                      "fully_connected", "torus"])
def test_distributed_schedule_matches_simulator(topology):
    """Tentpole acceptance: the schedule-driven engine (packed AND per-leaf)
    reproduces the Algorithm-5 matrix simulator on every compiled topology —
    graphs the pre-schedule runtime could not run at all (hypercube, star,
    chain, fully-connected) now go through the same packed ppermute path."""
    run_sub(f"""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.comm.schedule import compile_schedule
        from repro.core import make_topology, TopK
        from repro.core.choco_gossip import (choco_gossip_round_efficient,
                                             init_efficient_state)

        n, d = 8, 96
        topo = make_topology("{topology}", n)
        sched = compile_schedule(topo)
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        comp = TopK(k=9)            # deterministic: no RNG divergence
        gamma = 0.07
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        W = jnp.asarray(topo.W)

        st = init_efficient_state(x0)
        for _ in range(5):
            st = choco_gossip_round_efficient(st, W, gamma, comp)

        for packed in (True, False):
            ex = make_gossip_exchange(
                mode="choco", mesh=mesh, state_specs={{"w": P("data", None)}},
                axis="data", compressor=comp, gamma=gamma, packed=packed,
                schedules=(sched,))
            x = {{"w": x0}}
            xh = {{"w": jnp.zeros_like(x0)}}
            s = {{"w": jnp.zeros_like(x0)}}
            for i in range(5):
                x, xh, s = ex(jax.random.PRNGKey(i), x, xh, s)
            np.testing.assert_allclose(np.asarray(x["w"]), np.asarray(st.x),
                                       rtol=1e-4, atol=1e-5)
            np.testing.assert_allclose(np.asarray(xh["w"]),
                                       np.asarray(st.x_hat),
                                       rtol=1e-4, atol=1e-5)
        print("SCHEDULE MATCHES SIMULATOR")
    """)


def test_schedule_engine_bitmatches_legacy_ring_torus():
    """Regression for the schedule refactor: the compiled ring and torus
    schedules must reproduce the pre-refactor hardcoded engines bit for bit
    (same ppermute data movement, same accumulation order, same weak-typed
    uniform weights).  The legacy engines are inlined here verbatim from the
    PR-1 comm/gossip.py."""
    run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import (_choco_leaf_updates, _flatten_states,
                                       _packed_self_half, shard_map,
                                       make_gossip_exchange)
        from repro.comm.packing import (bucket_dense, make_bucket_spec,
                                        unpack_leaves)
        from repro.core import BlockTopK

        comp = BlockTopK(k_per_block=5, block=128)
        gamma = 0.07

        def ring_perm(n, shift):
            return [(i, (i + shift) % n) for i in range(n)]

        def legacy_ring_packed(axis, axis_size):
            w_self = w_nbr = 1.0 / 3.0
            fwd, bwd = ring_perm(axis_size, 1), ring_perm(axis_size, -1)
            def local_fn(key, x_half, x_hat, s):
                key = jax.random.fold_in(key, jax.lax.axis_index(axis))
                leaves_h, leaves_hat, leaves_s, treedef = _flatten_states(
                    x_half, x_hat, s)
                spec = make_bucket_spec(leaves_hat, align=128)
                payloads, q_leaves, new_hat = _packed_self_half(
                    comp, key, leaves_h, leaves_hat, spec)
                got_l = jax.lax.ppermute(payloads, axis, fwd)
                got_r = jax.lax.ppermute(payloads, axis, bwd)
                nbr_bufs = [bucket_dense(l, b) + bucket_dense(r, b)
                            for l, r, b in zip(got_l, got_r, spec.buckets)]
                nbr_leaves = unpack_leaves(spec, nbr_bufs)
                new_s, new_x = _choco_leaf_updates(
                    leaves_h, leaves_s, q_leaves, nbr_leaves, new_hat,
                    w_self, w_nbr, gamma)
                u = treedef.unflatten
                return u(new_x), u(new_hat), u(new_s)
            return local_fn

        def legacy_torus_packed(axes, sizes):
            n_edges = sum(2 if n > 2 else (1 if n == 2 else 0) for n in sizes)
            w = 1.0 / (1.0 + n_edges)
            def local_fn(key, x_half, x_hat, s):
                for a in axes:
                    key = jax.random.fold_in(key, jax.lax.axis_index(a))
                leaves_h, leaves_hat, leaves_s, treedef = _flatten_states(
                    x_half, x_hat, s)
                spec = make_bucket_spec(leaves_hat, align=128)
                payloads, q_leaves, new_hat = _packed_self_half(
                    comp, key, leaves_h, leaves_hat, spec)
                nbr_bufs = [jnp.zeros((b.size,), b.dtype) for b in spec.buckets]
                for a, n in zip(axes, sizes):
                    if n < 2:
                        continue
                    got = jax.lax.ppermute(payloads, a, ring_perm(n, 1))
                    nbr_bufs = [acc + bucket_dense(g, b)
                                for acc, g, b in zip(nbr_bufs, got, spec.buckets)]
                    if n > 2:
                        got = jax.lax.ppermute(payloads, a, ring_perm(n, -1))
                        nbr_bufs = [acc + bucket_dense(g, b)
                                    for acc, g, b in zip(nbr_bufs, got, spec.buckets)]
                nbr_leaves = unpack_leaves(spec, nbr_bufs)
                new_s, new_x = _choco_leaf_updates(
                    leaves_h, leaves_s, q_leaves, nbr_leaves, new_hat,
                    w, w, gamma)
                u = treedef.unflatten
                return u(new_x), u(new_hat), u(new_s)
            return local_fn

        tree0 = {"a": jax.random.normal(jax.random.PRNGKey(1), (8, 384)),
                 "b": jax.random.normal(jax.random.PRNGKey(2), (8, 130)),
                 "c": jax.random.normal(jax.random.PRNGKey(3), (8, 512))}

        def run(ex, specs_tree):
            x = dict(tree0)
            xh = jax.tree.map(jnp.zeros_like, tree0)
            s = jax.tree.map(jnp.zeros_like, tree0)
            outs = []
            for i in range(3):
                x, xh, s = ex(jax.random.PRNGKey(i), x, xh, s)
                outs.append((x, xh, s))
            return outs

        # -- ring on one axis ------------------------------------------------
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        specs = {k: P("data", None) for k in tree0}
        legacy = shard_map(legacy_ring_packed("data", 8), mesh=mesh,
                           in_specs=(P(), specs, specs, specs),
                           out_specs=(specs, specs, specs))
        new = make_gossip_exchange(mode="choco", mesh=mesh, state_specs=specs,
                                   axis="data", compressor=comp, gamma=gamma)
        for (xo, xho, so), (xn, xhn, sn) in zip(run(legacy, specs),
                                                run(new, specs)):
            for k in tree0:
                np.testing.assert_array_equal(np.asarray(xo[k]), np.asarray(xn[k]))
                np.testing.assert_array_equal(np.asarray(xho[k]), np.asarray(xhn[k]))
                np.testing.assert_array_equal(np.asarray(so[k]), np.asarray(sn[k]))

        # -- torus on a (pod, data) axis pair --------------------------------
        mesh = jax.make_mesh((2, 4, 1), ("pod", "data", "model"))
        specs = {k: P(("pod", "data"), None) for k in tree0}
        legacy = shard_map(legacy_torus_packed(("pod", "data"), (2, 4)),
                           mesh=mesh, in_specs=(P(), specs, specs, specs),
                           out_specs=(specs, specs, specs))
        new = make_gossip_exchange(mode="choco", mesh=mesh, state_specs=specs,
                                   axis=("pod", "data"), compressor=comp,
                                   gamma=gamma)
        for (xo, xho, so), (xn, xhn, sn) in zip(run(legacy, specs),
                                                run(new, specs)):
            for k in tree0:
                np.testing.assert_array_equal(np.asarray(xo[k]), np.asarray(xn[k]))
                np.testing.assert_array_equal(np.asarray(xho[k]), np.asarray(xhn[k]))
                np.testing.assert_array_equal(np.asarray(so[k]), np.asarray(sn[k]))
        print("BITMATCH OK")
    """)


def test_multi_step_gossip_beats_single_step():
    """gossip_steps=3 (three CHOCO consensus rounds per SGD step, one packed
    spec) must contract consensus error strictly further than one round —
    the Hashemi et al. multiple-gossip-steps effect."""
    run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.comm.schedule import compile_schedule
        from repro.core import make_topology, TopK

        n, d = 8, 256
        topo = make_topology("hypercube", n)
        comp = TopK(k=64)
        # practical consensus stepsize: the Theorem-2 worst-case gamma
        # contracts by <0.2% per round, far too slow to separate k in one
        # SGD step (it is a safety bound, not the tuned value)
        gamma = 0.4
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        xbar = jnp.mean(x0, axis=0, keepdims=True)

        def consensus_err(x):
            return float(jnp.mean(jnp.sum((x - xbar) ** 2, axis=-1)))

        errs = {}
        for k in (1, 3):
            ex = make_gossip_exchange(
                mode="choco", mesh=mesh, state_specs=P("data", None),
                axis="data", compressor=comp, gamma=gamma,
                schedules=(compile_schedule(topo),), gossip_steps=k)
            x, _, _ = ex(jax.random.PRNGKey(0), x0, jnp.zeros_like(x0),
                         jnp.zeros_like(x0))
            errs[k] = consensus_err(x)
        print("consensus err k=1:", errs[1], "k=3:", errs[3])
        assert errs[3] < errs[1] * 0.9, errs
        print("MULTI-STEP OK")
    """)


def test_hypercube_packed_launch_count_end_to_end():
    """Acceptance: hypercube on n=8 simulated devices runs end-to-end
    through the packed engine, and the compiled train step issues at most
    2*log2(n) collective-permute launches per gossip round (payload pairs
    per bucket; one ppermute per dimension-exchange round)."""
    run_sub("""
        import math
        from repro.configs.base import get_config, ChocoConfig
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.data.synthetic import make_lm_batch_fn
        from repro.analysis.roofline import parse_collectives

        mesh = jax.make_mesh((8, 1), ("data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        m = build_model(cfg)
        tr = DecentralizedTrainer(model=m, choco=ChocoConfig(
                compressor="top_k", comp_kwargs=(("fraction", 0.01),),
                topology="hypercube"),
            mesh=mesh, n_nodes=8, optimizer=sgd(),
            lr_fn=constant_schedule(0.05))
        n_rounds = tr.schedules[0].n_rounds
        assert n_rounds == 3, n_rounds                    # log2(8)

        state = tr.init_state(jax.random.PRNGKey(0))
        nb = make_lm_batch_fn(cfg, 32, 4, 8)
        b = jax.tree.map(jnp.asarray, nb())
        step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                    jax.eval_shape(lambda: b))
        compiled = step.lower(state, b).compile()
        st = parse_collectives(compiled.as_text(), 8)
        permutes = st.counts["collective-permute"]
        per_round = permutes / n_rounds
        bound = 2 * math.log2(8)
        print("permute launches:", permutes, "rounds:", n_rounds,
              "per-round:", per_round, "bound:", bound)
        assert 0 < per_round <= bound, (permutes, n_rounds, bound)

        losses = []
        for i in range(8):
            state, mets = step(state, jax.tree.map(jnp.asarray, nb()))
            losses.append(float(mets["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses
        print("HYPERCUBE E2E OK", losses[0], "->", losses[-1])
    """)


def test_trainer_gossip_steps_and_time_varying():
    """Trainer end-to-end with gossip_steps=2 cycling a time-varying
    ring,hypercube schedule sequence."""
    run_sub("""
        from repro.configs.base import get_config, ChocoConfig
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.data.synthetic import make_lm_batch_fn

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        m = build_model(cfg)
        tr = DecentralizedTrainer(model=m, choco=ChocoConfig(
                compressor="top_k", comp_kwargs=(("fraction", 0.05),),
                topology="ring,hypercube", gossip_steps=2),
            mesh=mesh, n_nodes=4, optimizer=sgd(),
            lr_fn=constant_schedule(0.05))
        assert [s.name for s in tr.schedules] == ["ring", "hypercube"]
        state = tr.init_state(jax.random.PRNGKey(0))
        nb = make_lm_batch_fn(cfg, 32, 4, 4)
        b = jax.tree.map(jnp.asarray, nb())
        step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                    jax.eval_shape(lambda: b))
        losses = []
        for i in range(10):
            state, mets = step(state, jax.tree.map(jnp.asarray, nb()))
            losses.append(float(mets["loss"]))
        assert all(np.isfinite(losses)) and losses[-1] < losses[0], losses
        print("TIME-VARYING K-STEP OK")
    """)
