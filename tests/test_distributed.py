"""Distributed gossip / trainer tests.

These need >1 device, so each test runs a short script in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (never set globally —
the assignment requires smoke tests to see 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

# every test here compiles shard_map graphs in an 8-device subprocess:
# deselect with -m "not slow" for the fast inner loop (see pytest.ini)
pytestmark = [pytest.mark.slow, pytest.mark.distributed]

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str, timeout=420):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("packed", [True, False])
def test_distributed_choco_matches_matrix_simulator(packed):
    """The shard_map/ppermute gossip — both the bucketed flat-buffer engine
    and the legacy per-leaf exchange — reproduces the (n,d) matrix simulator
    (injecting identical compressor randomness via fold-ins is impractical,
    so we use the deterministic top_k operator)."""
    run_sub(f"""
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.comm.gossip import make_gossip_exchange
        from repro.core.choco_gossip import (choco_gossip_round_efficient,
                                             init_efficient_state)
        from repro.core import ring, TopK

        n, d = 8, 96
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        comp = TopK(k=9)            # deterministic: no RNG divergence
        gamma = 0.07
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))

        # matrix simulator (W = uniform ring)
        W = jnp.asarray(ring(n).W)
        st = init_efficient_state(x0)
        for _ in range(5):
            st = choco_gossip_round_efficient(st, W, gamma, comp)

        # distributed: leaves (n, d) sharded over 'data'
        specs = {{"w": P("data", None)}}
        ex = make_gossip_exchange(mode="choco", mesh=mesh, state_specs=specs,
                                  axis="data", compressor=comp, gamma=gamma,
                                  packed={packed})
        x = {{"w": x0}}
        xh = {{"w": jnp.zeros_like(x0)}}
        s = {{"w": jnp.zeros_like(x0)}}
        for i in range(5):
            x, xh, s = ex(jax.random.PRNGKey(i), x, xh, s)
        np.testing.assert_allclose(np.asarray(x["w"]), np.asarray(st.x),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(xh["w"]), np.asarray(st.x_hat),
                                   rtol=1e-4, atol=1e-5)
        print("MATCH")
    """)


def test_distributed_packed_multi_leaf_matches_per_leaf():
    """Bucketed engine == legacy per-leaf exchange, bit for bit, on a
    multi-leaf tree with unaligned sizes (blockwise operator commutes with
    the engine's block-aligned packing)."""
    run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.core import BlockTopK

        n = 8
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        tree0 = {"a": jax.random.normal(jax.random.PRNGKey(1), (n, 384)),
                 "b": jax.random.normal(jax.random.PRNGKey(2), (n, 130)),
                 "c": jax.random.normal(jax.random.PRNGKey(3), (n, 512))}
        specs = {k: P("data", None) for k in tree0}
        comp = BlockTopK(k_per_block=5, block=128)
        outs = {}
        for packed in (True, False):
            ex = make_gossip_exchange(mode="choco", mesh=mesh,
                                      state_specs=specs, axis="data",
                                      compressor=comp, gamma=0.07,
                                      packed=packed)
            x = dict(tree0)
            xh = jax.tree.map(jnp.zeros_like, tree0)
            s = jax.tree.map(jnp.zeros_like, tree0)
            for i in range(3):
                x, xh, s = ex(jax.random.PRNGKey(i), x, xh, s)
            outs[packed] = (x, xh, s)
        for j in range(3):
            for k in tree0:
                np.testing.assert_array_equal(np.asarray(outs[True][j][k]),
                                              np.asarray(outs[False][j][k]))
        print("PACKED == PER-LEAF")
    """)


def test_distributed_allreduce_is_exact_mean():
    run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        x0 = jax.random.normal(jax.random.PRNGKey(0), (8, 33))
        ex = make_gossip_exchange(mode="allreduce", mesh=mesh,
                                  state_specs=P("data", None), axis="data")
        x, _, _ = ex(jax.random.PRNGKey(0), x0, x0 * 0, x0 * 0)
        np.testing.assert_allclose(np.asarray(x),
                                   np.broadcast_to(np.asarray(x0).mean(0), x0.shape),
                                   rtol=1e-5, atol=1e-6)
        print("OK")
    """)


def test_trainer_choco_loss_decreases():
    run_sub("""
        from repro.configs.base import get_config, ChocoConfig, InputShape
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.launch.specs import train_batch_specs
        from repro.data.synthetic import make_lm_batch_fn

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        m = build_model(cfg)
        tr = DecentralizedTrainer(model=m, choco=ChocoConfig(
                compressor="top_k", comp_kwargs=(("fraction", 0.05),)),
            mesh=mesh, n_nodes=4, optimizer=sgd(),
            lr_fn=constant_schedule(0.05))
        state = tr.init_state(jax.random.PRNGKey(0))
        next_batch = make_lm_batch_fn(cfg, seq_len=32, batch_per_node=4,
                                      n_nodes=4, heterogeneity=1.0)
        b0 = jax.tree.map(jnp.asarray, next_batch())
        step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                    jax.eval_shape(lambda: b0))
        losses = []
        for i in range(30):
            state, mets = step(state, jax.tree.map(jnp.asarray, next_batch()))
            losses.append(float(mets["loss"]))
        assert all(np.isfinite(losses)), losses
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses
        # x_hat tracks params (error feedback engages)
        xh = jnp.concatenate([a.ravel() for a in jax.tree.leaves(state.x_hat)])
        assert float(jnp.sum(jnp.abs(xh))) > 0
        print("LOSS", losses[0], "->", losses[-1])
    """)


def test_trainer_modes_plain_and_allreduce():
    run_sub("""
        from repro.configs.base import get_config, ChocoConfig
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.data.synthetic import make_lm_batch_fn

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("yi-9b", smoke=True)
        m = build_model(cfg)
        next_batch = make_lm_batch_fn(cfg, 32, 4, 4)
        for mode in ("plain", "allreduce"):
            tr = DecentralizedTrainer(model=m, choco=ChocoConfig(), mesh=mesh,
                                      n_nodes=4, optimizer=sgd(),
                                      lr_fn=constant_schedule(0.05), mode=mode)
            state = tr.init_state(jax.random.PRNGKey(0))
            b = jax.tree.map(jnp.asarray, next_batch())
            step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                        jax.eval_shape(lambda: b))
            for i in range(5):
                state, mets = step(state, jax.tree.map(jnp.asarray, next_batch()))
            assert np.isfinite(float(mets["loss"])), mode
            if mode == "allreduce":
                # exact averaging keeps replicas identical
                p = jax.tree.leaves(state.params)[0]
                np.testing.assert_allclose(np.asarray(p[0]), np.asarray(p[1]),
                                           rtol=1e-4, atol=1e-5)
        print("MODES OK")
    """)


def test_multipod_style_gossip_axis():
    """2-node gossip over 'pod' with FSDP over 'data' (multi-pod layout)."""
    run_sub("""
        from repro.configs.base import get_config, ChocoConfig
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.data.synthetic import make_lm_batch_fn

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        m = build_model(cfg)
        tr = DecentralizedTrainer(model=m,
            choco=ChocoConfig(gossip_axis="pod",
                              compressor="top_k", comp_kwargs=(("fraction", 0.1),)),
            mesh=mesh, n_nodes=2, optimizer=sgd(), lr_fn=constant_schedule(0.05))
        state = tr.init_state(jax.random.PRNGKey(0))
        next_batch = make_lm_batch_fn(cfg, 32, 4, 2)
        b = jax.tree.map(jnp.asarray, next_batch())
        step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                    jax.eval_shape(lambda: b))
        for i in range(3):
            state, mets = step(state, jax.tree.map(jnp.asarray, next_batch()))
        assert np.isfinite(float(mets["loss"]))
        print("MULTIPOD OK", float(mets["loss"]))
    """)
