"""Fused Pallas gossip path (kernels/dispatch.py + comm/gossip.py).

Cross-backend parity contract for `--kernel-backend`, asserted per
compressor on the real 8-device shard_map engine:

  * wire payloads are identical — witnessed by round-1 ``x_hat`` being
    bit-exact across backends (x_hat moves only by the dequantized wire
    codes, so equal x_hat == equal codes+scales);
  * all state accumulates only FMA-contraction rounding across rounds —
    bounded at 1e-5 over 5 rounds, measured drift is ~1e-6.  The drift
    source is the EF kernel's x-update compiling separately from the
    in-context jnp graph (different mul+add contraction choices), so it
    applies to every compressor, deterministic ones included: round-2
    deltas quantize the ulp-drifted x.

Plus the launch-count proof behind BENCH_fused.json (exactly
``2 * n_buckets * gossip_steps`` pallas_call equations per exchange: one
fused quantize+pack and one fused dequant+EF-update per bucket per
round), the checkpoint-fingerprint invariance required by the issue, and
the pre-jax CLI version gate.  Multi-device tests follow the
tests/test_distributed.py subprocess pattern.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(ROOT, "src")


def run_sub(body: str, timeout=560):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep + ROOT)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# fast tier — CLI version gate (pre-jax, in-process)
# ---------------------------------------------------------------------------

def test_cli_rejects_pallas_on_old_jax(monkeypatch, capsys):
    """--kernel-backend pallas fails fast (argparse SystemExit 2) when the
    installed jax predates the Pallas toolchain floor.  The gate reads
    package metadata, never imports jax, so it is monkeypatchable and
    cheap."""
    from repro.kernels import dispatch
    from repro.launch.train import main
    monkeypatch.setattr(dispatch, "jax_version_tuple", lambda: (0, 4, 20))
    with pytest.raises(SystemExit) as ei:
        main(["--arch", "qwen3-1.7b", "--smoke", "--kernel-backend",
              "pallas"])
    assert ei.value.code == 2
    assert "jax" in capsys.readouterr().err.lower()


# ---------------------------------------------------------------------------
# slow tier — 8-device engine parity / launch counts / fingerprint
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.distributed
@pytest.mark.parametrize("comp", [
    "QSGD(s=16)",
    "QSGD(s=200)",                   # int16 wire format
    "SignNorm()",
    "TopK(k=9)",
    "Identity()",
])
def test_fused_engine_cross_backend_parity(comp):
    """jnp vs pallas backend on the multi-leaf packed engine, 5 rounds.
    Round-1 x_hat is always bit-exact (the wire witness); all later state
    drifts only at FMA rounding level (see module docstring)."""
    run_sub(f"""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.core import QSGD, SignNorm, TopK, Identity

        n = 8
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        tree0 = {{"a": jax.random.normal(jax.random.PRNGKey(1), (n, 384)),
                  "b": jax.random.normal(jax.random.PRNGKey(2), (n, 130)),
                  "c": jax.random.normal(jax.random.PRNGKey(3), (n, 512))}}
        specs = {{k: P("data", None) for k in tree0}}
        outs, r1hat = {{}}, {{}}
        for bk in ("jnp", "pallas"):
            ex = make_gossip_exchange(mode="choco", mesh=mesh,
                                      state_specs=specs, axis="data",
                                      compressor={comp}, gamma=0.07,
                                      kernel_backend=bk)
            x = dict(tree0)
            xh = jax.tree.map(jnp.zeros_like, tree0)
            s = jax.tree.map(jnp.zeros_like, tree0)
            for i in range(5):
                x, xh, s = ex(jax.random.PRNGKey(i), x, xh, s)
                if i == 0:
                    r1hat[bk] = xh
            outs[bk] = (x, xh, s)
        for k in tree0:
            np.testing.assert_array_equal(
                np.asarray(r1hat["jnp"][k]), np.asarray(r1hat["pallas"][k]))
        for j in range(3):
            for k in tree0:
                np.testing.assert_allclose(np.asarray(outs["jnp"][j][k]),
                                           np.asarray(outs["pallas"][j][k]),
                                           rtol=0, atol=1e-5)
        print("PARITY")
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_fused_pallas_engine_matches_matrix_simulator():
    """The pallas-backed engine reproduces the (n, d) matrix simulator with
    the same tolerances the jnp engine is held to (deterministic TopK so
    compressor randomness cannot diverge)."""
    run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.core.choco_gossip import (choco_gossip_round_efficient,
                                             init_efficient_state)
        from repro.core import ring, TopK

        n, d = 8, 128
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        comp = TopK(k=9)
        gamma = 0.07
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))

        W = jnp.asarray(ring(n).W)
        st = init_efficient_state(x0)
        for _ in range(5):
            st = choco_gossip_round_efficient(st, W, gamma, comp)

        specs = {"w": P("data", None)}
        ex = make_gossip_exchange(mode="choco", mesh=mesh, state_specs=specs,
                                  axis="data", compressor=comp, gamma=gamma,
                                  kernel_backend="pallas")
        x = {"w": x0}
        xh = {"w": jnp.zeros_like(x0)}
        s = {"w": jnp.zeros_like(x0)}
        for i in range(5):
            x, xh, s = ex(jax.random.PRNGKey(i), x, xh, s)
        np.testing.assert_allclose(np.asarray(x["w"]), np.asarray(st.x),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(xh["w"]), np.asarray(st.x_hat),
                                   rtol=1e-4, atol=1e-5)
        print("MATCH")
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_fused_launch_count_per_bucket_per_round():
    """Exactly 2 fused kernel launches per bucket per gossip round — one
    quantize+pack, one dequant+EF-update — and zero on the jnp backend.
    This is the structural claim BENCH_fused.json's stream audit rests
    on: more launches would mean unfused glue re-reading the buckets."""
    run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.comm.packing import make_bucket_spec
        from repro.core import QSGD
        from repro.analysis.jaxpr_audit import count_pallas_calls

        n, steps = 8, 3
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        tree0 = {"a": jax.random.normal(jax.random.PRNGKey(1), (n, 384)),
                 "b": jax.random.normal(jax.random.PRNGKey(2), (n, 4, 130))}
        specs = {k: P("data", None) for k in tree0}
        local = [jax.ShapeDtypeStruct((1,) + v.shape[1:], v.dtype)
                 for v in tree0.values()]
        spec = make_bucket_spec(local)
        counts = {}
        for bk in ("jnp", "pallas"):
            ex = make_gossip_exchange(mode="choco", mesh=mesh,
                                      state_specs=specs, axis="data",
                                      compressor=QSGD(s=16), gamma=0.07,
                                      gossip_steps=steps, kernel_backend=bk)
            z = jax.tree.map(jnp.zeros_like, tree0)
            jaxpr = jax.make_jaxpr(ex)(jax.random.PRNGKey(0), tree0, z, z)
            counts[bk] = count_pallas_calls(jaxpr.jaxpr)
        assert counts["jnp"] == 0, counts
        assert counts["pallas"] == 2 * spec.n_buckets * steps, (
            counts, spec.n_buckets)
        print("LAUNCHES", counts)
    """)


@pytest.mark.slow
@pytest.mark.distributed
def test_kernel_backend_never_in_fingerprint():
    """Flipping --kernel-backend must not change the checkpoint
    fingerprint or state layout: a run restarted on a host without the
    Pallas toolchain has to restore bit-compatibly."""
    run_sub("""
        from repro.configs.base import get_config, ChocoConfig
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import make_optimizer, cosine_schedule
        from repro.launch.mesh import make_mesh

        cfg = get_config("qwen3-1.7b", smoke=True)
        model = build_model(cfg)
        mesh = make_mesh((8, 1), ("data", "model"))
        fps, layouts = [], []
        for bk in ("jnp", "pallas", "auto"):
            tr = DecentralizedTrainer(
                model=model,
                choco=ChocoConfig(compressor="qsgd",
                                  comp_kwargs=(("s", 16),),
                                  gossip_axis="data", kernel_backend=bk),
                mesh=mesh, n_nodes=8,
                optimizer=make_optimizer("momentum"),
                lr_fn=cosine_schedule(0.1, warmup=10, total=100),
                mode="choco")
            fps.append(tr.fingerprint())
            state = tr.init_state(jax.random.PRNGKey(0))
            layouts.append(jax.tree.map(
                lambda l: (l.shape, str(l.dtype)), state.params))
        assert fps[0] == fps[1] == fps[2], fps
        assert layouts[0] == layouts[1] == layouts[2]
        print("FINGERPRINT", fps[0])
    """)
