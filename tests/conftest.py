import os
import sys

# tests run on the single real CPU device; multi-device tests spawn
# subprocesses with their own XLA_FLAGS (never set globally here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
