"""Roofline machinery: HLO collective parsing + term arithmetic."""
import numpy as np

from repro.analysis.roofline import (parse_collectives, Roofline,
                                     PEAK_FLOPS, HBM_BW, ICI_BW,
                                     model_flops_for)
from repro.configs.base import get_config, INPUT_SHAPES


FAKE_HLO = """
HloModule test
ENTRY main {
  %p0 = f32[16,2048]{1,0} parameter(0)
  %ag = f32[16,2048]{1,0} all-gather(%p0), replica_groups=[2,8]<=[16], dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = s8[512]{0} collective-permute(%q), source_target_pairs={{0,1},{1,2}}
  %rs = f32[128]{0} reduce-scatter(%y), replica_groups=[4,4]<=[16], dimensions={0}
  %a2a = bf16[8,64]{1,0} all-to-all(%z), replica_groups=[2,8]<=[16]
  %agd = f32[4]{0} all-gather-done(%h)
}
"""


def test_parse_collective_counts():
    st = parse_collectives(FAKE_HLO, n_devices=16)
    assert st.counts["all-gather"] == 1
    assert st.counts["all-reduce"] == 1
    assert st.counts["collective-permute"] == 1
    assert st.counts["reduce-scatter"] == 1
    assert st.counts["all-to-all"] == 1


def test_parse_collective_bytes():
    st = parse_collectives(FAKE_HLO, n_devices=16)
    ag = 16 * 2048 * 4
    assert abs(st.wire_bytes["all-gather"] - ag * 7 / 8) < 1
    ar = 1024 * 2
    assert abs(st.wire_bytes["all-reduce"] - 2 * ar * 3 / 4) < 1
    assert st.wire_bytes["collective-permute"] == 512


def test_roofline_terms():
    rl = Roofline(flops=PEAK_FLOPS, bytes_accessed=HBM_BW / 2,
                  wire_bytes=ICI_BW * 2, n_devices=4, model_flops=PEAK_FLOPS)
    assert abs(rl.compute_s - 1.0) < 1e-9
    assert abs(rl.memory_s - 0.5) < 1e-9
    assert abs(rl.collective_s - 2.0) < 1e-9
    assert rl.dominant == "collective"
    assert abs(rl.useful_flop_ratio - 0.25) < 1e-9
    assert rl.step_time_s == rl.collective_s


def test_model_flops_modes():
    cfg = get_config("yi-9b")
    t = model_flops_for(cfg, INPUT_SHAPES["train_4k"])
    p = model_flops_for(cfg, INPUT_SHAPES["prefill_32k"])
    d = model_flops_for(cfg, INPUT_SHAPES["decode_32k"])
    n = cfg.n_params()
    assert abs(t - 6 * n * 256 * 4096) / t < 1e-9
    assert abs(p - 2 * n * 32 * 32768) / p < 1e-9
    assert abs(d - 2 * n * 128) / d < 1e-9


def test_moe_uses_active_params():
    cfg = get_config("qwen3-moe-30b-a3b")
    t = model_flops_for(cfg, INPUT_SHAPES["train_4k"])
    assert t < 6 * cfg.n_params() * 256 * 4096 / 3   # far below dense count
