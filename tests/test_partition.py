"""Property suite for the Dirichlet partitioner (data/partition.py) plus
the TokenStream skew wiring and the vocab-slice remainder regression."""
import numpy as np
import pytest

from optional_hypothesis import HAVE_HYPOTHESIS, given, settings, st

from repro.data.partition import (data_skew_tv, dirichlet_class_shares,
                                  dirichlet_shards, mean_tv_distance,
                                  node_label_distributions)
from repro.data.synthetic import TokenStream, make_logreg


def _labels(rng, m, n_classes):
    return rng.integers(0, n_classes, size=m).astype(np.int64)


# ---------------------------------------------------------------------------
# dirichlet_shards: conservation, disjointness, reproducibility
# ---------------------------------------------------------------------------


class TestShardInvariants:
    @pytest.mark.parametrize("alpha", [0.05, 0.5, 1.0, 10.0, 1e4,
                                       float("inf")])
    @pytest.mark.parametrize("seed", [0, 7])
    def test_counts_conserved_and_disjoint(self, alpha, seed):
        """Every node gets exactly m // n samples; no sample appears on two
        nodes — for every (alpha, seed)."""
        rng = np.random.default_rng(seed)
        labels = _labels(rng, 1000, 10)
        shards = dirichlet_shards(labels, 8, alpha, seed=seed)
        assert shards.shape == (8, 125)
        flat = shards.ravel()
        assert len(np.unique(flat)) == flat.size            # disjoint
        assert flat.min() >= 0 and flat.max() < 1000

    def test_bit_reproducible_from_seed(self):
        rng = np.random.default_rng(3)
        labels = _labels(rng, 640, 5)
        a = dirichlet_shards(labels, 8, 0.3, seed=11)
        b = dirichlet_shards(labels, 8, 0.3, seed=11)
        np.testing.assert_array_equal(a, b)
        c = dirichlet_shards(labels, 8, 0.3, seed=12)
        assert not np.array_equal(a, c)

    def test_alpha_inf_near_uniform(self):
        """alpha -> inf recovers the IID split: per-node label TV ~ 0."""
        rng = np.random.default_rng(0)
        labels = _labels(rng, 8000, 10)
        shards = dirichlet_shards(labels, 8, float("inf"), seed=0)
        tv = data_skew_tv(labels, shards)
        assert tv < 0.08, tv

    def test_alpha_small_near_disjoint(self):
        """alpha -> 0 recovers the sorted split: each node's shard is
        dominated by very few labels (high TV from the mean)."""
        rng = np.random.default_rng(0)
        labels = _labels(rng, 8000, 10)
        shards = dirichlet_shards(labels, 8, 1e-3, seed=0)
        tv = data_skew_tv(labels, shards)
        assert tv > 0.5, tv
        # skew is monotone-ish across the sweep endpoints
        assert tv > data_skew_tv(
            labels, dirichlet_shards(labels, 8, 100.0, seed=0))

    def test_alpha_nonpositive_rejected(self):
        labels = np.zeros(64, dtype=np.int64)
        with pytest.raises(ValueError, match="> 0"):
            dirichlet_shards(labels, 4, 0.0)
        with pytest.raises(ValueError, match="> 0"):
            dirichlet_shards(labels, 4, -1.5)

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis missing")
    @settings(max_examples=25, deadline=None)
    @given(alpha=st.floats(min_value=0.01, max_value=1e4),
           seed=st.integers(min_value=0, max_value=2**31 - 1),
           n_nodes=st.sampled_from([2, 4, 8]),
           n_classes=st.integers(min_value=2, max_value=12))
    def test_property_conserved_disjoint_reproducible(self, alpha, seed,
                                                      n_nodes, n_classes):
        """Hypothesis sweep of the three structural invariants over the
        whole (alpha, seed, n, C) space — including non-divisible m."""
        rng = np.random.default_rng(seed % 1000)
        m = 991                                              # prime: m % n != 0
        labels = _labels(rng, m, n_classes)
        shards = dirichlet_shards(labels, n_nodes, alpha, seed=seed)
        m_per = m // n_nodes
        assert shards.shape == (n_nodes, m_per)
        flat = shards.ravel()
        assert len(np.unique(flat)) == flat.size
        np.testing.assert_array_equal(
            shards, dirichlet_shards(labels, n_nodes, alpha, seed=seed))


# ---------------------------------------------------------------------------
# shares / divergence helpers
# ---------------------------------------------------------------------------


class TestSharesAndDivergence:
    def test_shares_rows_normalized(self):
        rng = np.random.default_rng(0)
        s = dirichlet_class_shares(10, 8, 0.2, rng)
        assert s.shape == (10, 8)
        np.testing.assert_allclose(s.sum(axis=1), 1.0, atol=1e-9)
        assert (s >= 0).all()

    def test_shares_inf_is_exactly_uniform(self):
        rng = np.random.default_rng(0)
        s = dirichlet_class_shares(6, 4, float("inf"), rng)
        np.testing.assert_array_equal(s, np.full((6, 4), 0.25))

    def test_mean_tv_bounds(self):
        uniform = np.full((4, 10), 0.1)
        assert mean_tv_distance(uniform) == 0.0
        disjoint = np.eye(4)
        assert mean_tv_distance(disjoint) == pytest.approx(0.75)

    def test_node_label_distributions(self):
        labels = np.array([0, 0, 1, 1, 2, 2], dtype=np.int64)
        idx = np.array([[0, 1, 2], [3, 4, 5]])
        p = node_label_distributions(labels, idx)
        np.testing.assert_allclose(p[0], [2 / 3, 1 / 3, 0.0])
        np.testing.assert_allclose(p[1], [0.0, 1 / 3, 2 / 3])


# ---------------------------------------------------------------------------
# TokenStream wiring + the vocab-slice remainder regression
# ---------------------------------------------------------------------------


class TestTokenStreamSkew:
    def test_remainder_slice_covers_full_vocab(self):
        """Regression: at heterogeneity=1.0 with V % n != 0 the last node's
        slice must absorb the remainder — previously tokens
        [n*(V//n), V) had only the (1-h)=0 background mass, so the union of
        node supports missed part of the vocabulary."""
        ts = TokenStream(vocab_size=103, seq_len=8, batch_per_node=2,
                         n_nodes=4, heterogeneity=1.0)
        probs = ts.node_probs()
        support = (probs > 1e-12).any(axis=0)
        assert support.all(), np.flatnonzero(~support)
        # and the remainder went to the LAST node, not nowhere
        assert (probs[-1][4 * (103 // 4):] > 1e-12).all()

    def test_skew_alpha_overrides_heterogeneity(self):
        ts = TokenStream(vocab_size=64, seq_len=8, batch_per_node=2,
                         n_nodes=4, heterogeneity=0.0, skew_alpha=0.05)
        assert ts.skew_tv() > 0.3
        iid = TokenStream(vocab_size=64, seq_len=8, batch_per_node=2,
                          n_nodes=4, heterogeneity=0.0)
        assert iid.skew_tv() == pytest.approx(0.0)

    def test_skew_tv_monotone_in_alpha(self):
        tvs = [TokenStream(vocab_size=64, seq_len=8, batch_per_node=2,
                           n_nodes=4, skew_alpha=a).skew_tv()
               for a in (0.05, 1.0, 1e3)]
        assert tvs[0] > tvs[1] > tvs[2]

    def test_stream_samples_respect_skew(self):
        ts = TokenStream(vocab_size=32, seq_len=64, batch_per_node=8,
                         n_nodes=2, skew_alpha=0.01, seed=0)
        batch = next(iter(ts))
        assert batch["tokens"].shape == (2, 8, 64)
        probs = ts.node_probs()
        # each node's empirical support should concentrate where its
        # sampling distribution does
        for i in range(2):
            toks = np.asarray(batch["tokens"][i]).ravel()
            top = set(np.argsort(probs[i])[-8:].tolist())
            frac = np.mean([t in top for t in toks])
            assert frac > 0.5, (i, frac)


class TestLogRegSkew:
    def test_make_logreg_dirichlet_path(self):
        p = make_logreg("epsilon", 4, m=512, d=32, skew_alpha=0.05)
        idx = np.asarray(p.node_index)
        assert idx.shape == (4, 128)
        assert len(np.unique(idx.ravel())) == idx.size
        labels = (np.asarray(p.b) > 0).astype(np.int64)
        assert data_skew_tv(labels, idx) > 0.3

    def test_make_logreg_skew_vs_sorted_exclusive(self):
        with pytest.raises(ValueError, match="mutually exclusive"):
            make_logreg("epsilon", 4, m=512, d=32, skew_alpha=1.0,
                        sorted_assignment=True)

    def test_make_logreg_iid_unchanged(self):
        a = make_logreg("epsilon", 4, m=512, d=32, seed=0)
        b = make_logreg("epsilon", 4, m=512, d=32, seed=0)
        np.testing.assert_array_equal(np.asarray(a.node_index),
                                      np.asarray(b.node_index))
