"""Paper Figure 4 + schedule-compiler audits.

Sections:
  * fig4            — plain decentralized SGD (Algorithm 3) across topologies
                      (ring / torus / fully-connected) and n in {9, 25, 64},
                      *sorted* data; derived column: final suboptimality.
  * schedule_compile — rounds (= collective-permute rounds per gossip step)
                      and compile time per topology: the static contract
                      EXPERIMENTS.md §Perf E records.  Compilation is pure
                      Python (never traced), so the times here are the whole
                      cost — they must stay microseconds-to-milliseconds.
  * kstep_tradeoff  — k gossip rounds per SGD step (ChocoConfig.gossip_steps):
                      consensus error after one step vs k x the wire bytes,
                      on the matrix simulator.
"""
import jax
import jax.numpy as jnp

from repro.core import make_topology, Identity, TopK, run_choco_sgd, \
    experiment_lr_schedule
from repro.core.choco_gossip import choco_gossip_round_efficient, \
    init_efficient_state
from repro.comm.schedule import compile_schedule
from repro.data.synthetic import make_logreg
from .common import time_fn, emit

STEPS = 800

SCHEDULED = ("ring", "torus", "hypercube", "star", "chain", "fully_connected")


def schedule_compile():
    for n in (8, 64):
        for name in SCHEDULED:
            topo = make_topology(name, n)
            us = time_fn(lambda: compile_schedule(topo), iters=3, warmup=1)
            sched = compile_schedule(topo)
            emit(f"topology/schedule_{name}_n{n}", us,
                 f"rounds={sched.n_rounds};delta={topo.delta:.4f};"
                 f"uniform={int(sched.self_weight is not None)}")


def kstep_tradeoff():
    """Hashemi et al. (2020): extra gossip rounds per SGD step buy consensus
    at k x the wire cost.  One 'step' here = k CHOCO-Gossip rounds from a
    fresh disagreement (the per-SGD-step situation)."""
    n, d = 8, 256
    topo = make_topology("hypercube", n)
    W = jnp.asarray(topo.W)
    comp = TopK(k=64)
    gamma = 0.4                      # practical consensus stepsize
    x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    xbar = jnp.mean(x0, axis=0, keepdims=True)
    err0 = float(jnp.mean(jnp.sum((x0 - xbar) ** 2, axis=-1)))
    # one payload send per (src, dst) pair per compiled round — exact for
    # partial rounds too (star ships 1 edge-pair per round, hypercube all n)
    sched = compile_schedule(topo)
    bits_per_round = comp.wire_bits(d) * sum(len(r.perm) for r in sched.rounds)
    for k in (1, 2, 4, 8):
        def fn():
            st = init_efficient_state(x0)
            for _ in range(k):
                st = choco_gossip_round_efficient(st, W, gamma, comp)
            return st
        us = time_fn(fn, iters=1, warmup=1)
        st = fn()
        err = float(jnp.mean(jnp.sum((st.x - xbar) ** 2, axis=-1)))
        emit(f"topology/kstep_k{k}", us,
             f"consensus_err={err:.3f};vs_initial={err / err0:.4f};"
             f"wire_bits={k * bits_per_round}")


def run():
    schedule_compile()
    kstep_tradeoff()
    for n in (9, 25, 64):
        prob = make_logreg("epsilon", n_nodes=n, sorted_assignment=True,
                           m=1152 * 2, d=256, seed=1)
        grad_fn = prob.make_grad_fn(batch_size=4)
        lr = experiment_lr_schedule(1, 300.0, 300.0)
        x0 = jnp.zeros((n, prob.d))
        for topo_name in ("ring", "torus", "fully_connected"):
            topo = make_topology(topo_name, n)
            W = jnp.asarray(topo.W)

            def fn():
                return run_choco_sgd(x0, W, grad_fn, Identity(), lr, 1.0,
                                     STEPS, eval_fn=prob.full_loss)

            us = time_fn(fn, iters=1, warmup=1) / STEPS
            _, trace = fn()
            emit(f"topology/{topo_name}_n{n}", us,
                 f"loss@{STEPS}={float(trace[-1]):.4f};delta={topo.delta:.4f}")


if __name__ == "__main__":
    run()
