"""Paper Figure 4: plain decentralized SGD (Algorithm 3) across topologies
(ring / torus / fully-connected) and n in {9, 25, 64}, *sorted* data.
Derived column: final suboptimality — shows the mild topology effect."""
import jax
import jax.numpy as jnp

from repro.core import make_topology, Identity, run_choco_sgd, \
    experiment_lr_schedule
from repro.data.synthetic import make_logreg
from .common import time_fn, emit

STEPS = 800


def run():
    for n in (9, 25, 64):
        prob = make_logreg("epsilon", n_nodes=n, sorted_assignment=True,
                           m=1152 * 2, d=256, seed=1)
        grad_fn = prob.make_grad_fn(batch_size=4)
        lr = experiment_lr_schedule(1, 300.0, 300.0)
        x0 = jnp.zeros((n, prob.d))
        for topo_name in ("ring", "torus", "fully_connected"):
            topo = make_topology(topo_name, n)
            W = jnp.asarray(topo.W)

            def fn():
                return run_choco_sgd(x0, W, grad_fn, Identity(), lr, 1.0,
                                     STEPS, eval_fn=prob.full_loss)

            us = time_fn(fn, iters=1, warmup=1) / STEPS
            _, trace = fn()
            emit(f"topology/{topo_name}_n{n}", us,
                 f"loss@{STEPS}={float(trace[-1]):.4f};delta={topo.delta:.4f}")


if __name__ == "__main__":
    run()
