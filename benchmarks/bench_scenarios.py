"""Non-IID scenario suite (EXPERIMENTS.md §Scenarios).

Sections:
  * skew_sweep — the declarative scenario harness (tests/scenarios.py) on
    the Dirichlet alpha sweep: final consensus loss of CHOCO-SGD at
    alpha in {0.1, 1, 100} vs the IID control and the no-gossip negative
    control, plus the gossip_steps=3 variant.  The derived column carries
    the contract observables (final loss, node-loss spread, consensus
    distance) so the EXPERIMENTS.md table regenerates from this output.
  * hlo_audit — compiled-HLO permute-launch parity of the per-edge
    straggler staleness engine vs the global-staleness baseline on an
    8-device simulated mesh: a heterogeneous delay table changes WHICH
    ring slot each edge reads, never how much is shipped, so the launch
    count must be identical (choco_staleness_stragglers registry row).
    Emits machine-readable BENCH_scenarios.json at the repo root; the
    committed copy is re-validated by ``python -m repro.analysis.lint``.
"""
import json
import os
import subprocess
import sys
import textwrap

from .common import emit, time_fn

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
TESTS = os.path.join(os.path.dirname(__file__), "..", "tests")
OUT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_scenarios.json")

#: the sweep cells the benchmark reports (a subset of the full matrix the
#: slow test tier runs — rings only, both compressors, all alphas)
SWEEP = ("a0.1-ring-topk", "a1-ring-topk", "a100-ring-topk",
         "iid-ring-topk", "a0.1-ring-qsgd", "a0.1-ring-topk-k3")


def skew_sweep():
    """Run the scenario harness over the alpha sweep; returns the records
    for BENCH_scenarios.json."""
    sys.path.insert(0, TESTS)
    try:
        from scenarios import get_scenario, no_gossip_control, run_scenario
    finally:
        sys.path.pop(0)
    records = {}
    for name in SWEEP:
        sc = get_scenario(name)
        us = time_fn(lambda: run_scenario(sc), iters=1, warmup=0)
        r = run_scenario(sc)
        records[name] = r
        emit(f"scenarios/{name}", us,
             f"final_loss={r['final_loss']:.4f};"
             f"node_loss_spread={r['node_loss_spread']:.2e};"
             f"consensus={r['consensus_dist']:.2e}")
    ng = run_scenario(no_gossip_control(get_scenario("a0.1-ring-topk")))
    records["a0.1-ring-topk-nogossip"] = ng
    emit("scenarios/a0.1-ring-topk-nogossip", 0.0,
         f"final_loss={ng['final_loss']:.4f};"
         f"node_loss_spread={ng['node_loss_spread']:.2e};"
         f"consensus={ng['consensus_dist']:.2e}")
    return records


_AUDIT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.comm.gossip import make_gossip_exchange
    from repro.comm.schedule import compile_schedule
    from repro.comm.async_gossip import StalenessProcess
    from repro.core import make_topology, TopK
    from repro.analysis.hlo_audit import count_permute_launches
    from repro.analysis.invariants import CONTEXT_VARS, assert_invariant

    def permutes(proc):
        ex = make_gossip_exchange(mode="choco", mesh=mesh,
                                  state_specs=P("data", None), axis="data",
                                  compressor=comp, gamma=0.3, process=proc)
        z = lambda: jnp.zeros_like(x0)
        args = (jax.random.PRNGKey(0), x0,
                [z() for _ in range(1 + tau)],
                [z() for _ in range(R * (1 + tau))])
        return count_permute_launches(
            jax.jit(ex).lower(*args).compile().as_text())

    n, d, tau = 8, 4096, 2
    sched = compile_schedule(make_topology("ring", n))
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    comp = TopK(fraction=0.05)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    R = sched.n_rounds

    n_global = permutes(StalenessProcess(sched, max_staleness=tau))
    n_strag = permutes(StalenessProcess(
        sched, max_staleness=tau, straggler_edges=((0, 1),),
        straggler_delay_probs=(0.1, 0.2, 0.7)))
    # registered contract: per-edge delay tables add ZERO permute launches
    assert_invariant("choco_staleness_stragglers", "jnp",
                     {"permute_launches": n_strag},
                     dict(CONTEXT_VARS, baseline=n_global))
    print("BENCH_SCENARIOS_JSON=" + json.dumps(
        {"global_staleness": n_global, "straggler_staleness": n_strag}))
""")


def hlo_audit():
    """Run the subprocess parity audit; returns the straggler record."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _AUDIT], env=env,
                       capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        emit("scenarios/hlo_audit", 0.0, f"ERROR:{r.stderr[-200:]}")
        return None
    line = [l for l in r.stdout.splitlines()
            if l.startswith("BENCH_SCENARIOS_JSON=")][-1]
    rec = json.loads(line.split("=", 1)[1])
    emit("scenarios/hlo_straggler", 0.0,
         f"permute_launches={rec['straggler_staleness']};"
         f"global_baseline={rec['global_staleness']};"
         f"extra_launches="
         f"{rec['straggler_staleness'] - rec['global_staleness']}")
    return rec


def run():
    """Benchmark entry point (python -m benchmarks.run)."""
    skew = skew_sweep()
    straggler = hlo_audit()
    if straggler is None:
        return
    out = {"straggler": straggler,
           "skew": {k: {"final_loss": round(v["final_loss"], 4),
                        "consensus_dist": round(v["consensus_dist"], 4)}
                    for k, v in skew.items()},
           "config": {"devices": 8, "topology": "ring", "tau": 2,
                      "straggler_edges": [[0, 1]],
                      "straggler_delay_probs": [0.1, 0.2, 0.7]}}
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    run()
