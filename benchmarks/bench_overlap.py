"""Pipelined-gossip overlap audit (EXPERIMENTS.md §Perf H).

Proves — on the compiled HLO of the real qwen3-1.7b smoke train step, 8
simulated devices — that the pipelined engine (comm/pipelined.py) removes
the data dependency that serializes compressed communication behind the
backward pass.

The CPU backend lowers ``lax.ppermute`` to a synchronous
``collective-permute`` (no start/done pair to place), and printed
instruction order is not a schedule, so "how far apart are start and done"
cannot be read off the text directly.  What CAN be read off — and is the
scheduler-independent fact that start/done separation on an async backend
follows from — is the DEPENDENCY structure: an async scheduler may move
collective-start before, and collective-done after, exactly those ops that
are not on a path to/from the collective.  So the audit computes the
transitive operand closure of every collective-permute in the entry
computation and counts the matmuls inside it (descending into fused/called
computations, e.g. the transformer's scan-over-layers while loop):

  * serial engine:    the payload is Q(x_half - x_hat) and x_half is
    downstream of the gradient, so every forward/backward dot feeds the
    collective — the wire transfer cannot begin until the backward pass
    has finished.
  * pipelined engine: the payload is Q(x_k - x_hat_k) from the carry, so
    ZERO dots feed the collective — it is launchable at step start,
    concurrent with the entire forward/backward (start and its done are
    separable by all of the step's matmul compute).

Sections:
  * overlap_audit — dots_feeding_collective for serial vs pipelined on the
    qwen3-1.7b smoke config, plus permute-launch parity (pipelining adds
    zero collectives) and walltime/step.  Emits machine-readable
    BENCH_overlap.json at the repo root so the perf trajectory is tracked
    from PR 6 onward.
"""
import json
import os
import re
import subprocess
import sys
import textwrap

from .common import emit

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
OUT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_overlap.json")

# runs inside a subprocess so the 8-device simulation never leaks
# XLA_FLAGS into the caller
_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import jax, jax.numpy as jnp

    from repro.configs.base import get_config, ChocoConfig
    from repro.models import build_model
    from repro.train.trainer import DecentralizedTrainer
    from repro.optim import make_optimizer, cosine_schedule
    from repro.data.synthetic import make_lm_batch_fn
    from repro.launch.mesh import make_mesh
    from benchmarks.bench_overlap import audit_hlo_text

    cfg = get_config("qwen3-1.7b", smoke=True)
    model = build_model(cfg)
    mesh = make_mesh((8, 1), ("data", "model"))
    nb = make_lm_batch_fn(cfg, 64, 2, 8, 1.0)

    out = {}
    for pipe in (False, True):
        tr = DecentralizedTrainer(
            model=model,
            choco=ChocoConfig(compressor="top_k",
                              comp_kwargs=(("fraction", 0.05),),
                              gossip_axis="data", pipeline_gossip=pipe),
            mesh=mesh, n_nodes=8, optimizer=make_optimizer("momentum"),
            lr_fn=cosine_schedule(0.1, warmup=10, total=100), mode="choco")
        state = tr.init_state(jax.random.PRNGKey(0))
        batch = jax.tree.map(jnp.asarray, nb())
        step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                    jax.eval_shape(lambda: batch))
        hlo = step.lower(state, batch).compile().as_text()
        rec = audit_hlo_text(hlo)
        state, _ = step(state, batch)          # compile + donate once
        t0 = time.time()
        iters = 5
        for _ in range(iters):
            state, mets = step(state, jax.tree.map(jnp.asarray, nb()))
        jax.block_until_ready(state.params)
        rec["us_per_step"] = (time.time() - t0) / iters * 1e6
        out["pipelined" if pipe else "serial"] = rec
    print("BENCH_OVERLAP_JSON=" + json.dumps(out))
""")


def _hlo_computations(hlo: str):
    """Split HLO text into {computation_name: [instruction lines]}."""
    comps, cur, body = {}, None, []
    for line in hlo.splitlines():
        if re.match(r"^\S.*\{\s*$", line):
            cur = line.split()[0].lstrip("%")
            if cur.startswith("ENTRY"):
                cur = line.split()[1].lstrip("%")
            body = comps.setdefault(cur, [])
            if line.startswith("ENTRY"):
                comps["__entry__"] = body
        elif cur is not None and line.strip() and line.strip() != "}":
            body.append(line)
    return comps


_CALLED = re.compile(r"(?:calls|to_apply|body|condition)=%([\w\.\-]+)")
_NAMES = re.compile(r"%([\w\.\-]+)")


def _dots_in(comps, name, memo):
    """Transitive dot(...) count of a computation, descending into the
    computations it calls (fusions, while bodies, to_apply reducers)."""
    if name in memo:
        return memo[name]
    memo[name] = 0          # cycle guard (HLO call graphs are acyclic)
    total = 0
    for line in comps.get(name, ()):
        if "dot(" in line:
            total += 1
        for callee in _CALLED.findall(line):
            total += _dots_in(comps, callee, memo)
    memo[name] = total
    return total


def audit_hlo_text(hlo: str) -> dict:
    """Dependency audit of a compiled train-step HLO module.

    Returns dot counts for the whole module and for the transitive operand
    closure of its collective-permutes: ``dots_feeding_collective`` is the
    matmul work an async scheduler must finish BEFORE the wire transfer can
    start — 0 means the collective is launchable at step start and its
    start/done pair is separable by the entire forward/backward compute.
    """
    comps = _hlo_computations(hlo)
    entry = comps.get("__entry__", [])
    defs, deps, called = {}, {}, {}
    for line in entry:
        m = re.match(r"\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=", line)
        if not m:
            continue
        name = m.group(1)
        defs[name] = line
        callees = set(_CALLED.findall(line))
        rhs = line.split("=", 1)[1]
        deps[name] = [n for n in _NAMES.findall(rhs)
                      if n != name and n not in callees]
        called[name] = callees
    permutes = [n for n, l in defs.items() if "collective-permute" in l]
    memo = {}
    seen, stack = set(), []
    for p in permutes:
        stack.extend(deps.get(p, []))
    feeding_dots = 0
    while stack:
        n = stack.pop()
        if n in seen or n not in defs:
            continue
        seen.add(n)
        if "dot(" in defs[n]:
            feeding_dots += 1
        for c in called.get(n, ()):
            feeding_dots += _dots_in(comps, c, memo)
        stack.extend(deps.get(n, []))
    total = _dots_in(comps, "__entry__", {})
    return {"permute_launches": len(permutes),
            "dots_total": total,
            "dots_feeding_collective": feeding_dots}


def overlap_audit():
    """Run the subprocess audit and emit CSV rows + BENCH_overlap.json."""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.path.join(SRC, ".."))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        emit("overlap/audit", 0.0, f"ERROR:{r.stderr[-200:]}")
        return None
    line = [l for l in r.stdout.splitlines()
            if l.startswith("BENCH_OVERLAP_JSON=")][-1]
    out = json.loads(line.split("=", 1)[1])
    for name, rec in out.items():
        emit(f"overlap/{name}", rec["us_per_step"],
             f"permute_launches={rec['permute_launches']};"
             f"dots_total={rec['dots_total']};"
             f"dots_feeding_collective={rec['dots_feeding_collective']}")
    out["config"] = {"arch": "qwen3-1.7b-smoke", "devices": 8,
                     "compressor": "top_k", "fraction": 0.05,
                     "topology": "ring"}
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    return out


def run():
    overlap_audit()


if __name__ == "__main__":
    run()
