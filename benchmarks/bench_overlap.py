"""Pipelined-gossip overlap audit (EXPERIMENTS.md §Perf H).

Proves — on the compiled HLO of the real qwen3-1.7b smoke train step, 8
simulated devices — that the pipelined engine (comm/pipelined.py) removes
the data dependency that serializes compressed communication behind the
backward pass.

The dependency analysis itself lives in
``repro.analysis.hlo_audit.collective_dependency_audit`` (shared with
``tests/test_pipelined.py`` and the invariant lint); the expected numbers
live in the engine-invariant registry
(``repro.analysis.invariants.ENGINE_INVARIANTS``):

  * serial engine:    every forward/backward dot feeds the collective —
    the wire transfer cannot begin until the backward pass has finished.
  * pipelined engine: ZERO dots feed the collective — it is launchable at
    step start, concurrent with the entire forward/backward, and adds no
    permute launches over serial.

Sections:
  * overlap_audit — dots_feeding_collective for serial vs pipelined on the
    qwen3-1.7b smoke config, checked against the registry, plus
    walltime/step.  Emits machine-readable BENCH_overlap.json at the repo
    root so the perf trajectory is tracked from PR 6 onward.
"""
import json
import os
import subprocess
import sys
import textwrap

from .common import emit

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
OUT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_overlap.json")

# runs inside a subprocess so the 8-device simulation never leaks
# XLA_FLAGS into the caller
_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import jax, jax.numpy as jnp

    from repro.configs.base import get_config, ChocoConfig
    from repro.models import build_model
    from repro.train.trainer import DecentralizedTrainer
    from repro.optim import make_optimizer, cosine_schedule
    from repro.data.synthetic import make_lm_batch_fn
    from repro.launch.mesh import make_mesh
    from repro.analysis.hlo_audit import collective_dependency_audit

    cfg = get_config("qwen3-1.7b", smoke=True)
    model = build_model(cfg)
    mesh = make_mesh((8, 1), ("data", "model"))
    nb = make_lm_batch_fn(cfg, 64, 2, 8, 1.0)

    out = {}
    for pipe in (False, True):
        tr = DecentralizedTrainer(
            model=model,
            choco=ChocoConfig(compressor="top_k",
                              comp_kwargs=(("fraction", 0.05),),
                              gossip_axis="data", pipeline_gossip=pipe),
            mesh=mesh, n_nodes=8, optimizer=make_optimizer("momentum"),
            lr_fn=cosine_schedule(0.1, warmup=10, total=100), mode="choco")
        state = tr.init_state(jax.random.PRNGKey(0))
        batch = jax.tree.map(jnp.asarray, nb())
        step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                    jax.eval_shape(lambda: batch))
        hlo = step.lower(state, batch).compile().as_text()
        rec = collective_dependency_audit(hlo).as_dict()
        state, _ = step(state, batch)          # compile + donate once
        t0 = time.time()
        iters = 5
        for _ in range(iters):
            state, mets = step(state, jax.tree.map(jnp.asarray, nb()))
        jax.block_until_ready(state.params)
        rec["us_per_step"] = (time.time() - t0) / iters * 1e6
        out["pipelined" if pipe else "serial"] = rec
    print("BENCH_OVERLAP_JSON=" + json.dumps(out))
""")


def overlap_audit():
    """Run the subprocess audit, check the registry invariants, emit CSV
    rows + BENCH_overlap.json."""
    from repro.analysis.invariants import CONTEXT_VARS, assert_invariant

    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.path.join(SRC, ".."))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        emit("overlap/audit", 0.0, f"ERROR:{r.stderr[-200:]}")
        return None
    line = [l for l in r.stdout.splitlines()
            if l.startswith("BENCH_OVERLAP_JSON=")][-1]
    out = json.loads(line.split("=", 1)[1])
    for name, rec in out.items():
        emit(f"overlap/{name}", rec["us_per_step"],
             f"permute_launches={rec['permute_launches']};"
             f"dots_total={rec['dots_total']};"
             f"dots_feeding_collective={rec['dots_feeding_collective']}")
    # the registry is the single statement of what these numbers must be
    ctx = dict(CONTEXT_VARS, dots_total=out["serial"]["dots_total"],
               baseline=out["serial"]["permute_launches"])
    assert_invariant("choco_serial", "jnp",
                     {"dots_feeding_collective":
                      out["serial"]["dots_feeding_collective"]}, ctx)
    ctx["dots_total"] = out["pipelined"]["dots_total"]
    assert_invariant("choco_pipelined", "jnp",
                     {"dots_feeding_collective":
                      out["pipelined"]["dots_feeding_collective"],
                      "permute_launches":
                      out["pipelined"]["permute_launches"]}, ctx)
    out["config"] = {"arch": "qwen3-1.7b-smoke", "devices": 8,
                     "compressor": "top_k", "fraction": 0.05,
                     "topology": "ring"}
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    return out


def run():
    """Benchmark entry point (python -m benchmarks.run)."""
    overlap_audit()


if __name__ == "__main__":
    run()
