"""Telemetry cost audit (observability PR).

Proves — on the compiled HLO of the real qwen3-1.7b smoke train step, 8
simulated devices — that the telemetry subsystem is free when off and
bounded when on:

  * telemetry off: building (and compiling) the diagnostics executable
    changes NOTHING about the train step — the compiled HLO is
    byte-identical to a build that never touched ``obs``
    (``telemetry_off`` invariant).
  * diag step: the diagnostics executable is reductions only — zero
    permute launches, and its collective-launch count stays within the
    budget recorded alongside it (``telemetry_diag`` invariant; the lint
    pass re-checks the committed record, so a doctored count fails CI).
  * tap cost: walltime of one diagnostics call vs one train step, so the
    ``--diag-every`` overhead is a number, not a guess.

Emits machine-readable BENCH_telemetry.json at the repo root; the
expected numbers live in the engine-invariant registry
(``repro.analysis.invariants.ENGINE_INVARIANTS``).
"""
import json
import os
import subprocess
import sys
import textwrap

from .common import emit

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
OUT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_telemetry.json")

# runs inside a subprocess so the 8-device simulation never leaks
# XLA_FLAGS into the caller
_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import hashlib, json, time
    import jax, jax.numpy as jnp

    from repro.configs.base import get_config, ChocoConfig
    from repro.models import build_model
    from repro.train.trainer import DecentralizedTrainer
    from repro.optim import make_optimizer, cosine_schedule
    from repro.data.synthetic import make_lm_batch_fn
    from repro.launch.mesh import make_mesh
    from repro.analysis.hlo_audit import count_permute_launches

    cfg = get_config("qwen3-1.7b", smoke=True)
    model = build_model(cfg)
    mesh = make_mesh((8, 1), ("data", "model"))
    nb = make_lm_batch_fn(cfg, 64, 2, 8, 1.0)

    def make_trainer():
        return DecentralizedTrainer(
            model=model,
            choco=ChocoConfig(compressor="top_k",
                              comp_kwargs=(("fraction", 0.05),),
                              gossip_axis="data"),
            mesh=mesh, n_nodes=8, optimizer=make_optimizer("momentum"),
            lr_fn=cosine_schedule(0.1, warmup=10, total=100), mode="choco")

    def step_hlo(tr, state, batch):
        step = tr.jitted_train_step(jax.eval_shape(lambda: state),
                                    jax.eval_shape(lambda: batch))
        return step, step.lower(state, batch).compile().as_text()

    # build A: telemetry never touched
    tr_a = make_trainer()
    state = tr_a.init_state(jax.random.PRNGKey(0))
    batch = jax.tree.map(jnp.asarray, nb())
    step_a, hlo_a = step_hlo(tr_a, state, batch)

    # build B: diagnostics executable built AND compiled first
    tr_b = make_trainer()
    diag = tr_b.jitted_diagnostics(jax.eval_shape(lambda: state))
    hlo_diag = diag.lower(state).compile().as_text()
    _, hlo_b = step_hlo(tr_b, state, batch)

    sha = lambda s: hashlib.sha256(s.encode()).hexdigest()
    out = {"parity": {"hlo_identical": int(sha(hlo_a) == sha(hlo_b)),
                      "train_step_sha256": sha(hlo_a)}}

    collectives = sum(
        1 for line in hlo_diag.splitlines()
        if " = " in line and ("all-reduce(" in line
                              or "all-gather(" in line
                              or "reduce-scatter(" in line))
    n_scalars = len(diag(state))
    n_leaves = len(jax.tree.leaves(state.params))
    out["diag"] = {"permute_launches": count_permute_launches(hlo_diag),
                   "collective_launches": collectives,
                   "collective_budget": collectives,
                   "n_metrics": n_scalars, "n_param_leaves": n_leaves}
    # structural boundedness, asserted at measure time: each diagnostic
    # costs a constant number of cross-node reductions per parameter
    # leaf (consensus mean, EF residual, compression sample + the
    # gathers feeding its per-row top-k), so the collective count is
    # O(leaves), never O(leaves * nodes)
    assert collectives <= 8 * n_leaves, (collectives, n_leaves)

    state, _ = step_a(state, batch)            # compile + donate once
    iters = 5
    t0 = time.time()
    for _ in range(iters):
        state, mets = step_a(state, jax.tree.map(jnp.asarray, nb()))
    jax.block_until_ready(state.params)
    us_step = (time.time() - t0) / iters * 1e6
    t0 = time.time()
    for _ in range(iters):
        vals = diag(state)
    jax.block_until_ready(vals)
    us_diag = (time.time() - t0) / iters * 1e6
    out["timing"] = {"us_per_step": us_step, "us_per_diag": us_diag,
                     "diag_over_step": us_diag / us_step}
    print("BENCH_TELEMETRY_JSON=" + json.dumps(out))
""")


def telemetry_audit():
    """Run the subprocess audit, check the registry invariants, emit CSV
    rows + BENCH_telemetry.json."""
    from repro.analysis.invariants import CONTEXT_VARS, assert_invariant

    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.path.join(SRC, ".."))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        emit("telemetry/audit", 0.0, f"ERROR:{r.stderr[-200:]}")
        return None
    line = [l for l in r.stdout.splitlines()
            if l.startswith("BENCH_TELEMETRY_JSON=")][-1]
    out = json.loads(line.split("=", 1)[1])
    emit("telemetry/off", 0.0,
         f"hlo_identical={out['parity']['hlo_identical']}")
    emit("telemetry/diag", out["timing"]["us_per_diag"],
         f"permute_launches={out['diag']['permute_launches']};"
         f"collective_launches={out['diag']['collective_launches']};"
         f"n_metrics={out['diag']['n_metrics']}")
    emit("telemetry/step", out["timing"]["us_per_step"],
         f"diag_over_step={out['timing']['diag_over_step']:.3f}")
    # the registry is the single statement of what these numbers must be
    ctx = dict(CONTEXT_VARS, budget=out["diag"]["collective_budget"])
    assert_invariant("telemetry_off", "jnp",
                     {"hlo_identical": out["parity"]["hlo_identical"]}, ctx)
    assert_invariant("telemetry_diag", "jnp",
                     {"permute_launches": out["diag"]["permute_launches"],
                      "collective_launches":
                      out["diag"]["collective_launches"]}, ctx)
    out["config"] = {"arch": "qwen3-1.7b-smoke", "devices": 8,
                     "compressor": "top_k", "fraction": 0.05,
                     "topology": "ring"}
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    return out


def run():
    """Benchmark entry point (python -m benchmarks.run)."""
    telemetry_audit()


if __name__ == "__main__":
    run()
