"""Stochastic topology processes + directed push-sum (EXPERIMENTS.md §Perf F).

Sections:
  * process_rate    — consensus error after T gossip rounds: static schedule
                      vs randomized matchings (uniform / weighted samplers)
                      vs link failures (p in {0.1, 0.3}), on the matrix
                      simulators of comm/stochastic.py.  The derived column
                      carries the per-step collective cost: a matching step
                      ships ONE permute round; static and linkfail ship all
                      n_rounds of the compiled schedule.
  * pushsum_directed — compressed push-sum on directed graphs: de-biased
                      consensus error x/w vs the true average after T
                      rounds, with the exact (identity) run as reference.
"""
import jax
import jax.numpy as jnp

from repro.core import TopK, Identity, make_topology, directed_ring, \
    random_digraph, run_pushsum_gossip
from repro.comm.schedule import compile_schedule, compile_directed_schedule
from repro.comm.stochastic import (LinkFailureProcess, MatchingProcess,
                                   run_choco_gossip_process)
from repro.core.choco_gossip import (choco_gossip_round_efficient,
                                     init_efficient_state)
from .common import time_fn, emit

N, D, STEPS = 8, 256, 300


def _consensus_err(x, xbar):
    return float(jnp.mean(jnp.sum((x - xbar) ** 2, axis=-1)))


def process_rate():
    comp = TopK(k=64)
    gamma = 0.4
    x0 = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    xbar = jnp.mean(x0, axis=0, keepdims=True)
    for name in ("ring", "hypercube"):
        topo = make_topology(name, N)
        sched = compile_schedule(topo)
        W = jnp.asarray(topo.W)

        def static_run():
            st = init_efficient_state(x0)
            for _ in range(STEPS):
                st = choco_gossip_round_efficient(st, W, gamma, comp)
            return st
        us = time_fn(static_run, iters=1, warmup=1)
        err = _consensus_err(static_run().x, xbar)
        emit(f"stochastic/static_{name}", us,
             f"err={err:.3e};permute_rounds_per_step={sched.n_rounds}")

        for sampler in ("uniform", "weighted"):
            proc = MatchingProcess(sched, sampler=sampler)
            fn = lambda p=proc: run_choco_gossip_process(
                x0, p, gamma, comp, STEPS)
            us = time_fn(fn, iters=1, warmup=1)
            _, errs = fn()
            emit(f"stochastic/matching_{sampler}_{name}", us,
                 f"err={float(errs[-1]):.3e};permute_rounds_per_step=1")

        for p in (0.1, 0.3):
            proc = LinkFailureProcess(sched, drop_prob=p)
            fn = lambda pr=proc: run_choco_gossip_process(
                x0, pr, 0.3, comp, STEPS)
            us = time_fn(fn, iters=1, warmup=1)
            _, errs = fn()
            emit(f"stochastic/linkfail_p{p}_{name}", us,
                 f"err={float(errs[-1]):.3e};"
                 f"permute_rounds_per_step={sched.n_rounds};"
                 f"expected_delta={proc.expected_delta_beta()[0]:.4f}")


def pushsum_directed():
    x0 = jax.random.normal(jax.random.PRNGKey(1), (N, D))
    xbar = jnp.mean(x0, axis=0, keepdims=True)
    for topo in (directed_ring(N), random_digraph(N, 0.4, seed=1)):
        sched = compile_directed_schedule(topo)
        A = jnp.asarray(topo.A)
        for comp, label, gamma in ((Identity(), "exact", 1.0),
                                   (TopK(k=64), "top64", 0.5),
                                   (TopK(k=26), "top10pct", 0.2)):
            def fn():
                final, errs = run_pushsum_gossip(x0, A, gamma, comp, STEPS)
                return errs
            us = time_fn(fn, iters=1, warmup=1)
            errs = fn()
            emit(f"stochastic/pushsum_{topo.name}_{label}", us,
                 f"debias_err={float(errs[-1]):.3e};"
                 f"rounds_per_step={sched.n_rounds};delta={topo.delta:.4f}")


def run():
    process_rate()
    pushsum_directed()


if __name__ == "__main__":
    run()
