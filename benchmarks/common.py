"""Shared benchmark utilities: timing + CSV emission + roofline constants."""
import os
import time

import jax

#: HBM bandwidth (bytes/s) every roofline-derived column is computed
#: against — one constant for all benchmarks so bench_kernels and
#: bench_fused report comparable numbers.  Default is the v5e figure the
#: kernels target; override with REPRO_HBM_BW for other parts.
HBM_BW = float(os.environ.get("REPRO_HBM_BW", 819e9))


def time_fn(fn, *args, iters=3, warmup=1, **kw):
    """Median wall time in microseconds (blocks on device results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name, us_per_call, derived):
    print(f"{name},{us_per_call:.1f},{derived}")
