"""Shared benchmark utilities: timing + CSV emission + roofline constants."""
import time

import jax

#: HBM bandwidth (bytes/s) every roofline-derived column is computed
#: against.  Single source of truth is repro.analysis.roofline (v5e figure,
#: REPRO_HBM_BW overrides) — re-exported here so benchmarks keep their
#: one-import habit.
from repro.analysis.roofline import HBM_BW  # noqa: E402


def time_fn(fn, *args, iters=3, warmup=1, **kw):
    """Median wall time in microseconds (blocks on device results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name, us_per_call, derived):
    print(f"{name},{us_per_call:.1f},{derived}")
