"""Benchmark driver — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV."""
import sys


def main() -> None:
    print("name,us_per_call,derived")
    from . import bench_consensus, bench_topology, bench_sgd, \
        bench_collectives, bench_kernels, bench_checkpoint, \
        bench_stochastic, bench_async, bench_overlap, bench_fused, \
        bench_telemetry, bench_scenarios
    bench_consensus.run()      # paper Figs 2-3
    bench_topology.run()       # paper Fig 4 + schedule compiler + k-step gossip
    bench_sgd.run()            # paper Figs 5-6
    bench_collectives.run()    # framework: wire bytes choco vs baselines
    bench_kernels.run()        # Pallas kernel targets
    bench_checkpoint.run()     # sharded vs legacy flat-npz checkpoint layer
    bench_stochastic.run()     # stochastic topologies + directed push-sum
    bench_async.run()          # bounded-staleness async gossip + HLO audit
    bench_overlap.run()        # pipelined-gossip overlap audit (Perf H)
    bench_fused.run()          # fused-kernel HBM stream audit (Perf I)
    bench_telemetry.run()      # telemetry cost audit (observability)
    bench_scenarios.run()      # non-IID scenario suite + straggler audit


if __name__ == '__main__':
    main()
