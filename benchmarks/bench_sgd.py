"""Paper Figures 5-6: decentralized optimization on ring n=9, sorted data —
plain Alg. 3 vs DCD-SGD vs ECD-SGD vs CHOCO-SGD (rand_1%, top_1%, qsgd_16),
on epsilon-like (dense) and rcv1-like (sparse) problems.
Derived: final loss + total transmitted megabits (both paper x-axes)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ring, TopK, RandK, QSGD, Identity,
                        run_choco_sgd, experiment_lr_schedule,
                        DCDState, dcd_sgd_step, ECDState, ecd_sgd_step)
from repro.data.synthetic import make_logreg
from .common import time_fn, emit

N = 9
STEPS = 1200


def _bits(comp, d, steps=STEPS, degree=2):
    return comp.wire_bits(d) * N * degree * steps / 1e6


def _run_choco(prob, comp, gamma, seed=0):
    grad_fn = prob.make_grad_fn(batch_size=4)
    lr = experiment_lr_schedule(1, 300.0, 300.0)
    _, t = run_choco_sgd(jnp.zeros((N, prob.d)), jnp.asarray(ring(N).W),
                         grad_fn, comp, lr, gamma, STEPS,
                         key=jax.random.PRNGKey(seed), eval_fn=prob.full_loss)
    return float(t[-1])


def _run_tang(prob, comp, kind, eta=0.5, seed=0):
    grad_fn = prob.make_grad_fn(batch_size=4)
    W = jnp.asarray(ring(N).W)
    key = jax.random.PRNGKey(seed)
    if kind == "dcd":
        st = DCDState(x=jnp.zeros((N, prob.d)))
        step = jax.jit(lambda s, k: dcd_sgd_step(s, W, grad_fn, comp, eta, k))
    else:
        st = ECDState(x=jnp.zeros((N, prob.d)),
                      x_tilde=jnp.zeros((N, prob.d)), t=jnp.zeros((), jnp.int32))
        step = jax.jit(lambda s, k: ecd_sgd_step(s, W, grad_fn, comp, eta, k))
    for i in range(STEPS):
        st = step(st, jax.random.fold_in(key, i))
    x = np.asarray(jnp.mean(st.x, 0))
    if not np.isfinite(x).all():
        return float("inf")
    return float(prob.full_loss(jnp.asarray(x)))


def run():
    for ds in ("epsilon", "rcv1"):
        prob = make_logreg(ds, n_nodes=N, sorted_assignment=True,
                           m=1152, d=256 if ds == "epsilon" else 1024, seed=2)
        d = prob.d

        us = time_fn(lambda: _run_choco(prob, Identity(), 1.0), iters=1) / STEPS
        emit(f"sgd/{ds}/plain", us,
             f"loss={_run_choco(prob, Identity(), 1.0):.4f};"
             f"Mbits={_bits(Identity(), d):.1f}")

        for name, comp, gamma in (
                ("choco_rand1pct", RandK(fraction=0.01), 0.016),
                ("choco_top1pct", TopK(fraction=0.01), 0.04),
                ("choco_qsgd16", QSGD(16), 0.2)):
            loss = _run_choco(prob, comp, gamma)
            emit(f"sgd/{ds}/{name}", us,
                 f"loss={loss:.4f};Mbits={_bits(comp, d):.1f}")

        for name, comp, eta in (
                ("dcd_qsgd16", QSGD(16, rescale=False), 0.05),
                ("dcd_rand1pct", RandK(fraction=0.01, rescale=True), 1e-3),
                ("ecd_qsgd16", QSGD(16, rescale=False), 1e-3)):
            loss = _run_tang(prob, comp, name.split("_")[0], eta)
            emit(f"sgd/{ds}/{name}", us,
                 f"loss={loss:.4f};Mbits={_bits(comp, d):.1f}")


if __name__ == "__main__":
    run()
