"""Framework-level communication benchmark: bytes on the wire per training
step for CHOCO vs plain gossip vs centralized all-reduce, plus the packed
(bucketed flat-buffer) vs per-leaf gossip engine comparison.

Three views:
  * analytic — from the compressors' wire formats (exact, any size);
  * packing audit — per-leaf vs packed payload wire bits + payload-array
    counts for a real multi-leaf param tree (no compilation needed);
  * compiled — collective-launch counts and wire bytes parsed from the SPMD
    HLO of the real train step on a small simulated mesh (subprocess with 8
    host devices, since benches themselves must see 1 device).

Methodology notes live in EXPERIMENTS.md §Wire audit.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax

from repro.core import TopK, RandK, QSGD, Identity
from .common import emit

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def analytic():
    d = 2_030_000_000          # qwen3-1.7b-scale parameter vector
    for name, comp in (("exact", Identity()),
                       ("qsgd16", QSGD(16)),
                       ("rand1pct", RandK(fraction=0.01)),
                       ("top1pct", TopK(fraction=0.01))):
        gb = comp.wire_bits(d) / 8 / 1e9 * 2        # 2 ring neighbours
        emit(f"collectives/analytic_{name}", 0.0,
             f"GB_per_node_per_step={gb:.3f};reduction={Identity().wire_bits(d)/comp.wire_bits(d):.0f}x")


def packing_audit(arch: str = "qwen3-1.7b"):
    """Packed-engine wire accounting vs the summed per-leaf payloads, from
    static shapes only.  The acceptance bar for the packing engine is packed
    wire bits within 10% of the per-leaf sum (padding + per-bucket ceil(k)
    are the only differences) with ~#leaves/#buckets fewer payload arrays."""
    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.comm.packing import make_bucket_spec, packed_wire_bits
    from repro.launch.sharding import param_pspecs
    from repro.comm.gossip import _leaf_routes

    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    n_nodes = 4
    # the real trainer state: (n_nodes, ...) leaves; routes from the same
    # param_pspecs call the exchange uses (model-sharded vs replicated)
    shapes_n = jax.eval_shape(
        lambda k: jax.vmap(m.init)(jax.random.split(k, n_nodes)),
        jax.random.PRNGKey(0))
    specs = param_pspecs(shapes_n, cfg, node_axis="data", model_size=0)
    routes = _leaf_routes(specs, "data")
    # per-node view (what one gossip node packs and ships)
    shapes = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), shapes_n)
    leaves = jax.tree.leaves(shapes)
    comp = TopK(fraction=0.01)
    per_leaf_bits = sum(comp.wire_bits(l.size) for l in leaves)
    spec = make_bucket_spec(shapes, routes=routes)
    packed_bits = packed_wire_bits(spec, comp)
    # payload arrays ppermuted per neighbour: 2 per sparse payload
    per_leaf_arrays = 2 * len(leaves)
    packed_arrays = 2 * spec.n_buckets
    emit(f"collectives/packing_audit_{arch}", 0.0,
         f"leaves={len(leaves)};buckets={spec.n_buckets};"
         f"per_leaf_bits={per_leaf_bits};packed_bits={packed_bits};"
         f"packed_over_per_leaf={packed_bits / per_leaf_bits:.4f};"
         f"payload_arrays_{per_leaf_arrays}->{packed_arrays}")


def compiled():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        from repro.configs.base import get_config, ChocoConfig, InputShape
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.launch.specs import train_batch_specs
        from repro.analysis.roofline import parse_collectives

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        m = build_model(cfg)
        out = {}
        runs = [("choco_packed", "choco", True), ("choco_per_leaf", "choco", False),
                ("plain", "plain", True), ("allreduce", "allreduce", True)]
        for name, mode, packed in runs:
            tr = DecentralizedTrainer(model=m, choco=ChocoConfig(
                    compressor="top_k", comp_kwargs=(("fraction", 0.01),),
                    packed_gossip=packed),
                mesh=mesh, n_nodes=4, optimizer=sgd(),
                lr_fn=constant_schedule(0.01), mode=mode)
            ss = tr.state_shape()
            bs = train_batch_specs(cfg, InputShape("b", 128, 16, "train"), 4)
            comp = tr.jitted_train_step(ss, bs).lower(ss, bs).compile()
            st = parse_collectives(comp.as_text(), 8)
            out[name] = {"wire_bytes": st.total_wire_bytes,
                         "permute_bytes": st.wire_bytes["collective-permute"],
                         "allreduce_bytes": st.wire_bytes["all-reduce"],
                         "permute_count": st.counts["collective-permute"],
                         "collective_count": sum(st.counts.values())}
        print(json.dumps(out))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        emit("collectives/compiled", 0.0, f"ERROR:{r.stderr[-200:]}")
        return
    out = json.loads(r.stdout.strip().splitlines()[-1])
    base = out["plain"]["permute_bytes"] or 1.0
    for mode, v in out.items():
        emit(f"collectives/compiled_{mode}", 0.0,
             f"wire_bytes={v['wire_bytes']:.3e};permute={v['permute_bytes']:.3e};"
             f"permute_count={v['permute_count']};collectives={v['collective_count']};"
             f"vs_plain_permute={v['permute_bytes']/base:.4f}")
    pk, pl = out["choco_packed"], out["choco_per_leaf"]
    emit("collectives/packed_vs_per_leaf", 0.0,
         f"permute_launches_{pl['permute_count']}->{pk['permute_count']};"
         f"launch_reduction={pl['permute_count']/max(pk['permute_count'],1):.1f}x;"
         f"permute_bytes_ratio={pk['permute_bytes']/max(pl['permute_bytes'],1.0):.4f}")


def run():
    analytic()
    packing_audit()
    compiled()


if __name__ == "__main__":
    run()
