"""Framework-level communication benchmark: bytes on the wire per training
step for CHOCO vs plain gossip vs centralized all-reduce.

Two views:
  * analytic — from the compressors' wire formats (exact, any size);
  * compiled — parsed from the SPMD HLO of the real train step on a small
    simulated mesh (subprocess with 8 host devices, since benches themselves
    must see 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

from repro.core import TopK, RandK, QSGD, Identity
from .common import emit

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def analytic():
    d = 2_030_000_000          # qwen3-1.7b-scale parameter vector
    for name, comp in (("exact", Identity()),
                       ("qsgd16", QSGD(16)),
                       ("rand1pct", RandK(fraction=0.01)),
                       ("top1pct", TopK(fraction=0.01))):
        gb = comp.wire_bits(d) / 8 / 1e9 * 2        # 2 ring neighbours
        emit(f"collectives/analytic_{name}", 0.0,
             f"GB_per_node_per_step={gb:.3f};reduction={Identity().wire_bits(d)/comp.wire_bits(d):.0f}x")


def compiled():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        from repro.configs.base import get_config, ChocoConfig, InputShape
        from repro.models import build_model
        from repro.train.trainer import DecentralizedTrainer
        from repro.optim import sgd, constant_schedule
        from repro.launch.specs import train_batch_specs
        from repro.analysis.roofline import parse_collectives

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("qwen3-1.7b", smoke=True)
        m = build_model(cfg)
        out = {}
        for mode in ("choco", "plain", "allreduce"):
            tr = DecentralizedTrainer(model=m, choco=ChocoConfig(
                    compressor="top_k", comp_kwargs=(("fraction", 0.01),)),
                mesh=mesh, n_nodes=4, optimizer=sgd(),
                lr_fn=constant_schedule(0.01), mode=mode)
            ss = tr.state_shape()
            bs = train_batch_specs(cfg, InputShape("b", 128, 16, "train"), 4)
            comp = tr.jitted_train_step(ss, bs).lower(ss, bs).compile()
            st = parse_collectives(comp.as_text(), 8)
            out[mode] = {"wire_bytes": st.total_wire_bytes,
                         "permute_bytes": st.wire_bytes["collective-permute"],
                         "allreduce_bytes": st.wire_bytes["all-reduce"]}
        print(json.dumps(out))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    if r.returncode != 0:
        emit("collectives/compiled", 0.0, f"ERROR:{r.stderr[-200:]}")
        return
    out = json.loads(r.stdout.strip().splitlines()[-1])
    base = out["plain"]["permute_bytes"] or 1.0
    for mode, v in out.items():
        emit(f"collectives/compiled_{mode}", 0.0,
             f"wire_bytes={v['wire_bytes']:.3e};permute={v['permute_bytes']:.3e};"
             f"vs_plain_permute={v['permute_bytes']/base:.4f}")


def run():
    analytic()
    compiled()


if __name__ == "__main__":
    run()
