"""Bounded-staleness async gossip (EXPERIMENTS.md §Perf G).

Sections:
  * staleness_rate — consensus error after T gossip rounds for
    tau in {0, 1, 2, 4} on ring and torus (delay-expanded matrix simulator,
    core/choco_gossip.py).  The derived column carries the delay-averaged
    freshness phi = E[1/(1+d)], the effective Theorem-2 eigengap, and the
    per-step permute-round cost — identical to the static schedule's, the
    whole point of the bounded-staleness design.
  * hlo_audit — compiled-HLO collective-permute launch count of the async
    engine vs the link-failure baseline on an 8-device simulated mesh
    (subprocess, like bench_collectives.compiled): async must add ZERO
    launches (the arrived-vs-stale selection is where-mask arithmetic over
    ring slots, never control flow or extra collectives).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax

from repro.core import TopK, make_topology
from repro.core.choco_gossip import run_choco_stale_gossip
from repro.comm.schedule import compile_schedule
from repro.comm.async_gossip import StalenessProcess
from .common import time_fn, emit

N, D, STEPS = 8, 256, 300
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def staleness_rate():
    """Consensus error vs staleness bound tau on ring/torus."""
    comp = TopK(k=64)
    gamma = 0.25
    x0 = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    for name in ("ring", "torus"):
        sched = compile_schedule(make_topology(name, N))
        for tau in (0, 1, 2, 4):
            proc = StalenessProcess(sched, max_staleness=tau)
            fn = lambda p=proc: run_choco_stale_gossip(
                x0, p, gamma, comp, STEPS)
            us = time_fn(fn, iters=1, warmup=1)
            _, errs = fn()
            emit(f"async/staleness_{name}_tau{tau}", us,
                 f"err={float(errs[-1]):.3e};"
                 f"err_mid={float(errs[STEPS // 2]):.3e};"
                 f"freshness={proc.freshness:.3f};"
                 f"expected_delta={proc.expected_delta_beta()[0]:.4f};"
                 f"permute_rounds_per_step={sched.n_rounds}")


def hlo_audit():
    """Permute-launch parity audit: async engine vs linkfail baseline,
    checked in-subprocess against the choco_staleness registry entry."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.comm.gossip import make_gossip_exchange
        from repro.comm.schedule import compile_schedule
        from repro.comm.async_gossip import StalenessProcess
        from repro.comm.stochastic import LinkFailureProcess
        from repro.core import make_topology, TopK
        from repro.analysis.hlo_audit import count_permute_launches
        from repro.analysis.invariants import (CONTEXT_VARS,
                                               assert_invariant)

        def permutes(ex, *args):
            hlo = jax.jit(ex).lower(*args).compile().as_text()
            return count_permute_launches(hlo)

        n, d = 8, 4096
        sched = compile_schedule(make_topology("ring", n))
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        comp = TopK(fraction=0.05)
        x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        R = sched.n_rounds
        k = jax.random.PRNGKey(0)
        z = lambda: jnp.zeros_like(x0)

        lf = LinkFailureProcess(sched, drop_prob=0.1)
        ex = make_gossip_exchange(mode="choco", mesh=mesh,
                                  state_specs=P("data", None), axis="data",
                                  compressor=comp, gamma=0.3, process=lf)
        n_lf = permutes(ex, k, x0, z(), [z() for _ in range(R)])
        out = {"linkfail": n_lf}
        for tau in (1, 2, 4):
            sp = StalenessProcess(sched, max_staleness=tau)
            ex = make_gossip_exchange(mode="choco", mesh=mesh,
                                      state_specs=P("data", None),
                                      axis="data", compressor=comp,
                                      gamma=0.3, process=sp)
            n_tau = permutes(
                ex, k, x0, [z() for _ in range(1 + tau)],
                [z() for _ in range(R * (1 + tau))])
            # registered contract: staleness adds ZERO permute launches
            # over the link-failure baseline
            assert_invariant("choco_staleness", "jnp",
                             {"permute_launches": n_tau},
                             dict(CONTEXT_VARS, baseline=n_lf))
            out[f"async_tau{tau}"] = n_tau
        print(json.dumps(out))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        emit("async/hlo_audit", 0.0, f"ERROR:{r.stderr[-200:]}")
        return
    out = json.loads(r.stdout.strip().splitlines()[-1])
    base = out["linkfail"]
    for name, cnt in out.items():
        if name == "linkfail":
            continue
        emit(f"async/hlo_{name}", 0.0,
             f"permute_launches={cnt};linkfail_baseline={base};"
             f"extra_launches={cnt - base}")


def run():
    """Benchmark entry point (python -m benchmarks.run)."""
    staleness_rate()
    hlo_audit()


if __name__ == "__main__":
    run()
