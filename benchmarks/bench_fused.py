"""Fused-kernel HBM audit for the gossip hot path (EXPERIMENTS.md §Perf I).

Counts the full-size memory streams one compressed gossip round moves per
device, comparing the serial jnp engine against the fused Pallas path
(``--kernel-backend pallas``) on the real qwen3-1.7b smoke exchange, 8
simulated devices:

  * serial side — MEASURED from the compiled HLO: every f32 tensor at or
    above the stream threshold that an entry-computation instruction
    defines (a write) or consumes as an operand (a read) is one HBM
    stream.  Post-fusion, so elementwise chains XLA already fused into
    one pass are not double-counted; shapes are the per-device local
    shapes after SPMD partitioning.
  * fused side — the interpret-mode Pallas HLO lowers to grid loops on
    CPU and is unrepresentative of the TPU lowering, so the fused path
    is audited STRUCTURALLY: the jaxpr is walked for ``pallas_call``
    launches (asserted == n_buckets x 2 per round: one fused
    quantize+pack, one fused dequant+EF-update — the registered
    choco_serial/pallas invariant in repro.analysis.invariants) and the
    kernel + glue streams are itemized analytically per bucket
    (delta/xi/norm/dense glue in jnp, 2 reads + 1 code write in the
    quantize kernel, 5 reads + 3 writes in the EF kernel).

The HLO/jaxpr parsers live in ``repro.analysis.hlo_audit`` /
``repro.analysis.jaxpr_audit`` (shared with tests and the lint CLI).

Both engines run in the same subprocess and the parity contract is
asserted on real arrays: identical round-1 x_hat (the wire-payload
witness) and ulp-bounded x/s drift.  Emits BENCH_fused.json at the repo
root (schema in the JSON itself) plus CSV rows.
"""
import json
import os
import subprocess
import sys
import textwrap

from repro.analysis.hlo_audit import STREAM_THRESHOLD

from .common import HBM_BW, emit

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_fused.json")


def fused_bucket_streams(bucket_bytes: int, code_bytes: int) -> dict:
    """Analytic per-bucket per-round streams of the fused path, itemized.

    jnp glue: delta (read h, hat / write d), xi (write), norm (read d),
    self/neighbour dense q (read codes / write q) x2.  Kernels: quantize
    reads d + xi and writes codes; EF reads (h, hat, s, q_self, q_nbr)
    and writes (x, hat', s').  Collective wire bytes are excluded (the
    wire audit is §Perf D/E)."""
    B, C = bucket_bytes, code_bytes
    glue = {"delta": 3 * B, "xi": B, "norm": B,
            "dense_q": 2 * (C + B)}
    kernels = {"quantize_kernel": 2 * B + C, "ef_kernel": 8 * B}
    return {"glue_bytes": glue, "kernel_bytes": kernels,
            "bytes": sum(glue.values()) + sum(kernels.values()),
            # one full-size stream per B-sized read/write above
            "full_streams": 3 + 1 + 1 + 2 + 2 + 8}


_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp

    from repro.configs.base import get_config, ChocoConfig
    from repro.models import build_model
    from repro.train.trainer import DecentralizedTrainer
    from repro.optim import make_optimizer, cosine_schedule
    from repro.launch.mesh import make_mesh
    from benchmarks.bench_fused import fused_bucket_streams
    from repro.analysis.hlo_audit import entry_stream_audit
    from repro.analysis.invariants import CONTEXT_VARS, assert_invariant
    from repro.analysis.jaxpr_audit import count_pallas_calls

    cfg = get_config("qwen3-1.7b", smoke=True)
    model = build_model(cfg)
    mesh = make_mesh((8, 1), ("data", "model"))

    out = {}
    exchanges = {}
    states = {}
    for bk in ("jnp", "pallas"):
        tr = DecentralizedTrainer(
            model=model,
            choco=ChocoConfig(compressor="qsgd", comp_kwargs=(("s", 16),),
                              gossip_axis="data", kernel_backend=bk),
            mesh=mesh, n_nodes=8, optimizer=make_optimizer("momentum"),
            lr_fn=cosine_schedule(0.1, warmup=10, total=100), mode="choco")
        state = tr.init_state(jax.random.PRNGKey(0))
        pshape = jax.eval_shape(lambda: state.params)
        ex = tr._exchange(pshape)
        key = jax.random.PRNGKey(7)
        args = (key, state.params, jax.tree.map(jnp.zeros_like, state.params),
                jax.tree.map(jnp.zeros_like, state.params))
        rec = {}
        if bk == "jnp":
            hlo = jax.jit(ex).lower(*args).compile().as_text()
            rec.update(entry_stream_audit(hlo))
        else:
            jaxpr = jax.make_jaxpr(ex)(*args)
            rec["pallas_calls"] = count_pallas_calls(jaxpr.jaxpr)
            # reproduce the engine's local bucket spec (shard_map view:
            # gossip axis dim contracted to 1) for the analytic streams
            from repro.comm.gossip import _leaf_routes
            from repro.comm.packing import make_bucket_spec
            from repro.launch.sharding import param_pspecs
            specs = param_pspecs(pshape, cfg, node_axis="data",
                                 fsdp_axis=None, model_size=0)
            leaves = jax.tree_util.tree_leaves(pshape)
            local = [jax.ShapeDtypeStruct((1,) + l.shape[1:], l.dtype)
                     for l in leaves]
            spec = make_bucket_spec(local,
                                    routes=_leaf_routes(specs, ("data",)))
            rec["n_buckets"] = spec.n_buckets
            per_bucket = [fused_bucket_streams(b.size * 4, b.size)
                          for b in spec.buckets]
            rec["bytes"] = sum(p["bytes"] for p in per_bucket)
            rec["streams"] = sum(p["full_streams"] for p in per_bucket)
            rec["per_bucket"] = per_bucket
            assert_invariant("choco_serial", "pallas",
                             {"pallas_calls": rec["pallas_calls"]},
                             dict(CONTEXT_VARS, buckets=spec.n_buckets,
                                  steps=1))
        exchanges[bk] = jax.jit(ex)
        states[bk] = args
        out[bk] = rec

    # parity contract on real arrays: round-1 x_hat is the wire witness
    res = {bk: exchanges[bk](*states[bk]) for bk in exchanges}
    hat_exact = all(
        bool(jnp.all(a == b)) for a, b in
        zip(jax.tree.leaves(res["jnp"][1]), jax.tree.leaves(res["pallas"][1])))
    drift = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                zip(jax.tree.leaves(res["jnp"]),
                    jax.tree.leaves(res["pallas"])))
    out["parity"] = {"round1_xhat_bit_exact": hat_exact,
                     "max_abs_drift": drift}
    assert hat_exact, "wire payloads diverged across kernel backends"
    assert drift < 1e-5, drift
    print("BENCH_FUSED_JSON=" + json.dumps(out))
""")


def fused_audit():
    """Run the subprocess audit and emit CSV rows + BENCH_fused.json."""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.path.join(SRC, ".."))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        emit("fused/audit", 0.0, f"ERROR:{r.stderr[-200:]}")
        return None
    line = [l for l in r.stdout.splitlines()
            if l.startswith("BENCH_FUSED_JSON=")][-1]
    out = json.loads(line.split("=", 1)[1])
    for name in ("jnp", "pallas"):
        rec = out[name]
        emit(f"fused/{name}", rec["bytes"] / HBM_BW * 1e6,
             f"streams={rec['streams']};bytes={rec['bytes']};"
             f"hbm_bw={HBM_BW:.0f}")
    out["config"] = {"arch": "qwen3-1.7b-smoke", "devices": 8,
                     "compressor": "qsgd", "s": 16, "topology": "ring",
                     "stream_threshold": STREAM_THRESHOLD,
                     "hbm_bw": HBM_BW,
                     "us_per_round_roofline": {
                         name: out[name]["bytes"] / HBM_BW * 1e6
                         for name in ("jnp", "pallas")}}
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    return out


def run():
    """Benchmark entry point (python -m benchmarks.run)."""
    fused_audit()


if __name__ == "__main__":
    run()
