"""Checkpoint-layer benchmark: sharded manifest-driven format vs legacy
flat-npz, on the qwen3-1.7b smoke TrainState.

Two sections:
  * save_restore — median wall time (us) for save and restore in both
    formats, with the checkpoint's on-disk bytes-per-host as the derived
    column.  Run for state_dtype float32 and bfloat16: the legacy flat
    format widens bf16 error-feedback state to f32 (npz cannot store
    ml_dtypes), while the sharded manifest bit-casts it to uint16 — half
    the bytes for the x_hat/s payload, recorded lossless.
  * restore_modes — sharded restore into target shardings (the production
    resume path: no host-gather, no donor state) vs host-numpy assembly.

Methodology notes live in EXPERIMENTS.md §Checkpointing.
"""
import os
import shutil
import tempfile

import jax

from repro.checkpoint.checkpointing import (restore_pytree, restore_sharded,
                                            save_pytree, save_sharded)
from .common import time_fn, emit


def _dir_bytes(path: str) -> int:
    if os.path.isfile(path):
        return os.path.getsize(path)
    return sum(os.path.getsize(os.path.join(r, f))
               for r, _, fs in os.walk(path) for f in fs)


def _make_trainer(state_dtype: str):
    from repro.configs.base import get_config, ChocoConfig
    from repro.models import build_model
    from repro.train.trainer import DecentralizedTrainer
    from repro.optim import momentum_sgd, constant_schedule
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("qwen3-1.7b", smoke=True)
    tr = DecentralizedTrainer(
        model=build_model(cfg),
        choco=ChocoConfig(compressor="top_k",
                          comp_kwargs=(("fraction", 0.01),),
                          state_dtype=state_dtype),
        mesh=mesh, n_nodes=1, optimizer=momentum_sgd(),
        lr_fn=constant_schedule(0.1))
    return tr, tr.init_state(jax.random.PRNGKey(0))


def save_restore():
    for sdt, tag in (("float32", "f32"), ("bfloat16", "bf16")):
        tr, state = _make_trainer(sdt)
        shape = jax.eval_shape(lambda: state)
        shardings = tr.state_shardings(shape)
        work = tempfile.mkdtemp(prefix="bench_ckpt_")
        flat_path = os.path.join(work, "flat.npz")
        shard_dir = os.path.join(work, "sharded")
        host = jax.device_get(state)

        us = time_fn(lambda: save_pytree(flat_path, host), iters=3)
        emit(f"checkpoint/legacy_save_{tag}", us,
             f"MB_per_host={_dir_bytes(flat_path) / 1e6:.1f}")
        us = time_fn(lambda: restore_pytree(flat_path, shape), iters=3)
        emit(f"checkpoint/legacy_restore_{tag}", us, "host_gathered=1")

        us = time_fn(lambda: save_sharded(
            shard_dir, state, step=0,
            fingerprint=tr.fingerprint()), iters=3)
        emit(f"checkpoint/sharded_save_{tag}", us,
             f"MB_per_host={_dir_bytes(shard_dir) / 1e6:.1f}")
        us = time_fn(lambda: restore_sharded(shard_dir, shape, shardings),
                     iters=3)
        emit(f"checkpoint/sharded_restore_{tag}", us,
             "into_target_shardings=1")
        shutil.rmtree(work, ignore_errors=True)


def restore_modes():
    tr, state = _make_trainer("bfloat16")
    shape = jax.eval_shape(lambda: state)
    shardings = tr.state_shardings(shape)
    work = tempfile.mkdtemp(prefix="bench_ckpt_")
    shard_dir = os.path.join(work, "sharded")
    save_sharded(shard_dir, state, step=0, fingerprint=tr.fingerprint())
    us = time_fn(lambda: restore_sharded(shard_dir, shape, shardings), iters=3)
    emit("checkpoint/restore_into_shardings", us, "mode=device")
    us = time_fn(lambda: restore_sharded(shard_dir, shape), iters=3)
    emit("checkpoint/restore_host_numpy", us, "mode=host")
    shutil.rmtree(work, ignore_errors=True)


def run():
    save_restore()
    restore_modes()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
