"""Kernel micro-benchmarks.

CPU wall time covers the pure-jnp oracles (interpret-mode Pallas timing is
meaningless); the derived column reports the TPU roofline time for the
kernel's HBM traffic at 819 GB/s — the number the Pallas kernel targets."""
import jax
import jax.numpy as jnp

from repro.kernels import ref
from .common import HBM_BW, time_fn, emit


def run():
    d = 1 << 22
    x = jax.random.normal(jax.random.PRNGKey(0), (d // 128, 128))
    xi = jax.random.uniform(jax.random.PRNGKey(1), (d // 128, 128))

    f = jax.jit(lambda a, b: ref.qsgd_quantize_ref(a, b, 16))
    us = time_fn(f, x, xi)
    bytes_moved = d * 4 * 2 + d          # read x, xi; write int8
    emit("kernels/qsgd_quantize_ref", us,
         f"d={d};tpu_roofline_us={bytes_moved / HBM_BW * 1e6:.1f};"
         f"hbm_bw={HBM_BW:.0f}")

    f = jax.jit(lambda a: ref.block_topk_mask_ref(a, 13))
    us = time_fn(f, x)
    bytes_moved = d * 4 * 2
    emit("kernels/block_topk_ref", us,
         f"d={d};tpu_roofline_us={bytes_moved / HBM_BW * 1e6:.1f};"
         f"hbm_bw={HBM_BW:.0f}")

    args = [jax.random.normal(jax.random.PRNGKey(i), (d // 128, 128))
            for i in range(5)]
    f = jax.jit(lambda *a: ref.ef_gossip_update_ref(*a, 1 / 3, 1 / 3, 0.05))
    us = time_fn(f, *args)
    bytes_moved = d * 4 * 8              # 5 reads + 3 writes
    emit("kernels/ef_gossip_update_ref", us,
         f"d={d};tpu_roofline_us={bytes_moved / HBM_BW * 1e6:.1f};"
         f"hbm_bw={HBM_BW:.0f}")

    B, S, H, Dh = 1, 1024, 4, 128
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, Dh)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, Dh)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, Dh))
    f = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c, causal=True))
    us = time_fn(f, q, k, v)
    flops = 4 * B * H * S * S * Dh
    emit("kernels/attention_ref", us,
         f"S={S};tpu_compute_us={flops / 197e12 * 1e6:.1f}")


if __name__ == "__main__":
    run()
