"""Paper Figures 2-3: average consensus on ring n=25, d=2000.

Schemes: exact gossip (E-G), Q1-G, Q2-G (unbiased qsgd), CHOCO-Gossip with
qsgd_256 / rand_1% / top_1%.  Derived column: final consensus error and total
transmitted megabits (the paper's two x-axes)."""
import jax
import jax.numpy as jnp

from repro.core import (ring, QSGD, RandK, TopK, Identity,
                        run_choco_gossip, run_gossip_baseline)
from .common import time_fn, emit

N, D = 25, 2000
STEPS = 300


def _bits_per_round(comp, n=N, d=D, degree=2):
    # every node sends its payload to each neighbour per round
    return comp.wire_bits(d) * n * degree


def run():
    topo = ring(N)
    W = jnp.asarray(topo.W)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (N, D))

    def bench(name, fn, comp, steps=STEPS):
        us = time_fn(fn, iters=2) / steps
        _, errs = fn()
        bits = _bits_per_round(comp) * steps / 1e6
        emit(f"consensus/{name}", us,
             f"err0={float(errs[0]):.3e};err@{steps}={float(errs[-1]):.3e};"
             f"Mbits={bits:.1f}")

    bench("exact_EG",
          lambda: run_gossip_baseline("exact", x0, W, None, STEPS),
          Identity())
    bench("Q1_qsgd256",
          lambda: run_gossip_baseline("q1", x0, W, QSGD(256, rescale=False),
                                      STEPS, key=jax.random.PRNGKey(1)),
          QSGD(256))
    bench("Q2_qsgd256",
          lambda: run_gossip_baseline("q2", x0, W, QSGD(256, rescale=False),
                                      STEPS, key=jax.random.PRNGKey(1)),
          QSGD(256))
    bench("choco_qsgd256",
          lambda: run_choco_gossip(x0, W, 1.0, QSGD(256), STEPS),
          QSGD(256))
    bench("choco_rand1pct",
          lambda: run_choco_gossip(x0, W, 0.011, RandK(fraction=0.01), 1500),
          RandK(fraction=0.01), steps=1500)
    bench("choco_top1pct",
          lambda: run_choco_gossip(x0, W, 0.046, TopK(fraction=0.01), 3000),
          TopK(fraction=0.01), steps=3000)


if __name__ == "__main__":
    run()
