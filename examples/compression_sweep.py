"""Ablation: accuracy-vs-bits frontier of CHOCO-SGD across compression
operators and ratios (paper §5.3, extended).

Sweeps top_k / rand_k / qsgd over ratios on sorted logistic regression and
prints the (transmitted megabits, final loss) frontier — the practical answer
to "how hard can I compress before it hurts?".

Run: PYTHONPATH=src python examples/compression_sweep.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import (ring, TopK, RandK, QSGD, Identity, run_choco_sgd,
                        experiment_lr_schedule, auto_gamma)
from repro.data.synthetic import make_logreg

N, STEPS = 9, 1500


def main():
    prob = make_logreg("epsilon", n_nodes=N, sorted_assignment=True,
                       m=1152, d=256, seed=2)
    grad_fn = prob.make_grad_fn(batch_size=4)
    lr = experiment_lr_schedule(1, 300.0, 300.0)
    W = jnp.asarray(ring(N).W)
    topo = ring(N)

    def run(comp, gamma):
        _, t = run_choco_sgd(jnp.zeros((N, prob.d)), W, grad_fn, comp, lr,
                             gamma, STEPS, key=jax.random.PRNGKey(0),
                             eval_fn=prob.full_loss)
        mbits = comp.wire_bits(prob.d) * N * 2 * STEPS / 1e6
        return float(t[-1]), mbits

    print(f"{'operator':24s} {'omega':>8s} {'gamma':>8s} {'Mbits':>9s} {'loss':>8s}")
    loss, mb = run(Identity(), 1.0)
    print(f"{'exact':24s} {1.0:8.3f} {1.0:8.3f} {mb:9.1f} {loss:8.4f}")
    for frac in (0.2, 0.05, 0.01):
        for name, comp in ((f"top_{frac:.0%}", TopK(fraction=frac)),
                           (f"rand_{frac:.0%}", RandK(fraction=frac))):
            gamma = max(auto_gamma(topo.delta, topo.beta, comp.omega(prob.d)),
                        0.04)
            loss, mb = run(comp, gamma)
            print(f"{name:24s} {comp.omega(prob.d):8.3f} {gamma:8.3f} "
                  f"{mb:9.1f} {loss:8.4f}")
    for s in (2, 16, 127):
        comp = QSGD(s)
        gamma = 0.2 if s < 16 else 0.5
        loss, mb = run(comp, gamma)
        print(f"{'qsgd_' + str(s):24s} {comp.omega(prob.d):8.3f} {gamma:8.3f} "
              f"{mb:9.1f} {loss:8.4f}")


if __name__ == "__main__":
    main()
