"""Serving example: batched prefill + autoregressive decode with a KV cache.

Runs the reduced config of any assigned architecture (including the SSM and
hybrid ones, whose decode is O(1)-state) and greedy-decodes a batch of
requests.  The same serve_step lowers against the production mesh in
launch/dryrun.py for the decode_32k / long_500k shapes.

Run: PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-3b
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b",
                    help="any assigned arch (reduced config)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch: no decode step (see DESIGN.md)")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P, G = args.batch, args.prompt_len, args.gen_len
    max_seq = P + G

    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab_size)

    # prefill: build the cache at full length, then splice prompt KV in.
    # (production path prefills into the padded cache directly)
    cache = model.init_cache(B, max_seq)
    decode = jax.jit(model.decode_step)
    t0 = time.time()
    tok = prompt[:, :1]
    # teacher-force the prompt through the decode path (exercises the cache),
    # then generate greedily
    out = []
    for t in range(max_seq - 1):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = decode(params, tok, cache, pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        tok = prompt[:, t + 1:t + 2] if t + 1 < P else nxt
        if t + 1 >= P:
            out.append(nxt)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} family={cfg.family}")
    print(f"decoded {G} tokens x {B} requests in {dt:.2f}s "
          f"({B * G / dt:.1f} tok/s on CPU)")
    print("generated token ids (request 0):", gen[0].tolist())
    assert gen.shape == (B, G - 1 + 1)


if __name__ == "__main__":
    main()
