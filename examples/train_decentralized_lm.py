"""End-to-end driver: decentralized CHOCO-SGD training of a transformer LM.

Simulates a gossip ring of data-parallel nodes on CPU host devices (the same
code path lowers to the TPU production mesh via launch/train.py).  Default is
a fast CPU-sized run; --model-scale 100m trains a ~100M-parameter qwen3-family
model for --steps steps.

Run:
    python examples/train_decentralized_lm.py                      # 2-min demo
    python examples/train_decentralized_lm.py --model-scale 100m --steps 300
    python examples/train_decentralized_lm.py --mode allreduce     # baseline
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
N_DEVICES = 8
os.environ.setdefault("XLA_FLAGS",
                      f"--xla_force_host_platform_device_count={N_DEVICES}")

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, ChocoConfig
from repro.models import build_model
from repro.models.transformer import count_params
from repro.train.trainer import DecentralizedTrainer
from repro.optim import momentum_sgd, cosine_schedule
from repro.data.synthetic import make_lm_batch_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--model-scale", default="tiny", choices=["tiny", "20m", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch-per-node", type=int, default=4)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--mode", default="choco", choices=["choco", "plain", "allreduce"])
    ap.add_argument("--compressor", default="top_k")
    ap.add_argument("--fraction", type=float, default=0.01)
    ap.add_argument("--heterogeneity", type=float, default=1.0,
                    help="1.0 = paper's hardest 'sorted' data assignment")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if args.model_scale == "20m":
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=512, n_heads=8,
                                  n_kv_heads=4, head_dim=64, d_ff=1536,
                                  vocab_size=8192)
    elif args.model_scale == "100m":
        cfg = dataclasses.replace(cfg, n_layers=8, d_model=768, n_heads=12,
                                  n_kv_heads=4, head_dim=64, d_ff=3072,
                                  vocab_size=32768)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={count_params(cfg) / 1e6:.1f}M "
          f"nodes={args.nodes} mode={args.mode} "
          f"compressor={args.compressor}@{args.fraction}")

    mesh = jax.make_mesh((args.nodes, N_DEVICES // args.nodes),
                         ("data", "model"))
    trainer = DecentralizedTrainer(
        model=model,
        choco=ChocoConfig(compressor=args.compressor,
                          comp_kwargs=(("fraction", args.fraction),)),
        mesh=mesh, n_nodes=args.nodes,
        optimizer=momentum_sgd(beta=0.9),
        lr_fn=cosine_schedule(0.2, warmup=10, total=args.steps),
        mode=args.mode)
    print(f"consensus stepsize gamma = {trainer.gamma:.4f}")

    state = trainer.init_state(jax.random.PRNGKey(0))
    next_batch = make_lm_batch_fn(cfg, args.seq_len, args.batch_per_node,
                                  args.nodes, args.heterogeneity)
    batch0 = jax.tree.map(jnp.asarray, next_batch())
    step = trainer.jitted_train_step(jax.eval_shape(lambda: state),
                                     jax.eval_shape(lambda: batch0))

    t0 = time.time()
    for i in range(args.steps):
        batch = jax.tree.map(jnp.asarray, next_batch())
        state, mets = step(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(mets['loss']):.4f}  "
                  f"lr {float(mets['lr']):.4f}  "
                  f"grad_norm {float(mets['grad_norm']):.2f}  "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)")

    if args.checkpoint:
        trainer.save_checkpoint(args.checkpoint, state,
                                metadata={"arch": cfg.name})
        print(f"saved sharded checkpoint to {args.checkpoint}/ "
              f"(manifest.json + per-host shards; resume via "
              f"launch/train.py --resume)")


if __name__ == "__main__":
    main()
