"""Quickstart: CHOCO-Gossip average consensus in 30 lines.

25 simulated nodes on a ring agree on the mean of their vectors while
transmitting only 1% of the coordinates per round (top-k compression).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import ring, TopK, QSGD, run_choco_gossip, run_gossip_baseline

n, d = 25, 2000
topo = ring(n)
W = jnp.asarray(topo.W)
x0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
print(f"ring(n={n}): spectral gap delta={topo.delta:.4f}")

# exact gossip baseline (full vectors on the wire)
_, err_exact = run_gossip_baseline("exact", x0, W, None, 300)
print(f"[exact  ] err: {err_exact[0]:.2e} -> {err_exact[-1]:.2e}  "
      f"(32*d bits/msg)")

# CHOCO-Gossip with 8-bit quantization: same rate, 4x fewer bits
comp = QSGD(127)
_, err_q = run_choco_gossip(x0, W, 1.0, comp, 300)
print(f"[qsgd   ] err: {err_q[0]:.2e} -> {err_q[-1]:.2e}  "
      f"({comp.wire_bits(d) / d:.1f} bits/coord)")

# CHOCO-Gossip with 99% sparsification: still converges (Theorem 2)
comp = TopK(fraction=0.01)
_, err_s = run_choco_gossip(x0, W, 0.046, comp, 3000)
print(f"[top 1% ] err: {err_s[0]:.2e} -> {err_s[-1]:.2e}  "
      f"(~{100 * comp.omega(d):.0f}% of coords/msg)")
