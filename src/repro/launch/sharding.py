"""Sharding rules: map every parameter / state / batch leaf to a
PartitionSpec.

Layout summary (single-pod mesh (data=16, model=16)):
  * gossip node axis  = "data"  (leading dim of every decentralized leaf)
  * tensor parallel   = "model" (attention heads, FFN hidden, experts, vocab)
Multi-pod mesh (pod=2, data=16, model=16):
  * gossip node axis  = "pod"
  * FSDP              = "data"  (the non-model matrix dim of big weights)
  * tensor parallel   = "model"
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

# leaf names -> how the *trailing* (block-level) dims shard.
#   "col": 2D (in, out) -> out over model          e.g. wq, w_up
#   "row": 2D (in, out) -> in  over model          e.g. wo, w_down
#   "expert": 3D (E, in, out) -> E over model
#   "vocab_in": (V, D) -> V over model
#   "repl": replicated
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "w_x", "w_z", "unembed",
        "in_proj", "w1", "w2", "w_g"}
_COL_NOFSDP = {"conv_x"}        # tiny first dim (d_conv): never FSDP-shard
_ROW = {"wo", "w_down", "w_out", "w_o"}
_VOCAB = {"tok"}


def _base_kind(path_names: Tuple[str, ...], leaf: jax.ShapeDtypeStruct) -> str:
    name = path_names[-1]
    parents = set(path_names[:-1])
    if "moe" in parents and "shared" not in parents \
            and name in ("w_gate", "w_up", "w_down"):
        return "expert"
    if "cm" in parents:           # rwkv channel-mix
        return {"w_k": "col", "w_v": "row", "w_r": "repl"}.get(name, "repl")
    if "tm" in parents:           # rwkv time-mix
        if name in ("w_r", "w_k", "w_v", "w_g"):
            return "col"
        if name == "w_o":
            return "row"
        return "repl"
    if name == "head":            # audio class head (504 classes: tiny, repl)
        return "repl"
    if name in _COL:
        return "col"
    if name in _COL_NOFSDP:
        return "col_nofsdp"
    if name in _ROW:
        return "row"
    if name in _VOCAB:
        return "vocab_in"
    return "repl"


def _trailing_spec(kind: str, model: str, fsdp: Optional[str]) -> Tuple:
    if kind == "col":
        return (fsdp, model)
    if kind == "col_nofsdp":
        return (None, model)
    if kind == "row":
        return (model, fsdp)
    if kind == "expert":
        return (model, fsdp, None)
    if kind == "vocab_in":
        return (model, fsdp)
    return ()


def param_pspecs(params_shape: Any, cfg: ModelConfig, *,
                 node_axis: Optional[str], model_axis: str = "model",
                 fsdp_axis: Optional[str] = None, model_size: int = 0):
    """PartitionSpec pytree for a param(-like) pytree.

    node_axis: mesh axis for the leading decentralized-node dim (None for
    serving, where params have no node dim).
    model_size: size of the model axis — KV projections whose head count does
    not divide it are replicated (col-sharding them makes GSPMD insert
    permute-reshards of k/v every layer; EXPERIMENTS.md §Perf A)."""
    kv_shardable = model_size <= 0 or cfg.n_kv_heads % model_size == 0

    def spec(path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        kind = _base_kind(names, leaf)
        if names[-1] in ("wk", "wv") and "attn" in names and not kv_shardable:
            kind = "repl"
        base = _trailing_spec(kind, model_axis, fsdp_axis)
        lead = (node_axis,) if node_axis else ()
        pad = leaf.ndim - len(lead) - len(base)
        if pad < 0:      # scalar / vector leaves: drop the base
            base = ()
            pad = leaf.ndim - len(lead)
        return P(*(lead + (None,) * pad + base))

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def batch_pspecs(batch_shape: Any, *, node_axis: Optional[str],
                 dp_axis: Optional[str] = None):
    """Batch leaves: (node, B_local, ...) -> P(node, dp, None...)."""
    def spec(leaf):
        lead = []
        if node_axis:
            lead.append(node_axis)
        if dp_axis and leaf.ndim > len(lead):
            lead.append(dp_axis)
        return P(*(tuple(lead) + (None,) * (leaf.ndim - len(lead))))
    return jax.tree.map(spec, batch_shape)


def cache_pspecs(cache_shape: Any, cfg: ModelConfig, *, batch: int,
                 model_axis: str = "model", dp_axes: Tuple[str, ...] = ("data",),
                 mesh_shape=None, kv_layout: str = "head"):
    """KV/state caches for serving.

    Layout: leading repeat/stack dim unsharded; batch dim over dp axes when it
    divides, otherwise the long sequence dim shards over the dp axes
    (sequence-parallel KV for long_500k); KV-head / SSM-head dims over model.
    """
    dp = tuple(a for a in dp_axes if a)

    def total(axes):
        t = 1
        for a in axes:
            t *= mesh_shape[a]
        return t

    batch_ok = mesh_shape is not None and batch % max(total(dp), 1) == 0 and batch >= total(dp)

    def spec(path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        name = names[-1]
        # stacked caches have a leading `repeat` dim when under "stack"
        stacked = "stack" in names
        lead = (None,) if stacked else ()
        nd = leaf.ndim - len(lead)
        if name in ("k", "v"):
            msz = mesh_shape[model_axis] if mesh_shape else 1
            bs = _maybe(dp, batch_ok)
            layout = kv_layout
            if layout == "auto":
                # flash-decoding (seq) layout whenever the KV-head count does
                # not divide the model axis — head-sharding then forces GSPMD
                # to reshard the cache every layer (EXPERIMENTS.md §Perf C)
                kv = leaf.shape[-2]
                layout = "head" if kv % msz == 0 else "seq"
            if layout == "seq":
                # flash-decoding layout: cache length over the model axis;
                # softmax/contraction reductions become tiny cross-shard ops
                return P(*(lead + (bs, model_axis, None, None)))
            # "head" layout: (B, C, KV, Dh) heads over model if divisible,
            # else head_dim, else replicate across model
            kv, dh = leaf.shape[-2], leaf.shape[-1]
            if kv % msz == 0:
                hspec = (model_axis, None)
            elif dh % msz == 0:
                hspec = (None, model_axis)
            else:
                hspec = (None, None)
            cs = None if batch_ok else _maybe(dp, True)
            return P(*(lead + (bs, cs) + hspec))
        if name == "ssm":
            # (B, H, N, P): batch over dp, heads over model
            msz = mesh_shape[model_axis] if mesh_shape else 1
            bs = _maybe(dp, batch_ok)
            h = leaf.shape[-3]
            hs = model_axis if h % msz == 0 else None
            return P(*(lead + (bs, hs) + (None,) * (nd - 2)))
        if name in ("conv_x",):
            bs = _maybe(dp, batch_ok)
            return P(*(lead + (bs, None, model_axis) + (None,) * (nd - 3)))
        if name in ("conv_B", "conv_C"):
            bs = _maybe(dp, batch_ok)
            return P(*(lead + (bs,) + (None,) * (nd - 1)))
        if name == "wkv":
            # (B, H, P, P): heads over model if divisible, else first P dim
            msz = mesh_shape[model_axis] if mesh_shape else 1
            bs = _maybe(dp, batch_ok)
            h, pdim = leaf.shape[-3], leaf.shape[-2]
            if h % msz == 0:
                hs = (model_axis, None, None)
            elif pdim % msz == 0:
                hs = (None, model_axis, None)
            else:
                hs = (None, None, None)
            return P(*(lead + (bs,) + hs))
        if name in ("shift_tm", "shift_cm"):
            bs = _maybe(dp, batch_ok)
            return P(*(lead + (bs,) + (None,) * (nd - 1)))
        return P(*((None,) * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def bytes_per_device(shapes_tree, specs_tree, mesh) -> int:
    """Analytic per-device bytes for a pytree of ShapeDtypeStructs sharded by
    the given PartitionSpecs (ground truth for the dry-run memory report —
    CompiledMemoryStats argument accounting on the host backend is unreliable)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    leaves = jax.tree.leaves(shapes_tree)
    specs = jax.tree.leaves(specs_tree, is_leaf=lambda x: isinstance(x, P))
    total = 0
    for leaf, spec in zip(leaves, specs):
        div = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                div *= sizes[a]
        total += leaf.size * jnp.dtype(leaf.dtype).itemsize // div
    return total


def _maybe(dp, batch_ok):
    if not batch_ok or not dp:
        return None
    return dp if len(dp) > 1 else dp[0]


def _kv_spec(lead, nd, dp, batch_ok, model_axis):
    """(B, C, KV, Dh): batch over dp when divisible, else cache length over dp."""
    bs = _maybe(dp, batch_ok)
    cs = None if batch_ok else _maybe(dp, True)
    return P(*(lead + (bs, cs, model_axis) + (None,) * (nd - 3)))
