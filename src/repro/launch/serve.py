"""Serving launcher: batched prefill + decode loop against the production
mesh (or a simulated CPU mesh).

    python -m repro.launch.serve --arch gemma2-9b --smoke \
        --simulate-devices 8 --mesh 4x2 --batch 8 --gen-len 16

Latency is reported per request, not as one run-wide aggregate: TTFT
(prompt ingest + first generated token, blocked on the token) p50/p99
across ``--requests``, and per-token decode time p50/p99 across every
generated step.  ``--metrics-dir`` writes the same numbers as
registry-validated records (obs/schema.py).
"""
import argparse
import os
import sys
import time

# jax-free imports: safe before XLA_FLAGS is frozen by the first jax import
from repro.launch.env import simulate_host_devices
from repro.obs.sinks import JsonlSink, MetricLog, StdoutSink
from repro.obs.timers import percentile
from repro.obs.trace import annotate


def _stdout_line(record):
    """Log lines verbatim; the serve summary as one compact line."""
    kind = record.get("kind")
    if kind == "log":
        return record.get("msg", "")
    if kind != "metrics":
        return None
    parts = " ".join(f"{k.split('/', 1)[1]} {v:.4f}"
                     for k, v in sorted(record.items())
                     if k.startswith("serve/"))
    return f"[serve] {parts}" if parts else None


def main(argv=None):
    """CLI driver: batched prefill then a greedy decode loop per request,
    reporting TTFT and per-token latency percentiles."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--kv-layout", default="head", choices=["head", "seq"])
    ap.add_argument("--requests", type=int, default=1,
                    help="decode requests to run (fresh cache each); "
                         "latency percentiles aggregate across them")
    ap.add_argument("--metrics-dir", default=None,
                    help="write latency records to metrics.jsonl "
                         "(obs/schema.py registry)")
    ap.add_argument("--simulate-devices", type=int, default=0)
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args(argv)
    if args.requests < 1:
        ap.error(f"--requests must be >= 1, got {args.requests}")

    if args.simulate_devices:
        simulate_host_devices(args.simulate_devices)

    sinks = [StdoutSink(formatter=_stdout_line)]
    if args.metrics_dir:
        sinks.append(JsonlSink(os.path.join(args.metrics_dir,
                                            "metrics.jsonl")))
    mlog = MetricLog(sinks)

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.launch.mesh import make_production_mesh, make_mesh
    from repro.launch.sharding import param_pspecs, cache_pspecs

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        mesh = make_mesh(dims, axes)
    else:
        mesh = make_production_mesh()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no decode step (DESIGN.md §4)")
    model = build_model(cfg)
    B, Pl, G = args.batch, args.prompt_len, args.gen_len
    max_seq = Pl + G

    pspecs = param_pspecs(jax.eval_shape(model.init, jax.random.PRNGKey(0)),
                          cfg, node_axis=None)
    shard = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                      is_leaf=lambda x: isinstance(x, P))
    params = jax.jit(model.init, out_shardings=shard(pspecs))(jax.random.PRNGKey(0))

    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    cache0 = model.init_cache(B, max_seq)
    cspecs = cache_pspecs(jax.eval_shape(lambda: cache0), cfg, batch=B,
                          dp_axes=("data",), mesh_shape=mesh_shape,
                          kv_layout=args.kv_layout)

    decode = jax.jit(model.decode_step,
                     in_shardings=(shard(pspecs), None, shard(cspecs), None),
                     out_shardings=(None, shard(cspecs)),
                     donate_argnums=(2,))

    mlog.header(arch=cfg.name, kv_layout=args.kv_layout, batch=B,
                prompt_len=Pl, gen_len=G, requests=args.requests,
                jax_version=jax.__version__, mesh=mesh_shape)

    key = jax.random.PRNGKey(1)
    ttfts, tok_times = [], []
    out = []
    t_all = time.perf_counter()
    try:
        for r in range(args.requests):
            cache = jax.device_put(model.init_cache(B, max_seq),
                                   shard(cspecs))
            prompt = jax.random.randint(jax.random.fold_in(key, r),
                                        (B, Pl), 0, cfg.vocab_size)
            tok = prompt[:, :1]
            out = []
            req_t0 = time.perf_counter()
            last = req_t0
            with annotate("serve:request"):
                for t in range(max_seq - 1):
                    pos = jnp.full((B,), t, jnp.int32)
                    logits, cache = decode(params, tok, cache, pos)
                    nxt = jnp.argmax(logits[:, -1],
                                     axis=-1).astype(jnp.int32)[:, None]
                    tok = prompt[:, t + 1:t + 2] if t + 1 < Pl else nxt
                    if t + 1 >= Pl:
                        # block per generated token: per-token latency is
                        # the serving metric, async dispatch would hide it
                        nxt.block_until_ready()
                        now = time.perf_counter()
                        if t + 1 == Pl:
                            ttfts.append(now - req_t0)   # TTFT
                        else:
                            tok_times.append(now - last)
                        last = now
                        out.append(nxt)
        dt = time.perf_counter() - t_all
        total_tok = B * len(out) * args.requests
        summary = {"serve/ttft_p50_s": percentile(ttfts, 50),
                   "serve/ttft_p99_s": percentile(ttfts, 99),
                   "serve/throughput_tok_s": total_tok / dt}
        if tok_times:   # gen-len 1: TTFT is the only per-token sample
            summary["serve/tok_p50_s"] = percentile(tok_times, 50)
            summary["serve/tok_p99_s"] = percentile(tok_times, 99)
        mlog.emit(0, summary)
        mlog.log(f"[serve] arch={cfg.name} kv_layout={args.kv_layout} "
                 f"decoded {len(out) * args.requests}x{B} tokens in "
                 f"{dt:.2f}s ({total_tok / dt:.1f} tok/s)")
    finally:
        mlog.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
