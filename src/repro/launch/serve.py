"""Serving launcher: batched prefill + decode loop against the production
mesh (or a simulated CPU mesh).

    python -m repro.launch.serve --arch gemma2-9b --smoke \
        --simulate-devices 8 --mesh 4x2 --batch 8 --gen-len 16
"""
import argparse
import os
import sys
import time

from repro.launch.env import simulate_host_devices  # jax-free: pre-XLA_FLAGS


def main(argv=None):
    """CLI driver: batched prefill then a greedy decode loop, printing
    per-phase timings and tokens/s."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--kv-layout", default="head", choices=["head", "seq"])
    ap.add_argument("--simulate-devices", type=int, default=0)
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args(argv)

    if args.simulate_devices:
        simulate_host_devices(args.simulate_devices)

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.launch.mesh import make_production_mesh, make_mesh
    from repro.launch.sharding import param_pspecs, cache_pspecs

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        mesh = make_mesh(dims, axes)
    else:
        mesh = make_production_mesh()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no decode step (DESIGN.md §4)")
    model = build_model(cfg)
    B, Pl, G = args.batch, args.prompt_len, args.gen_len
    max_seq = Pl + G

    pspecs = param_pspecs(jax.eval_shape(model.init, jax.random.PRNGKey(0)),
                          cfg, node_axis=None)
    shard = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                      is_leaf=lambda x: isinstance(x, P))
    params = jax.jit(model.init, out_shardings=shard(pspecs))(jax.random.PRNGKey(0))

    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    cache = model.init_cache(B, max_seq)
    cspecs = cache_pspecs(jax.eval_shape(lambda: cache), cfg, batch=B,
                          dp_axes=("data",), mesh_shape=mesh_shape,
                          kv_layout=args.kv_layout)
    cache = jax.device_put(cache, shard(cspecs))

    decode = jax.jit(model.decode_step,
                     in_shardings=(shard(pspecs), None, shard(cspecs), None),
                     out_shardings=(None, shard(cspecs)),
                     donate_argnums=(2,))

    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (B, Pl), 0, cfg.vocab_size)
    tok = prompt[:, :1]
    t0 = time.time()
    out = []
    for t in range(max_seq - 1):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = decode(params, tok, cache, pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        tok = prompt[:, t + 1:t + 2] if t + 1 < Pl else nxt
        if t + 1 >= Pl:
            out.append(nxt)
    dt = time.time() - t0
    print(f"[serve] arch={cfg.name} kv_layout={args.kv_layout} "
          f"decoded {len(out)}x{B} tokens in {dt:.2f}s "
          f"({B * len(out) / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
