"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run launcher sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE importing jax.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests / examples use small CPU meshes)."""
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_nodes: int = 1):
    """Degenerate single-host mesh for CPU smoke tests: (n_nodes, 1)."""
    n = len(jax.devices())
    assert n % n_nodes == 0, f"{n} devices not divisible by {n_nodes} nodes"
    return jax.make_mesh((n_nodes, n // n_nodes), ("data", "model"))


def gossip_axis_for(mesh) -> str:
    """Default gossip placement: 'pod' when present, else 'data'."""
    return "pod" if "pod" in mesh.axis_names else "data"
