"""Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
combination against the production mesh, print memory/cost analysis and the
roofline terms.  No real allocation: all inputs are ShapeDtypeStructs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --json out.json
"""
import os
from repro.launch.env import simulate_host_devices  # jax-free: pre-XLA_FLAGS
simulate_host_devices(512)

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (get_config, ARCH_IDS, INPUT_SHAPES, ChocoConfig)
from repro.models import build_model
from repro.train.trainer import DecentralizedTrainer
from repro.optim import sgd, constant_schedule
from repro.launch.mesh import make_production_mesh, gossip_axis_for
from repro.launch import specs as S
from repro.launch.sharding import param_pspecs, batch_pspecs, cache_pspecs
from repro.analysis.roofline import (analyze, model_flops_for, Roofline,
                                     parse_collectives)


def parse_collectives_from(compiled, n_devices):
    """CollectiveStats for a compiled executable (analysis.roofline)."""
    return parse_collectives(compiled.as_text(), n_devices)


def _shard(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_train(cfg, shape, mesh, mode: str = "choco",
                compressor: str = "top_k", comp_kwargs=(("fraction", 0.01),),
                state_dtype: str = "float32", topology: str = "ring"):
    """Lower (not compile) one decentralized train step for (cfg, shape)
    on ``mesh``; returns (lowered, info-dict with arg shapes/specs)."""
    gossip_axis = gossip_axis_for(mesh)
    n_nodes = mesh.shape[gossip_axis]
    if topology == "torus" and "pod" in mesh.axis_names:
        n_nodes = mesh.shape["pod"] * mesh.shape["data"]
    model = build_model(cfg)
    ccfg = ChocoConfig(compressor=compressor, comp_kwargs=tuple(comp_kwargs),
                       gossip_axis=gossip_axis, state_dtype=state_dtype,
                       topology=topology)
    tr = DecentralizedTrainer(model=model, choco=ccfg, mesh=mesh,
                              n_nodes=n_nodes, optimizer=sgd(),
                              lr_fn=constant_schedule(1e-2), mode=mode)
    state_shape = tr.state_shape()
    batch_shape = S.train_batch_specs(cfg, shape, n_nodes)
    jitted = tr.jitted_train_step(state_shape, batch_shape)
    info = {"arg_shapes": (state_shape, batch_shape),
            "arg_specs": (tr.state_pspecs(state_shape),
                          batch_pspecs(
                              batch_shape, node_axis=tr.gossip_axis, dp_axis=tr.fsdp_axis))}
    return jitted.lower(state_shape, batch_shape), info


def lower_prefill(cfg, shape, mesh, seq_shard: bool = False):
    """Lower one prefill step (optionally sequence-sharded) against the
    serving shardings; returns (lowered, info-dict)."""
    model = build_model(cfg)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    pspecs = param_pspecs(jax.eval_shape(model.init, jax.random.PRNGKey(0)), cfg,
                          node_axis=None, fsdp_axis=dp[0] if cfg.family == "moe" else None,
                          model_size=0)
    batch_shape = S.prefill_batch_specs(cfg, shape)
    dpa = dp if len(dp) > 1 else dp[0]
    if seq_shard:
        # sequence parallelism: tokens (B, S) sharded (data, model) so the
        # FFN/MoE activations never need a full-width all-reduce
        bspecs = jax.tree.map(
            lambda l: P(dpa, "model") if l.ndim == 2 else P(dpa, "model", None),
            batch_shape)
    else:
        bspecs = batch_pspecs(batch_shape, node_axis=None, dp_axis=dpa)

    def prefill_step(params, batch):
        logits, caches = model.prefill(params, batch)
        return logits

    fn = jax.jit(prefill_step,
                 in_shardings=(_shard(mesh, pspecs), _shard(mesh, bspecs)))
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    info = {"arg_shapes": (params_shape, batch_shape),
            "arg_specs": (pspecs, bspecs)}
    return fn.lower(params_shape, batch_shape), info


def lower_decode(cfg, shape, mesh, kv_layout: str = "auto"):
    """Lower one single-token decode step with sharded KV caches; returns
    (lowered, info-dict)."""
    model = build_model(cfg)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_pspecs(params_shape, cfg, node_axis=None,
                          fsdp_axis=dp[0] if cfg.family == "moe" else None,
                          model_size=0)
    dec = S.decode_specs(cfg, shape, model)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    cspecs = cache_pspecs(dec["caches"], cfg, batch=shape.global_batch,
                          dp_axes=dp, mesh_shape=mesh_shape,
                          kv_layout=kv_layout)
    dp_total = 1
    for a in dp:
        dp_total *= mesh_shape[a]
    batch_ok = shape.global_batch % dp_total == 0 and shape.global_batch >= dp_total
    tok_spec = P(dp if len(dp) > 1 else dp[0], None) if batch_ok else P(None, None)
    pos_spec = P(dp if len(dp) > 1 else dp[0]) if batch_ok else P(None)

    def serve_step(params, token, caches, pos):
        logits, new_caches = model.decode_step(params, token, caches, pos)
        return logits, new_caches

    fn = jax.jit(serve_step,
                 in_shardings=(_shard(mesh, pspecs),
                               NamedSharding(mesh, tok_spec),
                               _shard(mesh, cspecs),
                               NamedSharding(mesh, pos_spec)))
    info = {"arg_shapes": (params_shape, dec["caches"]),
            "arg_specs": (pspecs, cspecs)}
    return fn.lower(params_shape, dec["token"], dec["caches"], dec["pos"]), info


def lower_one(arch: str, shape_name: str, mesh, mode: str = "choco",
              compressor: str = "top_k", comp_kwargs=(("fraction", 0.01),),
              unroll: bool = True, overrides: Optional[Dict[str, Any]] = None,
              kv_layout: str = "auto", state_dtype: str = "float32",
              topology: str = "ring"):
    """Lower + compile one arch x shape combination, collect memory /
    roofline / collective analysis; returns the JSONL record dict
    (status ok | skip | fail) that ``analysis.report`` renders."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if unroll:
        cfg = _dc.replace(cfg, scan_unroll=True)
    if overrides:
        cfg_overrides = {k: v for k, v in overrides.items() if not k.startswith("_")}
        if cfg_overrides:
            cfg = _dc.replace(cfg, **cfg_overrides)
    shape = INPUT_SHAPES[shape_name]
    skip = S.applicability(cfg, shape)
    if skip:
        return None, skip, None
    if shape.kind == "train":
        lowered, info = lower_train(cfg, shape, mesh, mode, compressor,
                                    comp_kwargs, state_dtype=state_dtype,
                                    topology=topology)
    elif shape.kind == "prefill":
        lowered, info = lower_prefill(cfg, shape, mesh,
                                      seq_shard=bool((overrides or {}).get("_seq_shard", False)))
    else:
        lowered, info = lower_decode(cfg, shape, mesh, kv_layout=kv_layout)
    return lowered, None, info


def run_one(arch: str, shape_name: str, *, multi_pod: bool, mode: str = "choco",
            compressor: str = "top_k", comp_kwargs=(("fraction", 0.01),),
            verbose: bool = True, skip_roofline: bool = False,
            overrides: Optional[Dict[str, Any]] = None,
            kv_layout: str = "auto", state_dtype: str = "float32",
            topology: str = "ring") -> Dict[str, Any]:
    """One (arch x shape x mesh) dry-run.

    Phase A (the compile proof): the production config with the layer stack as
    lax.scan — compile must succeed; memory_analysis comes from this module
    (realistic buffer reuse).

    Phase B (roofline terms): two small *unrolled* variants with repeat=1 and
    repeat=2 of the block pattern; every cost term is linear in the repeat
    count, so  cost(L) = base + units * delta  with delta = cost(2)-cost(1)
    gives exact full-depth HLO flops / bytes / collective bytes without
    compiling a 48-layer unrolled SPMD module.
    """
    from repro.models.transformer import block_pattern
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.size
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)), "mode": mode,
    }
    t0 = time.time()
    try:
        # ---- Phase A: full-config compile proof (scan) --------------------
        lowered, skip, info = lower_one(arch, shape_name, mesh, mode, compressor,
                                        comp_kwargs, unroll=False,
                                        overrides=overrides, kv_layout=kv_layout,
                                        state_dtype=state_dtype, topology=topology)
        if skip:
            rec["status"] = "skip"
            rec["reason"] = skip
            if verbose:
                print(f"[skip] {arch} x {shape_name}: {skip}", flush=True)
            return rec
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
        from repro.launch.sharding import bytes_per_device
        rec["memory"]["analytic_arg_bytes_per_device"] = int(sum(
            bytes_per_device(sh, sp, mesh)
            for sh, sp in zip(info["arg_shapes"], info["arg_specs"])))
        stats_full = parse_collectives_from(compiled, n_devices)
        rec["collectives_scan_module"] = {"counts": stats_full.counts}
        rec["status"] = "ok"

        # ---- Phase B: per-layer-unit cost extrapolation --------------------
        if not skip_roofline:
            pattern, repeat, tail = block_pattern(cfg)
            unit = len(pattern)
            units_eff = cfg.n_layers / unit          # fractional for tail archs
            costs = []
            for r in (1, 2):
                ovr = dict(overrides or {})
                ovr["n_layers"] = unit * r
                if cfg.hybrid is not None:           # keep pattern identical
                    pass
                low_r, _, _ = lower_one(arch, shape_name, mesh, mode, compressor,
                                        comp_kwargs, unroll=True, overrides=ovr,
                                        kv_layout=kv_layout,
                                        state_dtype=state_dtype, topology=topology)
                comp_r = low_r.compile()
                rl_r, st_r = analyze(comp_r, n_devices=n_devices, model_flops=1.0)
                costs.append({
                    "flops": rl_r.flops, "bytes": rl_r.bytes_accessed,
                    "wire": rl_r.wire_bytes, "wire_by_kind": st_r.wire_bytes,
                    "counts": st_r.counts,
                })
            delta = {k: costs[1][k] - costs[0][k] for k in ("flops", "bytes", "wire")}
            base = {k: costs[0][k] - delta[k] for k in delta}
            full = {k: max(base[k] + units_eff * delta[k], 0.0) for k in delta}
            rl = Roofline(flops=full["flops"], bytes_accessed=full["bytes"],
                          wire_bytes=full["wire"], n_devices=n_devices,
                          model_flops=model_flops_for(cfg, shape))
            rec["roofline"] = rl.row()
            rec["per_unit"] = {"delta": delta, "base": base, "units_eff": units_eff}
            wire_kind = {}
            for k in costs[1]["wire_by_kind"]:
                d = costs[1]["wire_by_kind"][k] - costs[0]["wire_by_kind"][k]
                b = costs[0]["wire_by_kind"][k] - d
                wire_kind[k] = max(b + units_eff * d, 0.0)
            rec["collectives"] = {"wire_bytes_extrapolated": wire_kind,
                                  "counts_unit2": costs[1]["counts"]}
        rec["total_s"] = round(time.time() - t0, 1)
        if verbose:
            print(f"[ok]   {arch} x {shape_name} ({rec['mesh']}, {mode}) "
                  f"compile={rec['compile_s']}s total={rec['total_s']}s", flush=True)
            print(f"       memory: {rec['memory']}", flush=True)
            if "roofline" in rec:
                r = rec["roofline"]
                print(f"       roofline: compute={r['compute_s']:.4f}s "
                      f"memory={r['memory_s']:.4f}s collective={r['collective_s']:.6f}s "
                      f"dominant={r['dominant']} "
                      f"useful={r['useful_ratio'] and round(r['useful_ratio'], 3)}", flush=True)
    except Exception as e:  # noqa: BLE001 - dry-run reports failures as data
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=8)
        if verbose:
            print(f"[FAIL] {arch} x {shape_name}: {rec['error']}", flush=True)
    return rec


def main(argv=None):
    """CLI driver: dry-run the selected (or all) arch x shape combinations
    and print/append the roofline records."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="choco", choices=["choco", "plain", "allreduce"])
    ap.add_argument("--compressor", default="top_k")
    ap.add_argument("--fraction", type=float, default=0.01)
    ap.add_argument("--qsgd-s", type=int, default=None)
    ap.add_argument("--json", default=None, help="append records to this JSON-lines file")
    ap.add_argument("--metrics-dir", default=None,
                    help="also emit compile/total timings as registry-"
                         "validated metric records (obs/schema.py) to "
                         "metrics.jsonl in this directory")
    ap.add_argument("--kv-layout", default="auto", choices=["auto", "head", "seq"])
    ap.add_argument("--state-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--topology", default="ring", choices=["ring", "torus"])
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (e.g. attn_impl=chunked)")
    args = ap.parse_args(argv)

    comp_kwargs = (("s", args.qsgd_s),) if args.qsgd_s else (("fraction", args.fraction),)

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            pass
        overrides[k] = v

    mlog = None
    if args.metrics_dir:
        from repro.obs.sinks import JsonlSink, MetricLog
        mlog = MetricLog([JsonlSink(os.path.join(args.metrics_dir,
                                                 "metrics.jsonl"))])
        mlog.header(tool="dryrun", jax_version=jax.__version__,
                    multi_pod=args.multi_pod, mode=args.mode,
                    compressor=args.compressor)

    records = []
    for i, (arch, shp) in enumerate(combos):
        rec = run_one(arch, shp, multi_pod=args.multi_pod, mode=args.mode,
                      compressor=args.compressor, comp_kwargs=comp_kwargs,
                      overrides=overrides or None, kv_layout=args.kv_layout,
                      state_dtype=args.state_dtype, topology=args.topology)
        records.append(rec)
        if args.json:
            with open(args.json, "a") as f:
                f.write(json.dumps(rec) + "\n")
        if mlog is not None and rec.get("status") == "ok":
            mlog.emit(i, {"dryrun/compile_s": float(rec["compile_s"]),
                          "dryrun/total_s": float(rec["total_s"])},
                      extra={"arch": arch, "shape": shp, "mesh": rec["mesh"]})
    if mlog is not None:
        mlog.close()

    n_fail = sum(r["status"] == "fail" for r in records)
    print(f"\n== {len(records)} combos: "
          f"{sum(r['status']=='ok' for r in records)} ok, "
          f"{sum(r['status']=='skip' for r in records)} skip, {n_fail} fail ==")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
