"""Production training launcher.

On a real TPU slice this runs under `python -m repro.launch.train` on every
host (jax.distributed initialises from the TPU environment); on CPU it
simulates the mesh with host devices for integration testing.

    python -m repro.launch.train --arch qwen3-1.7b --shape train_4k \
        --mode choco --compressor top_k --fraction 0.01 --steps 100

Checkpoints are sharded directories (manifest.json + per-host shard files;
see checkpoint/checkpointing.py).  ``--steps`` is the TOTAL step budget:
resuming a step-60 checkpoint with ``--steps 100`` trains 40 more steps and
the cosine schedule continues from step 60 (anchored by the manifest step),
it does not restart.  A checkpoint saved with a different ``n_nodes`` is
restored elastically (params tiled/averaged across the node dim, CHOCO
x_hat/s re-zeroed + consensus warmup — checkpoint/elastic.py):

    python -m repro.launch.train --arch qwen3-1.7b --smoke --steps 100 \
        --simulate-devices 8 --mesh 8x1 \
        --resume ckpts/step60 --checkpoint-dir ckpts --checkpoint-every 20
"""
import argparse
import os
import sys

# jax-free imports: safe before XLA_FLAGS is frozen by the first jax import
from repro.configs.base import (parse_delay_probs, parse_straggler_edges,
                                parse_topology)
from repro.launch.env import simulate_host_devices
from repro.obs.sinks import (DivergenceMonitor, JsonlSink, MetricLog,
                             StdoutSink)
from repro.obs.timers import StepTimer
from repro.obs.trace import ProfileSession

# mirrors core.topology._TOPOLOGIES; kept literal so arg validation never
# imports jax before XLA_FLAGS is set
TOPOLOGY_CHOICES = ("ring", "torus", "hypercube", "star", "chain",
                    "fully_connected", "directed_ring", "random_digraph")
# mirrors core.topology.DIRECTED_TOPOLOGIES (column-stochastic: push-sum only)
DIRECTED_CHOICES = ("directed_ring", "random_digraph")
PROCESS_CHOICES = ("none", "matching", "linkfail", "staleness")


def _stdout_line(record):
    """Stdout rendering of structured records: log lines verbatim, train
    metric records in the historical ``[train] step ...`` format, diag
    records as one compact line; header records are file-only."""
    kind = record.get("kind")
    if kind == "log":
        return record.get("msg", "")
    if kind != "metrics":
        return None
    if "train/loss" in record:
        tail = (f"{record['train/s_per_step']:.2f}s/step"
                if "train/s_per_step" in record
                else f"compile {record['train/compile_s']:.2f}s")
        return (f"[train] step {record['step']:5d} "
                f"loss {record['train/loss']:.4f} "
                f"lr {record['train/lr']:.4f} ({tail})")
    if "diag/consensus_dist" in record:
        parts = " ".join(f"{k.split('/', 1)[1]} {v:.3e}"
                         for k, v in sorted(record.items())
                         if k.startswith("diag/"))
        return f"[diag] step {record['step']} {parts}"
    return None


def main(argv=None):
    """CLI driver: validate args jax-free, then build the mesh/trainer and
    run the decentralized training loop."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--batch-per-node", type=int, default=None)
    ap.add_argument("--mode", default="choco",
                    choices=["choco", "plain", "allreduce", "pushsum"])
    ap.add_argument("--topology", default="ring",
                    help="gossip graph (one of "
                         f"{'/'.join(TOPOLOGY_CHOICES)}), or a "
                         "comma-separated sequence for time-varying mixing, "
                         "cycled across the --gossip-steps rounds of each "
                         "SGD step; directed graphs "
                         f"({'/'.join(DIRECTED_CHOICES)}) require "
                         "--mode pushsum")
    ap.add_argument("--topology-process", default="none",
                    choices=list(PROCESS_CHOICES),
                    help="stochastic topology process: 'matching' samples "
                         "one schedule round per gossip round (one permute "
                         "launch/step), 'linkfail' drops each edge i.i.d. "
                         "with --edge-drop-prob per round, 'staleness' runs "
                         "the bounded-staleness async engine (per-edge "
                         "delays up to --max-staleness rounds; nodes "
                         "proceed on the freshest copy they hold)")
    ap.add_argument("--edge-drop-prob", type=float, default=None,
                    help="Bernoulli link-failure probability in [0, 1) "
                         "(requires --topology-process linkfail)")
    ap.add_argument("--matching-sampler", default=None,
                    choices=["uniform", "weighted"],
                    help="round sampler for --topology-process matching "
                         "(default uniform)")
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="staleness bound tau >= 0 for --topology-process "
                         "staleness: per-edge payload delays are sampled "
                         "uniformly from {0..tau} (default 1; tau=0 is the "
                         "always-fresh replica engine)")
    ap.add_argument("--straggler-edges", default=None,
                    help="comma-separated slow links ('0-1,2-3') whose "
                         "delays come from --straggler-delay-probs instead "
                         "of the global distribution (requires "
                         "--topology-process staleness; edge ids are node "
                         "pairs in the gossip graph's edge support)")
    ap.add_argument("--straggler-delay-probs", default=None,
                    help="comma-separated P(d=0..tau) for the straggler "
                         "edges ('0.1,0.2,0.7'; needs --max-staleness + 1 "
                         "entries); default: point mass at tau — a "
                         "maximally slow link")
    ap.add_argument("--pipeline-gossip", action="store_true",
                    help="pipelined CHOCO engine (comm/pipelined.py): "
                         "compress the pre-gradient iterate and integrate "
                         "the received payload at the NEXT step's update so "
                         "the collective overlaps the backward pass (tau=1 "
                         "staleness gamma); requires --mode choco, a single "
                         "static --topology, and no --topology-process")
    ap.add_argument("--gossip-steps", type=int, default=1,
                    help="CHOCO gossip rounds per SGD step (k>1 trades wire "
                         "bytes for consensus; one pack amortizes the k "
                         "compressions)")
    ap.add_argument("--compressor", default="top_k")
    ap.add_argument("--fraction", type=float, default=0.01,
                    help="coordinate fraction for top_k/rand_k/block_top_k")
    ap.add_argument("--qsgd-s", type=int, default=None,
                    help="quantization levels (required with --compressor qsgd)")
    ap.add_argument("--state-dtype", default="float32")
    ap.add_argument("--gossip-engine", default="packed",
                    choices=["packed", "per-leaf"],
                    help="bucketed flat-buffer exchange (default) vs legacy "
                         "per-leaf compress+ppermute")
    ap.add_argument("--kernel-backend", default="auto",
                    choices=["auto", "pallas", "jnp"],
                    help="kernel backend for the gossip hot path "
                         "(kernels/dispatch.py): 'auto' probes the "
                         "toolchain and uses the fused Pallas kernels when "
                         "they run compiled (TPU), 'pallas'/'jnp' force; "
                         "pallas requires --mode choco with the packed "
                         "engine and no --topology-process")
    ap.add_argument("--exact-small-leaves", action="store_true",
                    help="route leaves <= 8192 elems to the uncompressed "
                         "exact bucket (norm scales, biases)")
    ap.add_argument("--optimizer", default="momentum")
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--heterogeneity", type=float, default=1.0)
    ap.add_argument("--data-skew-alpha", type=float, default=None,
                    help="Dirichlet(alpha) non-IID vocab shards "
                         "(data/partition.py): alpha -> inf is IID "
                         "('shuffled'), alpha -> 0 disjoint shards "
                         "('sorted'); overrides --heterogeneity")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--keep-checkpoints", type=int, default=None,
                    help="retain only the newest K checkpoint dirs under "
                         "--checkpoint-dir (GC runs after each successful "
                         "manifest write, never deletes the step just saved)")
    ap.add_argument("--resume", default=None,
                    help="sharded checkpoint dir (manifest.json) or a legacy "
                         "flat .npz; --steps stays the TOTAL budget")
    ap.add_argument("--elastic-warmup-rounds", type=int, default=None,
                    help="CHOCO-GOSSIP warmup rounds after an elastic "
                         "restore (default: derived from the new topology's "
                         "spectral gap)")
    ap.add_argument("--simulate-devices", type=int, default=0,
                    help=">0: simulate N host devices (CPU testing)")
    ap.add_argument("--mesh", default=None,
                    help="e.g. 4x2 => (data=4, model=2); default: production")
    ap.add_argument("--metrics-dir", default=None,
                    help="write a structured JSONL run log (metrics.jsonl: "
                         "header record + registry-validated metric records, "
                         "obs/schema.py) alongside the stdout lines")
    ap.add_argument("--diag-every", type=int, default=0,
                    help="run the jitted Lyapunov/consensus diagnostics "
                         "(obs/metrics.py) every k steps; 0 (default) "
                         "disables them — the fast-path train step is a "
                         "separate executable and stays byte-identical")
    ap.add_argument("--divergence-action", default=None,
                    choices=["warn", "abort"],
                    help="watch the diagnosed Lyapunov Xi_t: 'warn' logs "
                         "when it stops contracting, 'abort' exits nonzero "
                         "(overscaled --consensus-gamma detector); requires "
                         "--diag-every >= 1")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a TensorBoard-loadable jax.profiler trace "
                         "of steady-state steps into this directory (also "
                         "enables in-graph obs: phase scopes)")
    ap.add_argument("--profile-steps", type=int, default=None,
                    help="steps to trace under --profile-dir (default 3; "
                         "the compiling step 0 is always skipped)")
    args = ap.parse_args(argv)

    # fail fast on bad combinations, before any jax/device work
    topo_names = parse_topology(args.topology)
    bad = [t for t in topo_names if t not in TOPOLOGY_CHOICES]
    if bad or not topo_names:
        ap.error(f"--topology {args.topology!r}: unknown graph(s) {bad}; "
                 f"choose from {', '.join(TOPOLOGY_CHOICES)}")
    if args.gossip_steps < 1:
        ap.error("--gossip-steps must be >= 1")
    if len(topo_names) > 1 and args.gossip_steps % len(topo_names) != 0:
        ap.error(f"--topology {args.topology!r} is a {len(topo_names)}-graph "
                 f"time-varying sequence: --gossip-steps must be a multiple "
                 f"of {len(topo_names)} so every graph runs each SGD step "
                 f"(got {args.gossip_steps})")
    if args.compressor == "qsgd" and args.qsgd_s is None:
        ap.error("--compressor qsgd requires --qsgd-s (quantization levels); "
                 "it takes no --fraction")
    # directed topologies are column-stochastic: the symmetric choco/plain
    # engines would converge to a Perron-biased point, never the average
    directed = [t for t in topo_names if t in DIRECTED_CHOICES]
    if directed and args.mode != "pushsum":
        ap.error(f"--topology {args.topology!r} is directed "
                 f"(column-stochastic); --mode {args.mode} assumes a "
                 f"symmetric W. Directed graphs need the push-sum engine: "
                 f"--mode pushsum (de-biased x/w, comm/pushsum.py)")
    if args.mode == "pushsum":
        if len(topo_names) > 1:
            ap.error("--mode pushsum runs one directed schedule; "
                     f"time-varying sequences are unsupported "
                     f"(got --topology {args.topology!r})")
        if args.topology_process != "none":
            ap.error("--mode pushsum owns its directed schedule; combining "
                     "it with --topology-process is unsupported")
        if args.gossip_engine != "packed":
            ap.error("--mode pushsum is packed-only (the weight scalar "
                     "rides in-band with the bucket payloads); drop "
                     "--gossip-engine per-leaf")
    if args.topology_process != "none":
        if len(topo_names) > 1:
            ap.error(f"--topology-process {args.topology_process} is itself "
                     f"the per-step mixing distribution; a time-varying "
                     f"--topology sequence ({args.topology!r}) is ambiguous")
        if args.mode == "allreduce":
            ap.error("--topology-process has no effect under --mode "
                     "allreduce (no gossip graph); drop one of the two")
    if args.edge_drop_prob is not None:
        if args.topology_process != "linkfail":
            ap.error("--edge-drop-prob only applies to --topology-process "
                     "linkfail")
        if not 0.0 <= args.edge_drop_prob < 1.0:
            ap.error(f"--edge-drop-prob must be in [0, 1), got "
                     f"{args.edge_drop_prob} (p = 1 never mixes)")
    if args.matching_sampler is not None \
            and args.topology_process != "matching":
        ap.error("--matching-sampler only applies to --topology-process "
                 "matching")
    # bounded staleness reconstructs stale snapshots from rings of
    # compressed increments: only the compressed choco engine has that
    # increment stream (plain ships fresh iterates, allreduce/pushsum are
    # rejected for any process above)
    if args.topology_process == "staleness" and args.mode != "choco":
        ap.error(f"--topology-process staleness requires --mode choco "
                 f"(got --mode {args.mode}): the async engine ring-buffers "
                 f"compressed increments, which only the choco engine ships")
    if args.max_staleness is not None:
        if args.topology_process != "staleness":
            ap.error("--max-staleness only applies to --topology-process "
                     "staleness")
        if args.max_staleness < 0:
            ap.error(f"--max-staleness must be >= 0, got "
                     f"{args.max_staleness}")
    if args.straggler_delay_probs is not None and args.straggler_edges is None:
        ap.error("--straggler-delay-probs names the straggler links' delay "
                 "distribution; it requires --straggler-edges")
    if args.straggler_edges is not None:
        if args.topology_process != "staleness":
            ap.error("--straggler-edges models per-edge DELAYS; it requires "
                     "--topology-process staleness")
        try:
            parse_straggler_edges(args.straggler_edges)
        except ValueError as e:
            ap.error(f"--straggler-edges: {e}")
        if args.straggler_delay_probs is not None:
            try:
                probs = parse_delay_probs(args.straggler_delay_probs)
            except ValueError as e:
                ap.error(f"--straggler-delay-probs: {e}")
            tau = args.max_staleness if args.max_staleness is not None else 1
            if len(probs) != tau + 1:
                ap.error(f"--straggler-delay-probs needs max_staleness + 1 "
                         f"= {tau + 1} entries (P(d=0..{tau})), got "
                         f"{len(probs)}")
    if args.data_skew_alpha is not None and not args.data_skew_alpha > 0:
        ap.error(f"--data-skew-alpha must be > 0 (Dirichlet concentration), "
                 f"got {args.data_skew_alpha}")
    if args.pipeline_gossip:
        if args.mode != "choco":
            ap.error(f"--pipeline-gossip hides the COMPRESSED exchange "
                     f"behind the backward pass via the error-feedback "
                     f"carry; --mode {args.mode} has no (x_hat, s) state to "
                     f"double-buffer — it requires --mode choco")
        if args.topology_process != "none":
            ap.error(f"--pipeline-gossip is itself a deterministic delay-1 "
                     f"staleness process; stacking --topology-process "
                     f"{args.topology_process} on top compounds two delay "
                     f"models with no Theorem-2 gamma for the composite")
        if len(topo_names) > 1:
            ap.error(f"--pipeline-gossip needs one static schedule: a "
                     f"payload compressed under graph W_k but integrated a "
                     f"step later under W_k+1 breaks the recursion (got "
                     f"--topology {args.topology!r})")
    if args.kernel_backend == "pallas":
        # mirror kernels/dispatch.py's engine-eligibility rule pre-jax so a
        # bad launch dies in argparse, not after devices initialise
        if args.mode != "choco":
            ap.error(f"--kernel-backend pallas fuses the CHOCO "
                     f"quantize/error-feedback hot path; --mode {args.mode} "
                     f"never runs it — drop the flag or use --mode choco")
        if args.gossip_engine != "packed":
            ap.error("--kernel-backend pallas requires the packed engine "
                     "(the kernels run on bucket buffers); drop "
                     "--gossip-engine per-leaf")
        if args.topology_process != "none":
            ap.error(f"--kernel-backend pallas runs on the static choco "
                     f"engines only; --topology-process "
                     f"{args.topology_process} uses the replica/async "
                     f"engines, which stay jnp")
        # jax-free version gate (kernels/dispatch.py reads package metadata)
        from repro.kernels.dispatch import (MIN_JAX_FOR_PALLAS,
                                            jax_version_tuple)
        if jax_version_tuple() < MIN_JAX_FOR_PALLAS:
            ap.error(f"--kernel-backend pallas needs jax >= "
                     f"{'.'.join(map(str, MIN_JAX_FOR_PALLAS))} "
                     f"(found {'.'.join(map(str, jax_version_tuple()))}); "
                     f"use --kernel-backend auto or jnp")
    if args.keep_checkpoints is not None:
        if args.keep_checkpoints < 1:
            ap.error(f"--keep-checkpoints must be >= 1, got "
                     f"{args.keep_checkpoints}")
        if not args.checkpoint_dir:
            ap.error("--keep-checkpoints requires --checkpoint-dir")
    if args.diag_every < 0:
        ap.error(f"--diag-every must be >= 0 (0 disables diagnostics), got "
                 f"{args.diag_every}")
    if args.divergence_action is not None and args.diag_every == 0:
        ap.error("--divergence-action watches the Lyapunov diagnostics; it "
                 "requires --diag-every >= 1")
    if args.profile_steps is not None:
        if not args.profile_dir:
            ap.error("--profile-steps only applies with --profile-dir")
        if args.profile_steps < 1:
            ap.error(f"--profile-steps must be >= 1, got "
                     f"{args.profile_steps}")

    if args.simulate_devices:
        simulate_host_devices(args.simulate_devices)

    sinks = [StdoutSink(formatter=_stdout_line)]
    if args.metrics_dir:
        sinks.append(JsonlSink(os.path.join(args.metrics_dir,
                                            "metrics.jsonl")))
    mlog = MetricLog(sinks)

    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_config, ChocoConfig
    from repro.models import build_model
    from repro.models.transformer import count_params
    from repro.train.trainer import DecentralizedTrainer
    from repro.optim import make_optimizer, cosine_schedule
    from repro.data.synthetic import make_lm_batch_fn
    from repro.launch.mesh import make_production_mesh, make_mesh, gossip_axis_for
    from repro.checkpoint.checkpointing import restore_pytree
    from repro.checkpoint.manifest import (is_sharded_checkpoint,
                                           read_manifest)

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        mesh = make_mesh(dims, axes)
    else:
        mesh = make_production_mesh()
    gossip_axis = gossip_axis_for(mesh)
    n_nodes = mesh.shape[gossip_axis]

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    proc_info = ("" if args.topology_process == "none" else
                 f" process={args.topology_process}")
    proc_info += " pipelined" if args.pipeline_gossip else ""
    mlog.log(f"[train] arch={cfg.name} params={count_params(cfg)/1e6:.1f}M "
             f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
             f"nodes={n_nodes} mode={args.mode} topology={args.topology} "
             f"gossip_steps={args.gossip_steps}{proc_info}")

    if args.compressor == "qsgd":
        comp_kwargs = (("s", args.qsgd_s),)
    elif args.compressor in ("sign", "identity"):
        comp_kwargs = ()
    else:
        comp_kwargs = (("fraction", args.fraction),)
    trainer = DecentralizedTrainer(
        model=model,
        choco=ChocoConfig(compressor=args.compressor, comp_kwargs=comp_kwargs,
                          gossip_axis=gossip_axis, state_dtype=args.state_dtype,
                          topology=args.topology,
                          gossip_steps=args.gossip_steps,
                          packed_gossip=(args.gossip_engine == "packed"),
                          exact_small_leaves=args.exact_small_leaves,
                          topology_process=(None if args.topology_process == "none"
                                            else args.topology_process),
                          edge_drop_prob=(args.edge_drop_prob
                                          if args.edge_drop_prob is not None
                                          else 0.1),
                          matching_sampler=(args.matching_sampler or "uniform"),
                          max_staleness=(args.max_staleness
                                         if args.max_staleness is not None
                                         else 1),
                          pipeline_gossip=args.pipeline_gossip,
                          kernel_backend=args.kernel_backend,
                          data_skew_alpha=args.data_skew_alpha,
                          straggler_edges=args.straggler_edges,
                          straggler_delay_probs=args.straggler_delay_probs),
        mesh=mesh, n_nodes=n_nodes,
        optimizer=make_optimizer(args.optimizer),
        lr_fn=cosine_schedule(args.lr, warmup=min(100, args.steps // 10 + 1),
                              total=args.steps),
        mode=args.mode)

    def budget_check(resumed):
        if resumed >= args.steps:
            raise SystemExit(
                f"[train] --steps {args.steps} is the TOTAL step budget, but "
                f"{args.resume} is already at step {resumed}: nothing to do "
                f"(raise --steps; the LR schedule stays anchored at step 0 "
                f"over the full budget)")

    resumed = 0
    if args.resume:
        # a directory is always the sharded format: a torn save (no
        # manifest) surfaces as ManifestError, never as a bogus .npz lookup
        if os.path.isdir(args.resume) or is_sharded_checkpoint(args.resume):
            # budget check BEFORE restore/warmup — an exhausted resume must
            # not pay compilation + gossip rounds just to exit
            budget_check(read_manifest(args.resume).step)
            # restore directly under the trainer's shardings: no host-gather,
            # no throwaway init_state allocation
            state, man, warmup = trainer.restore_checkpoint(args.resume)
            resumed = man.step
            rounds = (args.elastic_warmup_rounds
                      if args.elastic_warmup_rounds is not None else warmup)
            if warmup and rounds:
                mlog.log(f"[train] elastic restore: checkpoint "
                         f"n_nodes={man.n_nodes} "
                         f"topology={man.fingerprint.get('topology')} -> "
                         f"n_nodes={n_nodes} topology={args.topology}; x_hat/s "
                         f"re-zeroed, consensus warmup {rounds} CHOCO-GOSSIP "
                         f"rounds (re-derived Theorem-2 "
                         f"gamma={trainer.gamma:.3e})")
                state = trainer.consensus_warmup(state, rounds)
        else:   # legacy flat npz
            state = jax.device_put(
                restore_pytree(args.resume, trainer.state_shape()),
                trainer.state_shardings())
            resumed = int(jax.device_get(state.step))
            budget_check(resumed)
        mlog.log(f"[train] resumed from {args.resume} at step {resumed}")
    else:
        state = trainer.init_state(jax.random.PRNGKey(0))

    seq = args.seq_len or min(cfg.n_layers * 64, 512)
    bpn = args.batch_per_node or 4
    next_batch = make_lm_batch_fn(cfg, seq, bpn, n_nodes, args.heterogeneity,
                                  skew_alpha=args.data_skew_alpha)
    batch0 = jax.tree.map(jnp.asarray, next_batch())
    state_shape = jax.eval_shape(lambda: state)
    # phase scopes change HLO op metadata, so they ride the profiler flag:
    # the default build keeps the compiled step byte-identical (the
    # telemetry_off invariant, benchmarks/bench_telemetry.py)
    step_fn = trainer.jitted_train_step(state_shape,
                                        jax.eval_shape(lambda: batch0),
                                        phase_scopes=bool(args.profile_dir))

    from repro.obs.metrics import bucket_telemetry
    buckets = bucket_telemetry(trainer)
    mlog.header(arch=cfg.name, mode=args.mode, topology=args.topology,
                fingerprint=trainer.fingerprint(),
                jax_version=jax.__version__,
                mesh=dict(zip(mesh.axis_names, mesh.devices.shape)),
                gamma=buckets["gamma"], buckets=buckets["buckets"],
                wire_bytes_round=buckets["wire_bytes_round"])
    diag_fn = (trainer.jitted_diagnostics(state_shape)
               if args.diag_every else None)
    monitor = (DivergenceMonitor() if args.divergence_action else None)
    prof = ProfileSession(args.profile_dir,
                          n_steps=(args.profile_steps or 3))

    timer = StepTimer()
    timer.start()
    remaining = args.steps - resumed       # --steps is the TOTAL budget
    try:
        for i in range(remaining):
            prof.maybe_start(i)
            state, mets = step_fn(state,
                                  jax.tree.map(jnp.asarray, next_batch()))
            if i == 0 or i % 10 == 0 or i == remaining - 1:
                # honest async-dispatch timing: block only on tap steps;
                # the first (compiling) step is reported once as
                # train/compile_s and never averaged into s/step
                metrics = {"train/loss": float(mets["loss"]),
                           "train/lr": float(mets["lr"]),
                           "train/grad_norm": float(mets["grad_norm"]),
                           "diag/node_loss_spread":
                               float(mets["node_loss_spread"])}
                blocker = lambda: jax.block_until_ready(state)
                if i == 0:
                    metrics["train/compile_s"] = timer.mark_compile(blocker)
                else:
                    sps = timer.tap(i, blocker)
                    if sps is not None:
                        metrics["train/s_per_step"] = sps
                extra = {k: float(v) for k, v in mets.items()
                         if k not in ("loss", "lr", "grad_norm",
                                      "node_loss_spread")}
                mlog.emit(int(state.step), metrics, extra=extra or None)
            if diag_fn is not None and (i + 1) % args.diag_every == 0:
                diag = {k: float(v) for k, v in diag_fn(state).items()}
                diag["diag/gamma"] = buckets["gamma"]
                diag["diag/wire_bytes_round"] = float(
                    buckets["wire_bytes_round"])
                diag["diag/data_skew_tv"] = float(next_batch.skew_tv)
                mlog.emit(int(state.step), diag)
                xi = diag.get("diag/lyapunov",
                              diag["diag/consensus_dist"])
                msg = monitor.update(int(state.step), xi) if monitor else None
                if msg is not None:
                    if args.divergence_action == "abort":
                        raise SystemExit(f"[train] {msg}")
                    mlog.log(f"[train] WARNING: {msg}")
            if (args.checkpoint_dir and args.checkpoint_every
                    and (i + 1) % args.checkpoint_every == 0):
                path = os.path.join(args.checkpoint_dir,
                                    f"step{int(state.step)}")
                trainer.save_checkpoint(path, state,
                                        metadata={"arch": cfg.name},
                                        keep_last=args.keep_checkpoints)
                mlog.log(f"[train] checkpointed {path}")
            if prof.active and i + 1 >= prof.stop_after:
                jax.block_until_ready(state)
            prof.maybe_stop(i)
    finally:
        prof.close()
        mlog.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
