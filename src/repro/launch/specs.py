"""ShapeDtypeStruct stand-ins for every model input x input-shape combination
(no device allocation — used by the multi-pod dry-run and the trainers).

Train batches carry a leading gossip-node dim; serve batches do not (CHOCO is
a training technique; serving is plain sharded inference).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, InputShape, INPUT_SHAPES


def sds(shape, dtype):
    """Shorthand ShapeDtypeStruct constructor."""
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: InputShape, n_nodes: int) -> Dict[str, Any]:
    """Train-batch ShapeDtypeStructs with the leading (n_nodes, ...) node
    dim, per model family (text / audio / vlm)."""
    assert shape.global_batch % n_nodes == 0, \
        f"global_batch {shape.global_batch} % nodes {n_nodes}"
    b = shape.global_batch // n_nodes
    S = shape.seq_len
    if cfg.family == "audio":
        fe = cfg.frontend
        return {
            "frame_embeds": sds((n_nodes, b, S, fe.embed_dim), cfg.dtype),
            "targets": sds((n_nodes, b, S), jnp.int32),
            "mask": sds((n_nodes, b, S), jnp.float32),
        }
    if cfg.family == "vlm":
        fe = cfg.frontend
        text = S - fe.n_tokens
        assert text > 0, f"seq {S} must exceed {fe.n_tokens} image tokens"
        return {
            "patch_embeds": sds((n_nodes, b, fe.n_tokens, fe.embed_dim), cfg.dtype),
            "tokens": sds((n_nodes, b, text), jnp.int32),
            "labels": sds((n_nodes, b, text), jnp.int32),
        }
    return {
        "tokens": sds((n_nodes, b, S), jnp.int32),
        "labels": sds((n_nodes, b, S), jnp.int32),
    }


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Serve-side prefill batch ShapeDtypeStructs (no node dim)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        fe = cfg.frontend
        return {"frame_embeds": sds((B, S, fe.embed_dim), cfg.dtype)}
    if cfg.family == "vlm":
        fe = cfg.frontend
        return {"patch_embeds": sds((B, fe.n_tokens, fe.embed_dim), cfg.dtype),
                "tokens": sds((B, S - fe.n_tokens), jnp.int32)}
    return {"tokens": sds((B, S), jnp.int32)}


def decode_specs(cfg: ModelConfig, shape: InputShape, model) -> Dict[str, Any]:
    """serve_step inputs: one new token + a full-length cache."""
    B, S = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(lambda: model.init_cache(B, S))
    return {
        "token": sds((B, 1), jnp.int32),
        "pos": sds((B,), jnp.int32),
        "caches": caches,
    }


# ---------------------------------------------------------------------------
# applicability matrix (skips are recorded, not silently dropped)
# ---------------------------------------------------------------------------

def applicability(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """None if the (arch, shape) pair runs; otherwise the skip reason."""
    if cfg.family == "audio" and shape.kind == "decode":
        return "encoder-only: no autoregressive decode step exists"
    if shape.name == "long_500k":
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid")
            or (cfg.sliding_window is not None and cfg.local_global_pattern > 0))
        if not sub_quadratic:
            return "pure full-attention arch: long_500k requires sub-quadratic attention"
    if cfg.family == "vlm" and shape.kind == "train" \
            and shape.seq_len <= cfg.frontend.n_tokens:
        return "sequence shorter than image-token budget"
    return None
