"""jax-free process-environment helpers (safe to import before XLA_FLAGS
is frozen by the first jax import)."""
from __future__ import annotations

import os

_DEVICE_COUNT_FLAG = "--xla_force_host_platform_device_count"


def simulate_host_devices(n: int) -> str:
    """Request ``n`` simulated host devices by APPENDING to XLA_FLAGS.

    Never clobbers pre-set flags (a user's --xla_dump_to etc. must survive
    --simulate-devices); any pre-existing device-count flag is replaced by
    ours, since XLA's last-wins duplicate handling is not a contract worth
    leaning on.  Must be called before jax is imported."""
    keep = [t for t in os.environ.get("XLA_FLAGS", "").split()
            if not t.startswith(_DEVICE_COUNT_FLAG + "=")]
    keep.append(f"{_DEVICE_COUNT_FLAG}={int(n)}")
    os.environ["XLA_FLAGS"] = " ".join(keep)
    return os.environ["XLA_FLAGS"]
