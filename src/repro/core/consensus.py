"""Blackbox averaging interface (paper Algorithm 4 / Assumption 3).

An averaging scheme is a map  h: (X, Y) -> (X', Y')  that
  (i)  preserves the average of X, and
  (ii) contracts the Lyapunov function
       Psi(X, Y) = ||X - Xbar||_F^2 + ||X - Y||_F^2  by (1 - p).

Exact gossip satisfies it with p = gamma * delta; CHOCO-Gossip with
p = delta^2 omega / 82 (Theorem 2).  Decentralized SGD with *any* such h
converges per Theorem 19 — this is the composition point of the framework:
plug a new averaging scheme here and the trainer/benchmarks pick it up.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .compression import Compressor, Identity
from .choco_gossip import _rowwise_compress, theorem2_stepsize, theorem2_rate


@dataclasses.dataclass(frozen=True)
class AveragingScheme:
    """h(X, Y, key) -> (X', Y') plus its contraction parameter p."""
    name: str
    h: Callable[[jax.Array, jax.Array, Optional[jax.Array]],
                Tuple[jax.Array, jax.Array]]
    p: float


def exact_averaging(W: jax.Array, delta: float, gamma: float = 1.0) -> AveragingScheme:
    """Uncompressed gossip baseline X <- X + gamma (W - I) X; contracts the
    consensus error at rate p = gamma * delta per round."""
    def h(X, Y, key=None):
        Xn = X + gamma * (W - jnp.eye(W.shape[0], dtype=W.dtype)) @ X
        return Xn, Xn
    return AveragingScheme("exact", h, p=gamma * delta)


def choco_averaging(W: jax.Array, delta: float, beta: float,
                    compressor: Compressor, d: int,
                    gamma: Optional[float] = None) -> AveragingScheme:
    """CHOCO-GOSSIP (Algorithm 1) as an AveragingScheme: compressed
    exchange with error feedback, gamma defaulting to the Theorem-2
    stepsize for the graph's (delta, beta) and the compressor's omega at
    dimension d; contracts at p = gamma delta omega / 2 (Theorem 2)."""
    omega = compressor.omega(d)
    if gamma is None:
        gamma = theorem2_stepsize(delta, beta, omega)

    def h(X, Y, key=None):
        # Y plays the role of Xhat
        q = _rowwise_compress(compressor, key, X - Y)
        Yn = Y + q
        Xn = X + gamma * (W - jnp.eye(W.shape[0], dtype=W.dtype)) @ Yn
        return Xn, Yn

    return AveragingScheme("choco", h, p=1.0 - theorem2_rate(delta, omega))


def stochastic_choco_averaging(process, compressor: Compressor, d: int,
                               gamma: Optional[float] = None) -> AveragingScheme:
    """Blackbox averaging over a stochastic topology process
    (comm/stochastic.py): h's auxiliary Y is the process's reference state —
    the (R, n, d) per-round reference stack for matchings, the (n, d) public
    copy for link failures — and each call consumes one sampled round.  The
    contraction parameter comes from Theorem 2 evaluated at the EXPECTED
    mixing matrix (Koloskova et al. 2020); ``key`` doubles as the sampling
    seed, so a keyed driver is deterministic and engine-reproducible.

    Directed push-sum deliberately has NO AveragingScheme: Algorithm 4's
    blackbox contract requires h to preserve the node AVERAGE of X, but the
    push-sum iterate only preserves the x-SUM while its ratio x/w converges
    — it composes with SGD through the dedicated trainer mode instead.
    """
    from repro.comm.stochastic import choco_process_round, ProcessGossipState
    delta, beta = process.expected_delta_beta()
    omega = compressor.omega(d)
    if gamma is None:
        gamma = theorem2_stepsize(delta, beta, omega)

    def h(X, Y, key=None):
        if key is None:
            key = jax.random.PRNGKey(0)
        # same split as run_choco_gossip_process: the exchange key seeds the
        # topology sample, a fold seeds the compressor's randomness
        ck = (jax.random.fold_in(key, 1) if compressor.stochastic else None)
        st = choco_process_round(ProcessGossipState(X, Y), process, gamma,
                                 compressor, key, comp_key=ck)
        return st.x, st.refs

    return AveragingScheme(f"stochastic-{process.kind}", h,
                           p=1.0 - theorem2_rate(delta, omega))
