"""Gossip graph topologies and mixing matrices W  (paper Def. 1, Table 1).

W must be symmetric, doubly stochastic, with spectral gap
delta = 1 - |lambda_2(W)| in (0, 1].  We build the paper's uniform-averaging
matrices (w_ij = 1/(deg+1) for regular graphs, Metropolis-Hastings otherwise)
and expose delta, rho = 1 - delta, beta = ||I - W||_2.

Directed graphs (Toghani & Uribe 2022; Assran et al. 2019) drop the symmetry
requirement: :class:`DirectedTopology` carries a *column*-stochastic A
(columns sum to 1, so 1^T A = 1^T and the node SUM is conserved — the
invariant push-sum de-biasing relies on).  Directed mixing cannot run through
the symmetric CHOCO engines; it needs the push-sum engine
(``comm/pushsum.py``), which ships the (x, w) weight pair and de-biases x/w.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    name: str
    W: np.ndarray                 # (n, n) mixing matrix
    neighbors: Tuple[Tuple[int, ...], ...]   # adjacency incl. self

    @property
    def n(self) -> int:
        return self.W.shape[0]

    @property
    def delta(self) -> float:
        """Spectral gap 1 - |lambda_2|."""
        eig = np.sort(np.abs(np.linalg.eigvalsh(self.W)))[::-1]
        return float(1.0 - (eig[1] if len(eig) > 1 else 0.0))

    @property
    def rho(self) -> float:
        return 1.0 - self.delta

    @property
    def beta(self) -> float:
        """||I - W||_2."""
        return float(np.linalg.norm(np.eye(self.n) - self.W, ord=2))

    def validate(self, atol=1e-10):
        W = self.W
        assert np.allclose(W, W.T, atol=atol), "W not symmetric"
        assert np.allclose(W.sum(0), 1.0, atol=atol), "W not doubly stochastic"
        assert np.all(W >= -atol), "W has negative entries"
        return self


def spectral_gap(W: np.ndarray) -> float:
    """delta = 1 - |lambda_2| for an arbitrary (possibly non-symmetric)
    stochastic matrix — the analysis knob for *expected* mixing matrices of
    stochastic topology processes (comm/stochastic.py) and for directed A."""
    eig = np.sort(np.abs(np.linalg.eigvals(np.asarray(W, np.float64))))[::-1]
    return float(1.0 - (eig[1] if len(eig) > 1 else 0.0))


def beta_norm(W: np.ndarray) -> float:
    """beta = ||I - W||_2 (paper Theorem 2's second spectral quantity)."""
    n = W.shape[0]
    return float(np.linalg.norm(np.eye(n) - np.asarray(W, np.float64), ord=2))


@dataclasses.dataclass(frozen=True)
class DirectedTopology:
    """Column-stochastic mixing over a directed graph.

    ``A[i, j]`` is the weight node j *pushes* to node i (j's column splits
    j's mass over its out-neighbours and itself), so columns sum to 1 and
    the total mass 1^T x is conserved — rows generally do NOT sum to 1,
    which is exactly why plain/CHOCO averaging diverges on these graphs and
    the push-sum (x, w) de-biasing is required."""
    name: str
    A: np.ndarray                              # (n, n) column-stochastic
    out_neighbors: Tuple[Tuple[int, ...], ...]  # per column, incl. self

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def directed(self) -> bool:
        return True

    @property
    def delta(self) -> float:
        """1 - |lambda_2(A)| — governs the push-sum consensus rate."""
        return spectral_gap(self.A)

    @property
    def beta(self) -> float:
        return beta_norm(self.A)

    def validate(self, atol=1e-10):
        A = self.A
        assert np.allclose(A.sum(0), 1.0, atol=atol), "A not column-stochastic"
        assert np.all(A >= -atol), "A has negative entries"
        assert np.all(np.diag(A) > atol), "push-sum needs self-loops (A_jj > 0)"
        return self


def directed_ring(n: int) -> DirectedTopology:
    """Directed cycle: every node pushes half its mass to its successor.
    A = (I + P) / 2 with P the cyclic shift — column- AND row-stochastic,
    but not symmetric, so it still requires push-sum."""
    if n == 1:
        return DirectedTopology("directed_ring", np.ones((1, 1)), ((0,),))
    A = 0.5 * np.eye(n)
    for j in range(n):
        A[(j + 1) % n, j] = 0.5
    nbrs = tuple((j, (j + 1) % n) for j in range(n))
    return DirectedTopology("directed_ring", A, nbrs).validate()


def random_digraph(n: int, extra_edge_prob: float = 0.3,
                   seed: int = 0) -> DirectedTopology:
    """Strongly-connected random digraph: the directed ring's j -> j+1 edges
    (guaranteeing strong connectivity) plus i.i.d. extra directed edges.
    Column j splits j's unit mass uniformly over {j} + out-neighbours —
    out-degrees differ, so A is column- but not row-stochastic."""
    if n == 1:
        return DirectedTopology("random_digraph", np.ones((1, 1)), ((0,),))
    rng = np.random.default_rng(seed)
    out = [{(j + 1) % n} for j in range(n)]
    for j in range(n):
        for i in range(n):
            if i != j and i != (j + 1) % n and rng.random() < extra_edge_prob:
                out[j].add(i)
    A = np.zeros((n, n))
    for j in range(n):
        share = 1.0 / (1 + len(out[j]))
        A[j, j] = share
        for i in out[j]:
            A[i, j] = share
    nbrs = tuple(tuple(sorted(out[j] | {j})) for j in range(n))
    return DirectedTopology("random_digraph", A, nbrs).validate()


def _from_adjacency(name: str, adj: np.ndarray) -> Topology:
    """Uniform / Metropolis-Hastings weights from a 0/1 adjacency (no self-loops)."""
    n = adj.shape[0]
    deg = adj.sum(1)
    W = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if i != j and adj[i, j]:
                W[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
    for i in range(n):
        W[i, i] = 1.0 - W[i].sum()
    nbrs = tuple(tuple(sorted(set(np.nonzero(adj[i])[0].tolist() + [i]))) for i in range(n))
    return Topology(name, W, nbrs).validate()


def ring(n: int) -> Topology:
    """Ring; uniform averaging 1/3 (self + 2 neighbours).  delta = O(1/n^2)."""
    adj = np.zeros((n, n), dtype=int)
    for i in range(n):
        adj[i, (i + 1) % n] = adj[i, (i - 1) % n] = 1
    if n == 1:
        return Topology("ring", np.ones((1, 1)), ((0,),))
    if n == 2:
        W = np.array([[0.5, 0.5], [0.5, 0.5]])
        return Topology("ring", W, ((0, 1), (0, 1))).validate()
    return _from_adjacency("ring", adj)


def torus2d(rows: int, cols: int) -> Topology:
    """2-d torus; uniform averaging 1/5.  delta = O(1/n)."""
    n = rows * cols
    adj = np.zeros((n, n), dtype=int)

    def nid(r, c):
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            i = nid(r, c)
            for (dr, dc) in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                adj[i, nid(r + dr, c + dc)] = 1
    np.fill_diagonal(adj, 0)
    return _from_adjacency("torus2d", adj)


def fully_connected(n: int) -> Topology:
    """Complete graph, W = (1/n) 11^T.  delta = 1."""
    W = np.full((n, n), 1.0 / n)
    nbrs = tuple(tuple(range(n)) for _ in range(n))
    return Topology("fully_connected", W, nbrs).validate()


def chain(n: int) -> Topology:
    """Path graph 0-1-...-(n-1): the worst-connected standard topology,
    delta = O(1/n^2) like the ring but without the wraparound edge."""
    adj = np.zeros((n, n), dtype=int)
    for i in range(n - 1):
        adj[i, i + 1] = adj[i + 1, i] = 1
    return _from_adjacency("chain", adj)


def star(n: int) -> Topology:
    """Hub-and-spoke graph: node 0 connects to all others — constant
    diameter but a congested hub; paper Table 1's high-degree contrast."""
    adj = np.zeros((n, n), dtype=int)
    adj[0, 1:] = adj[1:, 0] = 1
    return _from_adjacency("star", adj)


def hypercube(n: int) -> Topology:
    """m-dimensional hypercube on n = 2^m nodes: log-degree, log-diameter,
    delta = O(1/log n) — the well-connected end of the paper's spectrum."""
    m = int(np.log2(n))
    if 2 ** m != n:
        raise ValueError(f"hypercube topology needs n = 2^m nodes, got n={n}; "
                         f"use n={2 ** m} or n={2 ** (m + 1)}, or another "
                         f"topology")
    adj = np.zeros((n, n), dtype=int)
    for i in range(n):
        for b in range(m):
            adj[i, i ^ (1 << b)] = 1
    return _from_adjacency("hypercube", adj)


_TOPOLOGIES = {
    "ring": lambda n: ring(n),
    "torus": lambda n: torus2d(*_torus_factors(n)),
    "fully_connected": lambda n: fully_connected(n),
    "chain": lambda n: chain(n),
    "star": lambda n: star(n),
    "hypercube": lambda n: hypercube(n),
    "directed_ring": lambda n: directed_ring(n),
    "random_digraph": lambda n: random_digraph(n),
}

#: names whose make_topology result is a column-stochastic DirectedTopology —
#: these require the push-sum engine; the symmetric CHOCO/plain engines must
#: fail fast on them (launch/train.py, train/trainer.py)
DIRECTED_TOPOLOGIES = frozenset({"directed_ring", "random_digraph"})


def is_directed(name: str) -> bool:
    """True for column-stochastic (push-sum-only) topology names."""
    return name in DIRECTED_TOPOLOGIES


def _square_factors(n: int) -> Tuple[int, int]:
    r = int(np.sqrt(n))
    while n % r:
        r -= 1
    return r, n // r


def _torus_factors(n: int) -> Tuple[int, int]:
    """Most-square rows x cols factorization, refusing the degenerate 1 x n
    strip: a "torus" on prime n is a ring with doubled edges, whose spectral
    gap is the ring's O(1/n^2), not the advertised O(1/n) (Table 1) — the
    Theorem-2 stepsize computed from the claimed family would be silently
    wrong.  Fail fast instead."""
    rows, cols = _square_factors(n)
    if rows == 1 and n > 1:
        raise ValueError(
            f"torus topology needs a non-trivial rows x cols factorization, "
            f"but n={n} only factors as 1x{n} — a degenerate strip with "
            f"ring-grade spectral gap O(1/n^2), not the torus O(1/n). "
            f"Use a composite node count (e.g. n={n - 1} or n={n + 1}) or "
            f"topology='ring'.")
    return rows, cols


def make_topology(name: str, n: int) -> Topology:
    """Build a registered topology by name at n nodes (registry keys
    mirror launch.train.TOPOLOGY_CHOICES)."""
    if name not in _TOPOLOGIES:
        raise ValueError(f"unknown topology {name!r}; have {sorted(_TOPOLOGIES)}")
    return _TOPOLOGIES[name](n)
