"""CHOCO-Gossip (paper Algorithm 1 / matrix form of Appendix B).

State per node i: local x_i and the *public* copy x_hat_i (agreed upon by all
neighbours, because everyone integrates the same compressed messages).

Matrix form over X, Xhat in R^{n x d}  (rows = nodes):

    Q_t     = Q(X - Xhat)                 (row-wise compression)
    Xhat'   = Xhat + Q_t
    X'      = X + gamma * (W - I) @ Xhat'

Theorem 2: with gamma* = delta^2 omega / (16 d + d^2 + 4 b^2 + 2 d b^2 - 8 d w)
(d = delta, b = beta, w = omega) the Lyapunov error contracts by
(1 - delta^2 omega / 82) per round.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .compression import Compressor
from .topology import Topology


class GossipState(NamedTuple):
    x: jax.Array        # (n, d) local iterates
    x_hat: jax.Array    # (n, d) public copies


def theorem2_stepsize(delta: float, beta: float, omega: float) -> float:
    """Consensus stepsize gamma* of Theorem 2 (eq. 20)."""
    num = delta * delta * omega
    den = (16 * delta + delta ** 2 + 4 * beta ** 2
           + 2 * delta * beta ** 2 - 8 * delta * omega)
    return float(num / den)


@dataclasses.dataclass(frozen=True)
class GammaSpec:
    """Deferred Theorem-2 stepsize: (delta, beta) fixed by the mixing
    matrix, omega supplied later — per BUCKET by the packed engine.

    The consensus recursion is coordinate-wise given W, so each packed
    bucket is an independent CHOCO-Gossip instance whose contraction is
    governed by its OWN omega; a single global gamma derived from the worst
    bucket (``packing.bucket_omega_worst``) needlessly throttles every
    better-contracting bucket (an exact bucket with omega = 1 could mix an
    order of magnitude faster than a top-0.1% bucket allows).  The trainer
    passes a GammaSpec instead of a float and the engine evaluates
    ``value(omega_b)`` per bucket.

    ``omega_scale`` folds a process's effective-omega discount in (the
    pipelined engine's tau=1 staleness: scale = 1/2, matching
    ``StalenessProcess.effective_omega``); it multiplies every bucket's
    omega before the Theorem-2 formula.
    """
    delta: float
    beta: float
    omega_scale: float = 1.0

    def value(self, omega: float) -> float:
        """gamma* for one bucket's Assumption-1 omega."""
        return theorem2_stepsize(self.delta, self.beta,
                                 omega * self.omega_scale)


def theorem2_rate(delta: float, omega: float) -> float:
    """Per-round contraction factor  (1 - delta^2 omega / 82)."""
    return 1.0 - delta * delta * omega / 82.0


def init_state(x0: jax.Array) -> GossipState:
    """Algorithm-1 state at t=0: local iterates x0, public copies zero."""
    return GossipState(x=x0, x_hat=jnp.zeros_like(x0))


def _rowwise_compress(compressor: Compressor, key: Optional[jax.Array],
                      M: jax.Array) -> jax.Array:
    """Apply Q to each row of M (dense output)."""
    n = M.shape[0]
    if compressor.stochastic:
        keys = jax.random.split(key, n)
        return jax.vmap(compressor.apply)(keys, M)
    return jax.vmap(lambda r: compressor.apply(None, r))(M)


def choco_gossip_round(state: GossipState, W: jax.Array, gamma: float,
                       compressor: Compressor,
                       key: Optional[jax.Array] = None) -> GossipState:
    """One synchronous CHOCO-Gossip round (Algorithm 1, lines 2-7)."""
    q = _rowwise_compress(compressor, key, state.x - state.x_hat)
    x_hat = state.x_hat + q
    x = state.x + gamma * (W - jnp.eye(W.shape[0], dtype=W.dtype)) @ x_hat
    return GossipState(x=x, x_hat=x_hat)


@partial(jax.jit, static_argnames=("compressor", "steps"))
def run_choco_gossip(x0: jax.Array, W: jax.Array, gamma: float,
                     compressor: Compressor, steps: int,
                     key: Optional[jax.Array] = None):
    """Run `steps` rounds; returns (final_state, per-step consensus errors).

    error_t = (1/n) sum_i ||x_i^t - xbar||^2   (as plotted in Figs 2-3).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    xbar = jnp.mean(x0, axis=0, keepdims=True)

    def body(carry, k):
        state = carry
        new = choco_gossip_round(state, W, gamma, compressor, k)
        err = jnp.mean(jnp.sum((new.x - xbar) ** 2, axis=-1))
        return new, err

    keys = jax.random.split(key, steps)
    final, errs = jax.lax.scan(body, init_state(x0), keys)
    return final, errs


# ---------------------------------------------------------------------------
# Memory-efficient variant (paper Algorithm 5): each node stores only
# x_i, x_hat_i and s_i = sum_j w_ij x_hat_j.  Used to cross-check Algorithm 1
# and as the template for the distributed shard_map implementation.
# ---------------------------------------------------------------------------

class EfficientGossipState(NamedTuple):
    x: jax.Array        # (n, d)
    x_hat: jax.Array    # (n, d)   own public copy only
    s: jax.Array        # (n, d)   weighted neighbour aggregate


def init_efficient_state(x0: jax.Array) -> EfficientGossipState:
    """Algorithm-5 state at t=0: x0 plus zeroed x_hat and aggregate s."""
    return EfficientGossipState(x=x0, x_hat=jnp.zeros_like(x0),
                                s=jnp.zeros_like(x0))


def choco_gossip_round_efficient(state: EfficientGossipState, W: jax.Array,
                                 gamma: float, compressor: Compressor,
                                 key: Optional[jax.Array] = None
                                 ) -> EfficientGossipState:
    """Algorithm 5: q_i = Q(x_i - x_hat_i); x_hat_i += q_i;
    s_i += sum_j w_ij q_j;  x_i += gamma (s_i - x_hat_i).

    The (n,d) matrix `W @ q` stands in for the neighbour exchange — in the
    distributed runtime it becomes two `lax.ppermute`s of the payload.
    """
    q = _rowwise_compress(compressor, key, state.x - state.x_hat)
    x_hat = state.x_hat + q
    s = state.s + W @ q
    x = state.x + gamma * (s - x_hat)
    return EfficientGossipState(x=x, x_hat=x_hat, s=s)


@partial(jax.jit, static_argnames=("compressor", "steps"))
def run_choco_gossip_efficient(x0: jax.Array, W: jax.Array, gamma: float,
                               compressor: Compressor, steps: int,
                               key: Optional[jax.Array] = None):
    """Run ``steps`` rounds of memory-efficient CHOCO-GOSSIP (Algorithm 1
    with neighbour aggregates s_i instead of all x_hat_j), returning the
    final state and the per-round consensus-error trace."""
    if key is None:
        key = jax.random.PRNGKey(0)
    xbar = jnp.mean(x0, axis=0, keepdims=True)

    def body(state, k):
        new = choco_gossip_round_efficient(state, W, gamma, compressor, k)
        err = jnp.mean(jnp.sum((new.x - xbar) ** 2, axis=-1))
        return new, err

    keys = jax.random.split(key, steps)
    final, errs = jax.lax.scan(body, init_efficient_state(x0), keys)
    return final, errs


def auto_stepsize(topo: Topology, compressor: Compressor, d: int) -> float:
    """Theorem-2 stepsize from a topology + compressor (conservative)."""
    return theorem2_stepsize(topo.delta, topo.beta, compressor.omega(d))


# ---------------------------------------------------------------------------
# Bounded-staleness gossip — matrix simulator twin of comm/async_gossip.py.
# Each edge's update reads the endpoints' public copies as of d(t) steps ago
# (d <= tau, sampled per edge from the shared exchange key); since
# x_hat^(t-d) = x_hat^(t) - (last d compressed increments), a ring of the
# last tau global q's reconstructs every stale snapshot, and the per-node
# replicas of the distributed engine are just rows of the global state here.
# ---------------------------------------------------------------------------

class StaleGossipState(NamedTuple):
    x: jax.Array        # (n, d) local iterates
    x_hat: jax.Array    # (n, d) public copies (fresh)
    ring: jax.Array     # (tau, n, d): ring[j] = the global q of j steps ago


def init_stale_state(x0: jax.Array, max_staleness: int) -> StaleGossipState:
    """Zero-initialised bounded-staleness state with a depth-``max_staleness``
    increment ring."""
    return StaleGossipState(
        x=x0, x_hat=jnp.zeros_like(x0),
        ring=jnp.zeros((max_staleness,) + x0.shape, x0.dtype))


def choco_stale_round(state: StaleGossipState, process, gamma: float,
                      compressor: Compressor, key: jax.Array, t: int = 0,
                      comp_key: Optional[jax.Array] = None
                      ) -> StaleGossipState:
    """One bounded-staleness gossip round — the matrix twin of
    ``comm/async_gossip.py make_async_choco_fn`` (see its docstring for the
    replica/ring layout the distributed engine carries; the global view here
    needs none of it).  ``process`` is a
    :class:`~repro.comm.async_gossip.StalenessProcess`; ``key`` is the
    EXCHANGE key (pre-axis-fold), so engine parity requires driving both
    with the same key sequence and a deterministic compressor.

        q = Q(x - x_hat);  x_hat += q;  ring <- [q, ring[:-1]]
        d_e ~ delay_probs per edge (shared key);  per round r, dst i:
        x_i += gamma * v_r[i] * (x_hat^(t-d)[src_r(i)] - x_hat^(t-d)[i])

    with ``x_hat^(t-d) = x_hat - sum_{j<d} ring[j]``.
    """
    tau = int(state.ring.shape[0])
    q = _rowwise_compress(compressor, comp_key, state.x - state.x_hat)
    x_hat = state.x_hat + q
    ring = (jnp.concatenate([q[None], state.ring[:-1]], axis=0) if tau
            else state.ring)
    dvecs = process.round_delay_vecs(key, t)
    acc = jnp.zeros_like(state.x)
    for r, src in enumerate(process.round_src):
        src = jnp.asarray(src)
        v = jnp.asarray(process.round_recv[r], jnp.float32)[:, None]
        d = dvecs[r]
        diff = x_hat[src, :] - x_hat
        for j in range(tau):
            m = (d > j).astype(jnp.float32)[:, None]
            diff = diff - m * (ring[j][src, :] - ring[j])
        acc = acc + v * diff
    return StaleGossipState(x=state.x + gamma * acc, x_hat=x_hat, ring=ring)


def run_choco_stale_gossip(x0: jax.Array, process, gamma: float,
                           compressor: Compressor, steps: int,
                           key: Optional[jax.Array] = None):
    """Run `steps` bounded-staleness rounds, mirroring the trainer's seed
    plumbing (exchange key = fold_in(key, step)).  Returns
    (final StaleGossipState, per-step consensus errors)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    xbar = jnp.mean(x0, axis=0, keepdims=True)
    st = init_stale_state(x0, process.max_staleness)
    errs = []
    for step in range(steps):
        ek = jax.random.fold_in(key, step)
        ck = jax.random.fold_in(ek, 1) if compressor.stochastic else None
        st = choco_stale_round(st, process, gamma, compressor, ek,
                               t=0, comp_key=ck)
        errs.append(jnp.mean(jnp.sum((st.x - xbar) ** 2, axis=-1)))
    return st, jnp.stack(errs)


# ---------------------------------------------------------------------------
# Pipelined gossip — matrix simulator twin of comm/pipelined.py.  The
# pipelined engine compresses the PRE-update iterate and applies the
# received payload at the NEXT round's update, so the mixing term always
# reads the (s, x_hat) pair from one round ago.  That is exactly the
# bounded-staleness recursion with a deterministic delay of 1 on every edge
# (StalenessProcess(delay_probs=(0, 1))), but because the delay is uniform
# and every round ships, the depth-1 rings collapse into the carry itself:
# the stale pair IS the previous round's (s, x_hat), no replicas needed.
# ---------------------------------------------------------------------------


class PipelinedGossipState(NamedTuple):
    x: jax.Array        # (n, d) local iterates
    x_hat: jax.Array    # (n, d) public copies through round t-1
    s: jax.Array        # (n, d) W-weighted aggregate through round t-1


def init_pipelined_state(x0: jax.Array) -> PipelinedGossipState:
    """Pipelined-recursion state at t=0 (zero EF state, like Algorithm 5)."""
    return PipelinedGossipState(x=x0, x_hat=jnp.zeros_like(x0),
                                s=jnp.zeros_like(x0))


def choco_pipelined_round(state: PipelinedGossipState, W: jax.Array,
                          gamma: float, compressor: Compressor,
                          key: Optional[jax.Array] = None
                          ) -> PipelinedGossipState:
    """One pipelined CHOCO round — Algorithm 5 with the x-update reading the
    carry (the round-(t-1) pair) instead of this round's integration:

        q   = Q(x - x_hat)            compressed BEFORE the update
        x' = x + gamma (s - x_hat)    stale pair: payload of round t-1
        x_hat' = x_hat + q
        s'     = s + W q              this round's payload lands at t+1

    In the distributed engine the ``W @ q`` exchange has no consumer inside
    the current update, which is what lets XLA overlap the collective with
    the backward pass.  Per-step parity with the distributed engine is
    asserted in tests/test_pipelined.py; equality with the tau=1
    deterministic-delay stale simulator is a fast-tier test.
    """
    q = _rowwise_compress(compressor, key, state.x - state.x_hat)
    x = state.x + gamma * (state.s - state.x_hat)
    return PipelinedGossipState(x=x, x_hat=state.x_hat + q,
                                s=state.s + W @ q)


@partial(jax.jit, static_argnames=("compressor", "steps"))
def run_choco_pipelined_gossip(x0: jax.Array, W: jax.Array, gamma: float,
                               compressor: Compressor, steps: int,
                               key: Optional[jax.Array] = None):
    """Run `steps` pipelined rounds; returns (final state, per-step
    consensus errors), mirroring ``run_choco_gossip_efficient``."""
    if key is None:
        key = jax.random.PRNGKey(0)
    xbar = jnp.mean(x0, axis=0, keepdims=True)

    def body(state, k):
        new = choco_pipelined_round(state, W, gamma, compressor, k)
        err = jnp.mean(jnp.sum((new.x - xbar) ** 2, axis=-1))
        return new, err

    keys = jax.random.split(key, steps)
    final, errs = jax.lax.scan(body, init_pipelined_state(x0), keys)
    return final, errs


# ---------------------------------------------------------------------------
# Directed push-sum (column-stochastic A) — matrix simulator twin of
# comm/pushsum.py.  Neither x nor the weight w converges alone; the
# de-biased ratio z = x / w does, because 1^T A = 1^T conserves both sums.
# ---------------------------------------------------------------------------

class PushSumState(NamedTuple):
    x: jax.Array        # (n, d) biased iterates
    x_hat: jax.Array    # (n, d) public copies (compression error feedback)
    s: jax.Array        # (n, d) A-weighted aggregate of the q's
    w: jax.Array        # (n, 1) push-sum weights, init 1


def init_pushsum_state(x0: jax.Array) -> PushSumState:
    """Push-sum state at t=0: x0, zeroed EF state, unit weight column."""
    return PushSumState(x=x0, x_hat=jnp.zeros_like(x0),
                        s=jnp.zeros_like(x0),
                        w=jnp.ones((x0.shape[0], 1), x0.dtype))


def pushsum_gossip_round(state: PushSumState, A: jax.Array, gamma: float,
                         compressor: Compressor,
                         key: Optional[jax.Array] = None) -> PushSumState:
    """One compressed push-sum round:

        q = Q(x - x_hat);  x_hat += q;  s += A q;  x += gamma (s - x_hat)
        w += gamma (A w - w)                       (exact: scalars ship raw)

    With Q = identity this collapses to lazy push-sum
    x' = ((1-gamma) I + gamma A) x.  ``A @ q`` stands in for the directed
    partial-permutation rounds of comm/pushsum.py."""
    q = _rowwise_compress(compressor, key, state.x - state.x_hat)
    x_hat = state.x_hat + q
    s = state.s + A @ q
    x = state.x + gamma * (s - x_hat)
    w = state.w + gamma * (A @ state.w - state.w)
    return PushSumState(x=x, x_hat=x_hat, s=s, w=w)


def pushsum_debias(state: PushSumState) -> jax.Array:
    """z = x / w — the quantity that converges to the initial average."""
    return state.x / state.w


@partial(jax.jit, static_argnames=("compressor", "steps"))
def run_pushsum_gossip(x0: jax.Array, A: jax.Array, gamma: float,
                       compressor: Compressor, steps: int,
                       key: Optional[jax.Array] = None):
    """Run `steps` rounds; returns (final_state, per-step consensus errors
    of the DE-BIASED estimate x/w against the true initial average)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    xbar = jnp.mean(x0, axis=0, keepdims=True)

    def body(state, k):
        new = pushsum_gossip_round(state, A, gamma, compressor, k)
        err = jnp.mean(jnp.sum((pushsum_debias(new) - xbar) ** 2, axis=-1))
        return new, err

    keys = jax.random.split(key, steps)
    final, errs = jax.lax.scan(body, init_pushsum_state(x0), keys)
    return final, errs
