"""Compression operators Q: R^d -> R^d  (paper §3.3-§3.5, Assumption 1).

Every operator satisfies the paper's quality bound

    E_Q || Q(x) - x ||^2  <=  (1 - omega) ||x||^2         (7)

with a known compression factor ``omega in (0, 1]`` (omega = 1 means exact).

Two views of each operator are provided:

* ``apply(key, x) -> Q(x)``          -- dense output, used by the simulators.
* ``compress(key, x) -> payload``    -- the *wire format* actually transmitted
  (sparse values+indices, int8 codes + scale, ...).  ``decompress(payload)``
  reconstructs the dense Q(x).  The distributed runtime ppermutes payloads,
  so compiled HLO collective bytes reflect the true communication volume.

All operators are shape-polymorphic over flat vectors and are safe under
``jit``/``vmap`` (k is resolved statically from ``x.size``).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.tree_util import register_pytree_node_class


# ---------------------------------------------------------------------------
# Wire payloads
# ---------------------------------------------------------------------------

@register_pytree_node_class
@dataclasses.dataclass
class SparsePayload:
    """k values + k int32 indices of a d-dim vector."""
    values: jax.Array          # (k,)
    indices: jax.Array         # (k,) int32
    dim: int                   # static

    def tree_flatten(self):
        return (self.values, self.indices), (self.dim,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    def dense(self) -> jax.Array:
        return jnp.zeros((self.dim,), self.values.dtype).at[self.indices].set(self.values)

    def wire_bits(self) -> int:
        k = self.values.shape[-1]
        return int(k) * (self.values.dtype.itemsize * 8 + 32)


@register_pytree_node_class
@dataclasses.dataclass
class QuantPayload:
    """Per-coordinate integer codes + a single scale (qsgd wire format)."""
    codes: jax.Array           # (d,) small int
    scale: jax.Array           # () f32:  ||x|| / (s * tau)
    bits_per_coord: int        # static, for accounting

    def tree_flatten(self):
        return (self.codes, self.scale), (self.bits_per_coord,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    def dense(self) -> jax.Array:
        return self.codes.astype(jnp.float32) * self.scale

    def wire_bits(self) -> int:
        return int(self.codes.shape[-1]) * self.bits_per_coord + 32


@register_pytree_node_class
@dataclasses.dataclass
class DensePayload:
    x: jax.Array

    def tree_flatten(self):
        return (self.x,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    def dense(self) -> jax.Array:
        return self.x

    def wire_bits(self) -> int:
        return int(self.x.size) * self.x.dtype.itemsize * 8


@register_pytree_node_class
@dataclasses.dataclass
class PackedSparsePayload:
    """Blockwise top-k wire format for a flat (possibly packed) buffer:
    the k largest-magnitude coordinates of every `block`-wide row.

    Shipping (R, k) values + (R, k) int32 within-block indices keeps the
    payload shape static per bucket — the property the bucketed gossip
    engine (comm/packing.py) needs so ONE ppermute moves a whole bucket.
    """
    values: jax.Array          # (R, k)
    indices: jax.Array         # (R, k) int32, position within the block
    dim: int                   # static: flat length reconstructed by dense()
    block: int                 # static: row width, multiple of 128

    def tree_flatten(self):
        return (self.values, self.indices), (self.dim, self.block)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])

    def dense(self) -> jax.Array:
        R, _ = self.values.shape
        rows = jnp.zeros((R, self.block), self.values.dtype)
        rows = rows.at[jnp.arange(R)[:, None], self.indices].set(self.values)
        return rows.reshape(R * self.block)[: self.dim]

    def wire_bits(self) -> int:
        R, k = self.values.shape
        return int(R) * int(k) * (self.values.dtype.itemsize * 8 + 32)


@register_pytree_node_class
@dataclasses.dataclass
class PackedQuantPayload:
    """Per-coordinate integer codes + one scale for a packed bucket.

    Same wire format as QuantPayload, but covering a packed buffer whose
    leaf segments sit at block-aligned offsets: dense() must reproduce the
    FULL padded layout (`dim` = buffer length; padding quantizes to zero
    codes in place, it is never stripped — segment offsets would shift).
    `logical` (= sum of leaf sizes) is what wire accounting charges for:
    a production wire stream would run-length the interstitial zeros.
    """
    codes: jax.Array           # (dim,) small int, padded bucket layout
    scale: jax.Array           # () f32
    bits_per_coord: int        # static, for accounting
    dim: int                   # static: padded buffer length (= codes size)
    logical: int               # static: unpadded coordinate count

    def tree_flatten(self):
        return (self.codes, self.scale), (self.bits_per_coord, self.dim,
                                          self.logical)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1], aux[2])

    def dense(self) -> jax.Array:
        return self.codes[: self.dim].astype(jnp.float32) * self.scale

    def wire_bits(self) -> int:
        return int(self.logical) * self.bits_per_coord + 32


# ---------------------------------------------------------------------------
# Operators
# ---------------------------------------------------------------------------

class Compressor:
    """Base class.  Subclasses implement ``compress`` and ``omega``."""

    name: str = "base"
    #: True if E_Q Q(x) = x (needed by Q1/Q2/DCD/ECD baselines' theory)
    unbiased: bool = False
    #: True if the operator uses randomness (needs a key)
    stochastic: bool = True

    def compress(self, key: Optional[jax.Array], x: jax.Array):
        raise NotImplementedError

    def apply(self, key: Optional[jax.Array], x: jax.Array) -> jax.Array:
        return self.compress(key, x).dense()

    def __call__(self, key, x):
        return self.apply(key, x)

    def omega(self, d: int) -> float:
        raise NotImplementedError

    def wire_bits(self, d: int) -> int:
        """Bits on the wire for one d-dim vector (for benchmark accounting)."""
        raise NotImplementedError


class Identity(Compressor):
    """No-op compressor: Q(x) = x (omega = 1, exact gossip baseline)."""

    name = "identity"
    unbiased = True
    stochastic = False

    def compress(self, key, x):
        return DensePayload(x)

    def omega(self, d):
        return 1.0

    def wire_bits(self, d):
        return 32 * d


def _resolve_k(d: int, k: Optional[int], fraction: Optional[float]) -> int:
    if k is not None:
        return max(1, min(int(k), d))
    return max(1, min(d, int(math.ceil(fraction * d))))


class RandK(Compressor):
    """rand_k sparsification: keep k uniformly random coordinates.  omega = k/d."""
    name = "rand_k"
    unbiased = False  # (unbiased after d/k rescaling; raw form is biased)

    def __init__(self, k: Optional[int] = None, fraction: Optional[float] = None,
                 rescale: bool = False):
        assert (k is None) != (fraction is None)
        self.k, self.fraction, self.rescale = k, fraction, rescale
        self.unbiased = rescale

    def compress(self, key, x):
        d = x.size
        k = _resolve_k(d, self.k, self.fraction)
        idx = jax.random.permutation(key, d)[:k]
        vals = x[idx]
        if self.rescale:
            vals = vals * (d / k)
        return SparsePayload(vals, idx.astype(jnp.int32), d)

    def omega(self, d):
        k = _resolve_k(d, self.k, self.fraction)
        if self.rescale:           # rescaled-unbiased: tau = d/k  ->  omega = k/d
            return k / d
        return k / d

    def wire_bits(self, d):
        return _resolve_k(d, self.k, self.fraction) * 64


class TopK(Compressor):
    """top_k sparsification: keep the k largest-magnitude coords.  omega = k/d.
    Deterministic and *biased* — exactly the class CHOCO supports and
    Q1-G/Q2-G/DCD/ECD do not."""
    name = "top_k"
    unbiased = False
    stochastic = False

    def __init__(self, k: Optional[int] = None, fraction: Optional[float] = None):
        assert (k is None) != (fraction is None)
        self.k, self.fraction = k, fraction

    def compress(self, key, x):
        d = x.size
        k = _resolve_k(d, self.k, self.fraction)
        vals, idx = jax.lax.top_k(jnp.abs(x), k)
        return SparsePayload(x[idx], idx.astype(jnp.int32), d)

    def omega(self, d):
        return _resolve_k(d, self.k, self.fraction) / d

    def wire_bits(self, d):
        return _resolve_k(d, self.k, self.fraction) * 64


class BlockTopK(Compressor):
    """TPU-native blockwise top-k (the kernels/topk.py selection rule): keep
    the k_b largest-magnitude coordinates of every `block`-wide row.

    Assumption 1 holds per block with omega = k_b/block (Stich et al. 2018,
    Lemma A.1 applied blockwise), hence globally with the same omega.
    Blockwise selection *commutes with block-aligned concatenation*: the
    bucketed flat-buffer gossip engine (comm/packing.py) packs leaf segments
    at block boundaries, so compressing a packed bucket once is bit-for-bit
    identical to compressing every leaf separately — with a single top-k
    launch per bucket instead of one per leaf.
    """
    name = "block_top_k"
    unbiased = False
    stochastic = False

    def __init__(self, k_per_block: Optional[int] = None,
                 fraction: Optional[float] = None, block: int = 128):
        assert (k_per_block is None) != (fraction is None)
        assert block % 128 == 0, "block must be a multiple of the 128-lane unit"
        self.k_per_block, self.fraction, self.block = k_per_block, fraction, block

    def _kb(self) -> int:
        if self.k_per_block is not None:
            return max(1, min(int(self.k_per_block), self.block))
        return max(1, min(self.block, int(math.ceil(self.fraction * self.block))))

    def compress(self, key, x):
        from repro.kernels.ops import block_topk_select
        d = x.size
        vals, idx = block_topk_select(x.ravel(), self._kb(), block=self.block)
        return PackedSparsePayload(vals, idx, d, self.block)

    def omega(self, d):
        return min(1.0, self._kb() / self.block)

    def wire_bits(self, d):
        n_blocks = -(-d // self.block)
        return n_blocks * self._kb() * 64


class QSGD(Compressor):
    """qsgd_s random quantization (Alistarh et al. 2017), *rescaled by 1/tau*
    so that (7) holds with omega = 1/tau, tau = 1 + min(d/s^2, sqrt(d)/s).

        qsgd_s(x) = sign(x) * ||x|| / (s*tau) * floor(s |x| / ||x|| + xi)

    Wire format: int codes in [-s, s] + one f32 scale -> ceil(log2(2s+1))+1
    bits per coordinate.
    """
    name = "qsgd"
    unbiased = False   # rescaled version is biased (contraction), raw is unbiased

    def __init__(self, s: int, rescale: bool = True):
        self.s = int(s)
        self.rescale = rescale
        self.unbiased = not rescale

    def _tau(self, d):
        s = self.s
        return 1.0 + min(d / (s * s), math.sqrt(d) / s)

    def compress(self, key, x):
        d = x.size
        s = self.s
        norm = jnp.linalg.norm(x)
        xi = jax.random.uniform(key, x.shape)
        level = jnp.floor(s * jnp.abs(x) / jnp.where(norm == 0, 1.0, norm) + xi)
        codes = jnp.sign(x) * level                      # in [-s, s]
        tau = self._tau(d) if self.rescale else 1.0
        scale = norm / (s * tau)
        bits = int(math.ceil(math.log2(2 * s + 1))) + 1
        # wire format: int8 for s <= 127, int16 above — NOT int32 (an int32
        # code stream is *larger* than the raw bf16 vector; caught by the
        # compiled-HLO wire audit, EXPERIMENTS.md §Perf A)
        ctype = jnp.int8 if s <= 127 else jnp.int16
        return QuantPayload(codes.astype(ctype), scale.astype(jnp.float32), bits)

    def omega(self, d):
        return 1.0 / self._tau(d)

    def wire_bits(self, d):
        # must match the wire format compress() actually emits: integer codes
        # in [-s, s] need ceil(log2(2s+1)) magnitude bits + 1 sign bit per
        # coordinate, plus one f32 scale.  (The paper's §5.1 log2(s) figure
        # assumes an entropy-coded stream; we account for the raw codes.)
        return d * (int(math.ceil(math.log2(2 * self.s + 1))) + 1) + 32


class SignNorm(Compressor):
    """Scaled sign: Q(x) = ||x||_1 / d * sign(x).  Biased;
    ||Q(x)-x||^2 = ||x||^2 - ||x||_1^2/d  =>  omega >= 1/d (worst case),
    typically ~2/pi for Gaussian-like x."""
    name = "sign"
    unbiased = False
    stochastic = False

    def compress(self, key, x):
        d = x.size
        scale = jnp.sum(jnp.abs(x)) / d
        codes = jnp.sign(x)
        # int8 codes: 4x fewer ppermuted bytes than the old int32 stream
        return QuantPayload(codes.astype(jnp.int8), scale.astype(jnp.float32), 1)

    def omega(self, d):
        return 1.0 / d

    def wire_bits(self, d):
        return d + 32


class RandomizedGossip(Compressor):
    """Q(x) = x with prob p else 0.  omega = p  (paper §3.5)."""
    name = "randomized_gossip"
    unbiased = False

    def __init__(self, p: float):
        self.p = float(p)

    def compress(self, key, x):
        keep = jax.random.bernoulli(key, self.p)
        return DensePayload(jnp.where(keep, x, jnp.zeros_like(x)))

    def omega(self, d):
        return self.p

    def wire_bits(self, d):
        return int(32 * d * self.p)


_REGISTRY = {
    "identity": lambda **kw: Identity(),
    "rand_k": lambda **kw: RandK(**kw),
    "top_k": lambda **kw: TopK(**kw),
    "block_top_k": lambda **kw: BlockTopK(**kw),
    "qsgd": lambda **kw: QSGD(**kw),
    "sign": lambda **kw: SignNorm(),
    "randomized_gossip": lambda **kw: RandomizedGossip(**kw),
}


def make_compressor(name: str, **kwargs) -> Compressor:
    """Factory: make_compressor('top_k', fraction=0.01)."""
    if name not in _REGISTRY:
        raise ValueError(f"unknown compressor {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)


def compress_pytree(compressor: Compressor, key, tree):
    """Compress every leaf of a pytree (flattened per-leaf)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = (jax.random.split(key, len(leaves)) if compressor.stochastic
            else [None] * len(leaves))
    payloads = [compressor.compress(k, leaf.ravel()) for k, leaf in zip(keys, leaves)]
    return payloads, treedef


def decompress_pytree(payloads, treedef, shapes):
    """Inverse of compress_pytree: densify each wire payload and restore
    the original tree structure/leaf shapes."""
    leaves = [p.dense().reshape(s) for p, s in zip(payloads, shapes)]
    return jax.tree_util.tree_unflatten(treedef, leaves)
