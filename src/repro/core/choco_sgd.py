"""CHOCO-SGD (paper Algorithm 2, memory-efficient Algorithm 6).

Per node i and round t:
    g_i    = grad F_i(x_i, xi_i)                  (local stochastic gradient)
    x_i'   = x_i - eta_t g_i                      (SGD half-step)
    q_i    = Q(x_i' - x_hat_i)                    (compressed publication)
    x_hat_i += q_i ;  s_i += sum_j w_ij q_j       (neighbour exchange)
    x_i    = x_i' + gamma (s_i - x_hat_i)         (gossip mixing)

This module provides the (n, d) matrix simulator used by the paper-figure
benchmarks, plus the stepsize schedules of Theorem 4 and of the experiments
(§5.3: eta_t = m a / (t + b)).  The multi-device implementation lives in
``repro.train`` / ``repro.comm`` and follows the exact same update rules.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .compression import Compressor
from .choco_gossip import _rowwise_compress, theorem2_stepsize


GradFn = Callable[[jax.Array, jax.Array, jax.Array], jax.Array]


class ChocoSGDState(NamedTuple):
    x: jax.Array        # (n, d) local models
    x_hat: jax.Array    # (n, d) public copies
    s: jax.Array        # (n, d) weighted neighbour aggregate sum_j w_ij x_hat_j
    t: jax.Array        # scalar step


def init_state(x0: jax.Array) -> ChocoSGDState:
    """Algorithm-2 state at t=0: iterates x0, zero public copies x_hat
    (every neighbour's view starts empty) and zero aggregates s."""
    return ChocoSGDState(x=x0, x_hat=jnp.zeros_like(x0),
                         s=jnp.zeros_like(x0), t=jnp.zeros((), jnp.int32))


def choco_sgd_step(state: ChocoSGDState, W: jax.Array, grad_fn: GradFn,
                   compressor: Compressor, eta: jax.Array, gamma: float,
                   key: jax.Array) -> ChocoSGDState:
    """One CHOCO-SGD round (Algorithm 6, matrix form)."""
    n = state.x.shape[0]
    gkey, ckey = jax.random.split(key)
    gkeys = jax.random.split(gkey, n)
    G = jax.vmap(grad_fn)(state.x, jnp.arange(n), gkeys)
    x_half = state.x - eta * G
    q = _rowwise_compress(compressor, ckey, x_half - state.x_hat)
    x_hat = state.x_hat + q
    s = state.s + W @ q
    x = x_half + gamma * (s - x_hat)
    return ChocoSGDState(x=x, x_hat=x_hat, s=s, t=state.t + 1)


# --- stepsize schedules -----------------------------------------------------

def experiment_lr_schedule(m: int, a: float, b: float) -> Callable[[jax.Array], jax.Array]:
    """Paper §5.3: eta_t = m * a / (t + b)."""
    def eta(t):
        return m * a / (t.astype(jnp.float32) + b)
    return eta


def theorem4_lr_schedule(mu: float, a: float) -> Callable[[jax.Array], jax.Array]:
    """Theorem 4: eta_t = 4 / (mu (a + t)),  a >= max(410/(delta^2 omega), 16 kappa)."""
    def eta(t):
        return 4.0 / (mu * (a + t.astype(jnp.float32)))
    return eta


def theorem4_a(delta: float, omega: float, kappa: float) -> float:
    """Theorem 4's stepsize shift `a`: eta_t = 2 / (mu (a + t)) with
    a = max(410 / (delta^2 omega), 16 kappa) — large enough that the first
    steps do not outrun the consensus contraction."""
    return max(410.0 / (delta * delta * omega), 16.0 * kappa)


# --- driver -----------------------------------------------------------------

@partial(jax.jit, static_argnames=("grad_fn", "compressor", "steps", "lr_fn",
                                   "eval_fn", "eval_every"))
def run_choco_sgd(x0: jax.Array, W: jax.Array, grad_fn: GradFn,
                  compressor: Compressor, lr_fn, gamma: float, steps: int,
                  key: Optional[jax.Array] = None,
                  eval_fn=None, eval_every: int = 1):
    """Run CHOCO-SGD; returns (final state, metric trace).

    eval_fn(xbar) -> scalar (e.g. suboptimality f(xbar) - f*); evaluated on the
    node-average every `eval_every` steps (matching the paper's plots).
    """
    if key is None:
        key = jax.random.PRNGKey(0)

    def body(state, k):
        eta = lr_fn(state.t)
        new = choco_sgd_step(state, W, grad_fn, compressor, eta, gamma, k)
        xbar = jnp.mean(new.x, axis=0)
        metric = eval_fn(xbar) if eval_fn is not None else jnp.float32(0)
        return new, metric

    keys = jax.random.split(key, steps)
    return jax.lax.scan(body, init_state(x0), keys)


def auto_gamma(delta: float, beta: float, omega: float) -> float:
    """Theorem-2 consensus stepsize (used by Theorem 4)."""
    return theorem2_stepsize(delta, beta, omega)
