"""Baseline gossip and decentralized-SGD schemes the paper compares against.

Gossip (consensus) baselines, §3.2-3.3:
  * (E-G)   exact gossip,           Xiao & Boyd 2004
  * (Q1-G)  direct quantization,    Aysal et al. 2008   -- loses the average
  * (Q2-G)  difference quantization Carli et al. 2007   -- non-vanishing noise

Optimization baselines, §5.3:
  * plain decentralized SGD (Algorithm 3)
  * DCD-SGD, ECD-SGD (Tang et al. 2018a)
  * centralized mini-batch SGD (the star-topology reference)

All schemes are written in the (n, d) matrix form of Appendix B and are
scan/jit-compatible so the benchmark harness can run them end to end.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .compression import Compressor
from .choco_gossip import _rowwise_compress

# ---------------------------------------------------------------------------
# Consensus baselines
# ---------------------------------------------------------------------------


def exact_gossip_round(X: jax.Array, W: jax.Array, gamma: float = 1.0) -> jax.Array:
    """(E-G): X' = X + gamma (W - I) X."""
    return X + gamma * (W - jnp.eye(W.shape[0], dtype=W.dtype)) @ X


def q1_gossip_round(X: jax.Array, W: jax.Array, compressor: Compressor,
                    key: Optional[jax.Array] = None, gamma: float = 1.0) -> jax.Array:
    """(Q1-G): Delta_ij = Q(x_j) - x_i  =>  X' = X + gamma (W Q(X) - X).
    Does NOT preserve the average -> converges only to a neighbourhood."""
    QX = _rowwise_compress(compressor, key, X)
    return X + gamma * (W @ QX - X)


def q2_gossip_round(X: jax.Array, W: jax.Array, compressor: Compressor,
                    key: Optional[jax.Array] = None, gamma: float = 1.0) -> jax.Array:
    """(Q2-G): Delta_ij = Q(x_j) - Q(x_i)  =>  X' = X + gamma (W - I) Q(X).
    Preserves the average but the compression noise ||Q(x)|| does not vanish."""
    QX = _rowwise_compress(compressor, key, X)
    return X + gamma * (W - jnp.eye(W.shape[0], dtype=W.dtype)) @ QX


@partial(jax.jit, static_argnames=("scheme", "compressor", "steps"))
def run_gossip_baseline(scheme: str, x0: jax.Array, W: jax.Array,
                        compressor: Optional[Compressor], steps: int,
                        gamma: float = 1.0, key: Optional[jax.Array] = None):
    """Run a consensus baseline; returns (X_final, per-step errors)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    xbar = jnp.mean(x0, axis=0, keepdims=True)

    def body(X, k):
        if scheme == "exact":
            Xn = exact_gossip_round(X, W, gamma)
        elif scheme == "q1":
            Xn = q1_gossip_round(X, W, compressor, k, gamma)
        elif scheme == "q2":
            Xn = q2_gossip_round(X, W, compressor, k, gamma)
        else:
            raise ValueError(scheme)
        err = jnp.mean(jnp.sum((Xn - xbar) ** 2, axis=-1))
        return Xn, err

    keys = jax.random.split(key, steps)
    return jax.lax.scan(body, x0, keys)


# ---------------------------------------------------------------------------
# Decentralized SGD baselines
#
# grad_fn(params_row, node_id, key) -> stochastic gradient, vmapped over nodes.
# ---------------------------------------------------------------------------

GradFn = Callable[[jax.Array, jax.Array, jax.Array], jax.Array]


def _node_grads(grad_fn: GradFn, X: jax.Array, key: jax.Array) -> jax.Array:
    n = X.shape[0]
    keys = jax.random.split(key, n)
    return jax.vmap(grad_fn)(X, jnp.arange(n), keys)


def plain_dsgd_step(X: jax.Array, W: jax.Array, grad_fn: GradFn,
                    eta: jax.Array, key: jax.Array) -> jax.Array:
    """Algorithm 3: local SGD step then exact averaging with neighbours."""
    G = _node_grads(grad_fn, X, key)
    return W @ (X - eta * G)


class DCDState(NamedTuple):
    x: jax.Array      # (n, d) local models == public replicas (x == x_hat in DCD)


def dcd_sgd_step(state: DCDState, W: jax.Array, grad_fn: GradFn,
                 compressor: Compressor, eta: jax.Array, key: jax.Array) -> DCDState:
    """DCD-SGD (difference compression, Tang et al. 2018a, Alg. 1):

        x_i^{t+1/2} = sum_j w_ij x_j^t - eta g_i        (exact replicas)
        z_i         = x_i^{t+1/2} - x_i^t
        x_i^{t+1}   = x_i^t + Q(z_i)                     (everyone integrates Q(z))

    Requires high-precision Q; diverges for aggressive compression (paper Fig 5-6).
    """
    gkey, ckey = jax.random.split(key)
    G = _node_grads(grad_fn, state.x, gkey)
    x_half = W @ state.x - eta * G
    z = x_half - state.x
    qz = _rowwise_compress(compressor, ckey, z)
    return DCDState(x=state.x + qz)


class ECDState(NamedTuple):
    x: jax.Array       # (n, d) local models
    x_tilde: jax.Array  # (n, d) extrapolated public replicas
    t: jax.Array       # scalar step counter


def ecd_sgd_step(state: ECDState, W: jax.Array, grad_fn: GradFn,
                 compressor: Compressor, eta: jax.Array, key: jax.Array) -> ECDState:
    """ECD-SGD (extrapolation compression, Tang et al. 2018a, Alg. 2):

        x_i^{t+1/2} = sum_j w_ij xt_j^t - eta g_i
        y_i         = (1 - theta_t) xt_i^t + theta_t x_i^{t+1/2},  theta_t ~ O(t)
        xt_i^{t+1}  = Q(y_i) scaled back:  xt^{t+1} = (1-1/theta) xt + (1/theta) Q(...)

    We follow Tang et al.'s published recursion with theta_t = (t+2)/2:
        z_i^{t+1} = (1 - theta_t) x_tilde_i^t + theta_t * x_i^{t+1/2}
        x_tilde^{t+1} = (1 - 1/theta_t) x_tilde^t + (1/theta_t) Q(z)
    Known to be fragile for coarse compression (observed in the paper and here).
    """
    gkey, ckey = jax.random.split(key)
    G = _node_grads(grad_fn, state.x_tilde, gkey)
    x_half = W @ state.x_tilde - eta * G
    theta = (state.t.astype(x_half.dtype) + 2.0) / 2.0
    z = (1.0 - theta) * state.x_tilde + theta * x_half
    qz = _rowwise_compress(compressor, ckey, z)
    x_tilde = (1.0 - 1.0 / theta) * state.x_tilde + (1.0 / theta) * qz
    return ECDState(x=x_half, x_tilde=x_tilde, t=state.t + 1)


def centralized_sgd_step(x: jax.Array, grad_fn: GradFn, n: int,
                         eta: jax.Array, key: jax.Array) -> jax.Array:
    """Centralized mini-batch SGD: one model, average of n worker gradients."""
    X = jnp.broadcast_to(x, (n,) + x.shape)
    G = _node_grads(grad_fn, X, key)
    return x - eta * jnp.mean(G, axis=0)
