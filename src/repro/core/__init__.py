"""CHOCO core: compression operators, gossip topologies, CHOCO-Gossip /
CHOCO-SGD, and the baselines the paper compares against."""
from .compression import (Compressor, Identity, RandK, TopK, BlockTopK, QSGD,
                          SignNorm, RandomizedGossip, make_compressor,
                          SparsePayload, QuantPayload, DensePayload,
                          PackedSparsePayload, PackedQuantPayload)
from .topology import (Topology, DirectedTopology, ring, torus2d,
                       fully_connected, chain, star, hypercube,
                       directed_ring, random_digraph, make_topology,
                       is_directed, spectral_gap, beta_norm)
from .choco_gossip import (GossipState, EfficientGossipState, init_state,
                           choco_gossip_round, run_choco_gossip,
                           choco_gossip_round_efficient,
                           run_choco_gossip_efficient,
                           theorem2_stepsize, theorem2_rate, auto_stepsize,
                           PushSumState, init_pushsum_state,
                           pushsum_gossip_round, pushsum_debias,
                           run_pushsum_gossip)
from .choco_sgd import (ChocoSGDState, choco_sgd_step, run_choco_sgd,
                        experiment_lr_schedule, theorem4_lr_schedule,
                        theorem4_a, auto_gamma)
from .baselines import (exact_gossip_round, q1_gossip_round, q2_gossip_round,
                        run_gossip_baseline, plain_dsgd_step, DCDState,
                        dcd_sgd_step, ECDState, ecd_sgd_step,
                        centralized_sgd_step)
from .consensus import (AveragingScheme, exact_averaging, choco_averaging,
                        stochastic_choco_averaging)
