"""RWKV-6 "Finch" block (data-dependent decay linear attention) —
arXiv:2404.05892.  Attention-free: time-mix (WKV recurrence) + channel-mix.

Per head (k, v in R^{P}):   S_t in R^{P x P}
    out_t = r_t^T ( diag(u) k_t v_t^T + S_t )
    S_{t+1} = diag(w_t) S_t + k_t v_t^T          (w_t data-dependent, per channel)

Decode is O(1)-state; this is the showcase arch for long_500k.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import dense_init, rms_norm


LORA_R = 32     # low-rank size of the data-dependent mixes/decay


def init_rwkv_timemix(key, cfg: ModelConfig, dtype):
    """Init one RWKV-6 time-mix block (LoRA mixes, decay, bonus, out)."""
    D = cfg.d_model
    H = cfg.n_heads if cfg.n_heads > 0 else D // 64
    P = D // H
    ks = jax.random.split(key, 12)
    return {
        "mu_x": (jax.random.uniform(ks[0], (D,)) * 0.1).astype(dtype),
        "mu": (jax.random.uniform(ks[1], (5, D)) * 0.1).astype(dtype),   # r,k,v,g,w
        "lora_A": dense_init(ks[2], (D, 5 * LORA_R), dtype=dtype),
        "lora_B": (jax.random.normal(ks[3], (5, LORA_R, D)) * 0.01).astype(dtype),
        "w_r": dense_init(ks[4], (D, D), dtype=dtype),
        "w_k": dense_init(ks[5], (D, D), dtype=dtype),
        "w_v": dense_init(ks[6], (D, D), dtype=dtype),
        "w_g": dense_init(ks[7], (D, D), dtype=dtype),
        "w_o": dense_init(ks[8], (D, D), dtype=dtype),
        "decay_base": jnp.linspace(-6.0, -1.0, D).astype(jnp.float32),
        "decay_A": dense_init(ks[9], (D, LORA_R), dtype=dtype),
        "decay_B": (jax.random.normal(ks[10], (LORA_R, D)) * 0.01).astype(dtype),
        "bonus_u": (jax.random.normal(ks[11], (D,)) * 0.1).astype(jnp.float32),
        "ln_out": jnp.zeros((D,), dtype),
    }


def init_rwkv_channelmix(key, cfg: ModelConfig, dtype):
    """Init one RWKV-6 channel-mix block (token-shift mixes + MLP)."""
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "mu_k": (jax.random.uniform(ks[0], (D,)) * 0.1).astype(dtype),
        "mu_r": (jax.random.uniform(ks[1], (D,)) * 0.1).astype(dtype),
        "w_k": dense_init(ks[2], (D, F), dtype=dtype),
        "w_v": dense_init(ks[3], (F, D), dtype=dtype),
        "w_r": dense_init(jax.random.fold_in(ks[3], 1), (D, D), dtype=dtype),
    }


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift interpolation (RWKV6 'ddlerp') ->
    five mixed streams (r, k, v, g, w), each (B, S, D)."""
    xx = x_prev - x                                             # (B,S,D)
    base = x + xx * p["mu_x"][None, None, :]
    a = jax.nn.tanh(base @ p["lora_A"]).reshape(base.shape[0], base.shape[1], 5, LORA_R)
    dyn = jnp.einsum("bsir,ird->bsid", a, p["lora_B"])          # (B,S,5,D)
    mixes = p["mu"][None, None] + dyn                           # (B,S,5,D)
    return [x + xx * mixes[:, :, i] for i in range(5)]


def _wkv_scan(r, k, v, w, u, H: int, state=None):
    """WKV linear-attention recurrence.
    r,k,v: (B,S,H,P); w: (B,S,H,P) per-channel decay in (0,1); u: (H,P) bonus.
    Returns out (B,S,H,P), final state (B,H,P,P)."""
    B, S, Hn, P = r.shape
    if state is None:
        state = jnp.zeros((B, Hn, P, P), jnp.float32)

    def body(S_c, inp):
        r_t, k_t, v_t, w_t = inp                                # (B,H,P) each
        kv = jnp.einsum("bhp,bhq->bhpq", k_t, v_t)              # (B,H,P,P)
        out = jnp.einsum("bhp,bhpq->bhq", r_t, S_c + u[None, :, :, None] * kv)
        S_n = w_t[..., None] * S_c + kv
        return S_n, out

    xs = (jnp.swapaxes(r, 0, 1).astype(jnp.float32),
          jnp.swapaxes(k, 0, 1).astype(jnp.float32),
          jnp.swapaxes(v, 0, 1).astype(jnp.float32),
          jnp.swapaxes(w, 0, 1).astype(jnp.float32))
    final, outs = jax.lax.scan(body, state, xs)
    return jnp.swapaxes(outs, 0, 1), final


def timemix_forward(p, x, cfg: ModelConfig, x_prev_last=None, state=None):
    """x: (B,S,D).  x_prev_last: (B,D) carry-in shift state (decode chaining).
    Returns (out, (last_x, final_wkv_state))."""
    B, S, D = x.shape
    H = cfg.n_heads
    P = D // H
    x_prev = jnp.concatenate(
        [jnp.zeros((B, 1, D), x.dtype) if x_prev_last is None else x_prev_last[:, None],
         x[:, :-1]], axis=1)
    xr, xk, xv, xg, xw = _ddlerp(p, x, x_prev)
    r = (xr @ p["w_r"]).reshape(B, S, H, P)
    k = (xk @ p["w_k"]).reshape(B, S, H, P)
    v = (xv @ p["w_v"]).reshape(B, S, H, P)
    g = jax.nn.silu(xg @ p["w_g"])
    dec = p["decay_base"][None, None] + jax.nn.tanh(xw @ p["decay_A"]) @ p["decay_B"]
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32))).reshape(B, S, H, P)
    u = p["bonus_u"].reshape(H, P)
    out, final = _wkv_scan(r, k, v, w, u, H, state)
    out = out.reshape(B, S, D).astype(x.dtype)
    out = rms_norm(out, p["ln_out"], cfg.norm_eps) * g
    return out @ p["w_o"], (x[:, -1], final)


def channelmix_forward(p, x, x_prev_last=None):
    """RWKV-6 channel mix over a sequence; returns (out, last token)."""
    B, S, D = x.shape
    x_prev = jnp.concatenate(
        [jnp.zeros((B, 1, D), x.dtype) if x_prev_last is None else x_prev_last[:, None],
         x[:, :-1]], axis=1)
    xx = x_prev - x
    xk = x + xx * p["mu_k"][None, None]
    xr = x + xx * p["mu_r"][None, None]
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"]), x[:, -1]


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype):
    """Zeroed decode cache: wkv state + token-shift tails per block."""
    D = cfg.d_model
    H = cfg.n_heads
    P = D // H
    return {
        "wkv": jnp.zeros((batch, H, P, P), jnp.float32),
        "shift_tm": jnp.zeros((batch, D), dtype),
        "shift_cm": jnp.zeros((batch, D), dtype),
    }
