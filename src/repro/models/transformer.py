"""Generic composable model stack driven by ModelConfig.

Every architecture is expressed as a *block pattern* (tuple of block kinds)
repeated `repeat` times via lax.scan over stacked parameters, plus an optional
unstacked `tail` and an optional weight-shared block (zamba2).  One code path
serves all six families (dense / moe / ssm / hybrid / vlm / audio) and all
three execution modes (train loss, prefill, single-token decode).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import layers as L
from . import moe as MOE
from . import mamba2 as M2
from . import rwkv6 as R6


# ---------------------------------------------------------------------------
# block pattern
# ---------------------------------------------------------------------------

def block_pattern(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    """Returns (pattern, repeat, tail): `pattern` is scanned `repeat` times,
    then `tail` blocks are applied once each (handles non-divisible stacks)."""
    Ln = cfg.n_layers
    if cfg.family in ("dense", "vlm", "audio"):
        if cfg.local_global_pattern > 0:
            k = cfg.local_global_pattern
            unit = ("dense_local",) * k + ("dense_global",)
            rep, rem = divmod(Ln, len(unit))
            return unit, rep, unit[:rem]
        return ("dense_global",), Ln, ()
    if cfg.family == "moe":
        ev = cfg.moe.moe_every
        if ev == 1:
            return ("moe",), Ln, ()
        unit = ("dense_global",) * (ev - 1) + ("moe",)
        rep, rem = divmod(Ln, ev)
        return unit, rep, unit[:rem]
    if cfg.family == "ssm":
        kind = "rwkv" if cfg.ssm.kind == "rwkv6" else "mamba"
        return (kind,), Ln, ()
    if cfg.family == "hybrid":
        se = cfg.hybrid.shared_every
        unit = ("mamba",) * (se - 1) + ("shared",)
        rep, rem = divmod(Ln, se)
        return unit, rep, ("mamba",) * rem
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------

def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _cast(p, dt):
    """Cast floating-point params to the compute dtype (f32 master weights ->
    bf16 compute).  Leaves used in f32 paths re-upcast explicitly."""
    return jax.tree.map(
        lambda a: a.astype(dt) if jnp.issubdtype(a.dtype, jnp.floating) else a, p)


def init_block(key, kind: str, cfg: ModelConfig, dtype):
    """Init one block's params for its kind (dense / moe / mamba / rwkv)."""
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind in ("dense_global", "dense_local", "shared"):
        return {"ln1": jnp.zeros((D,), dtype), "attn": L.init_attention(ks[0], cfg, dtype),
                "ln2": jnp.zeros((D,), dtype), "mlp": L.init_mlp(ks[1], cfg, dtype)}
    if kind == "moe":
        return {"ln1": jnp.zeros((D,), dtype), "attn": L.init_attention(ks[0], cfg, dtype),
                "ln2": jnp.zeros((D,), dtype), "moe": MOE.init_moe(ks[1], cfg, dtype)}
    if kind == "mamba":
        return {"ln": jnp.zeros((D,), dtype), "mixer": M2.init_mamba(ks[0], cfg, dtype)}
    if kind == "rwkv":
        return {"ln1": jnp.zeros((D,), dtype), "tm": R6.init_rwkv_timemix(ks[0], cfg, dtype),
                "ln2": jnp.zeros((D,), dtype), "cm": R6.init_rwkv_channelmix(ks[1], cfg, dtype)}
    raise ValueError(kind)


def init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_seq: int, dtype):
    """Zeroed decode cache for one block of the given kind."""
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    if kind in ("dense_global", "moe", "shared"):
        C = max_seq
        return {"k": jnp.zeros((batch, C, KV, Dh), dtype),
                "v": jnp.zeros((batch, C, KV, Dh), dtype)}
    if kind == "dense_local":
        C = min(cfg.sliding_window or max_seq, max_seq)
        return {"k": jnp.zeros((batch, C, KV, Dh), dtype),
                "v": jnp.zeros((batch, C, KV, Dh), dtype)}
    if kind == "mamba":
        return M2.init_mamba_cache(cfg, batch, dtype)
    if kind == "rwkv":
        return R6.init_rwkv_cache(cfg, batch, dtype)
    raise ValueError(kind)


def apply_block_full(kind: str, p, x, cfg: ModelConfig, positions,
                     attn_mask=None, want_cache: bool = False):
    """Full-sequence pass (train / prefill).
    Returns (x, cache_or_None, aux_loss)."""
    aux = jnp.float32(0)
    if kind in ("dense_global", "dense_local", "moe", "shared"):
        h, (k, v) = L.attention(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
                                positions, local=(kind == "dense_local"),
                                attn_mask=attn_mask)
        x = x + h
        y = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            f, aux = MOE.moe_ffn(p["moe"], y, cfg)
        else:
            f = L.mlp(p["mlp"], y, cfg)
        x = x + f
        cache = None
        if want_cache:
            C = min(cfg.sliding_window, k.shape[1]) if kind == "dense_local" and cfg.sliding_window else k.shape[1]
            cache = {"k": k[:, -C:], "v": v[:, -C:]}
        return x, cache, aux
    if kind == "mamba":
        xin = L.rms_norm(x, p["ln"], cfg.norm_eps)
        if want_cache:
            h, cache = M2.mamba_forward(p["mixer"], xin, cfg, want_cache=True)
            return x + h, cache, aux
        return x + M2.mamba_forward(p["mixer"], xin, cfg), None, aux
    if kind == "rwkv":
        h, (last_tm, wkv) = R6.timemix_forward(p["tm"], L.rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
        x = x + h
        y, last_cm = R6.channelmix_forward(p["cm"], L.rms_norm(x, p["ln2"], cfg.norm_eps))
        x = x + y
        cache = {"wkv": wkv, "shift_tm": last_tm, "shift_cm": last_cm} if want_cache else None
        return x, cache, aux
    raise ValueError(kind)


def apply_block_decode(kind: str, p, x, cfg: ModelConfig, cache, pos):
    """Single-token decode.  x: (B,1,D); pos: (B,).
    Returns (x, new_cache, aux)."""
    aux = jnp.float32(0)
    if kind in ("dense_global", "dense_local", "moe", "shared"):
        h, ck, cv = L.decode_attention(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                                       cfg, cache["k"], cache["v"], pos,
                                       local=(kind == "dense_local"))
        x = x + h
        y = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            f, aux = MOE.moe_ffn(p["moe"], y, cfg)
        else:
            f = L.mlp(p["mlp"], y, cfg)
        return x + f, {"k": ck, "v": cv}, aux
    if kind == "mamba":
        h, new_cache = M2.mamba_decode_step(p["mixer"], L.rms_norm(x, p["ln"], cfg.norm_eps), cache, cfg)
        return x + h, new_cache, aux
    if kind == "rwkv":
        xin = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        h, (last_tm, wkv) = R6.timemix_forward(p["tm"], xin, cfg,
                                               x_prev_last=cache["shift_tm"],
                                               state=cache["wkv"])
        x = x + h
        yin = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        y, last_cm = R6.channelmix_forward(p["cm"], yin, x_prev_last=cache["shift_cm"])
        return x + y, {"wkv": wkv, "shift_tm": last_tm, "shift_cm": last_cm}, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# the Model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    def __post_init__(self):
        self.pattern, self.repeat, self.tail = block_pattern(self.cfg)
        self.has_shared = "shared" in self.pattern or "shared" in self.tail

    # -- parameters ---------------------------------------------------------

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        keys = jax.random.split(key, len(self.pattern) + len(self.tail) + 4)
        params: Dict[str, Any] = {}

        def stacked(k, kind):
            return jax.vmap(lambda kk: init_block(kk, kind, cfg, dt))(
                jax.random.split(k, self.repeat))

        params["stack"] = {f"p{i}": stacked(keys[i], kind)
                           for i, kind in enumerate(self.pattern) if kind != "shared"}
        params["tail"] = {f"t{i}": init_block(keys[len(self.pattern) + i], kind, cfg, dt)
                          for i, kind in enumerate(self.tail) if kind != "shared"}
        if self.has_shared:
            params["shared"] = init_block(keys[-4], "shared", cfg, dt)
        if cfg.family == "audio":
            fe = cfg.frontend
            params["in_proj"] = L.dense_init(keys[-3], (fe.embed_dim, cfg.d_model), dtype=dt)
            params["final_norm"] = jnp.zeros((cfg.d_model,), dt)
            params["head"] = L.dense_init(keys[-2], (cfg.d_model, cfg.vocab_size), dtype=dt)
        else:
            params["embed"] = L.init_embed(keys[-3], cfg, dt)
        if cfg.family == "vlm":
            fe = cfg.frontend
            k1, k2 = jax.random.split(keys[-1])
            params["projector"] = {
                "w1": L.dense_init(k1, (fe.embed_dim, cfg.d_model), dtype=dt),
                "w2": L.dense_init(k2, (cfg.d_model, cfg.d_model), dtype=dt)}
        return params

    # -- stack runner ---------------------------------------------------------

    def _run_stack(self, params, x, positions, *, mode: str, caches=None,
                   pos=None, attn_mask=None):
        """mode: 'train' | 'prefill' | 'decode'."""
        cfg = self.cfg
        shared_p = params.get("shared")
        want_cache = mode == "prefill"

        def apply_one(kind, p, x, cache):
            p = _cast(p, x.dtype)
            if mode == "decode":
                return apply_block_decode(kind, p, x, cfg, cache, pos)
            return apply_block_full(kind, p, x, cfg, positions,
                                    attn_mask=attn_mask, want_cache=want_cache)

        def body(carry, xs):
            x, aux = carry
            p_slices, cache_slices = xs
            new_caches = {}
            for i, kind in enumerate(self.pattern):
                p = shared_p if kind == "shared" else p_slices[f"p{i}"]
                c = None if cache_slices is None else cache_slices[f"c{i}"]
                x, cn, a = apply_one(kind, p, x, c)
                aux = aux + a
                if cn is not None:
                    new_caches[f"c{i}"] = cn
            return (x, aux), (new_caches if new_caches else None)

        if cfg.remat == "full":
            body = jax.checkpoint(body)
        elif cfg.remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

        stack_caches = None if caches is None else caches["stack"]
        (x, aux), new_stack_caches = jax.lax.scan(
            body, (x, jnp.float32(0)), (params["stack"], stack_caches),
            unroll=self.repeat if cfg.scan_unroll else 1)

        new_tail_caches = {}
        for i, kind in enumerate(self.tail):
            p = shared_p if kind == "shared" else params["tail"][f"t{i}"]
            c = None if caches is None else caches["tail"][f"t{i}"]
            x, cn, a = apply_one(kind, p, x, c)
            aux = aux + a
            if cn is not None:
                new_tail_caches[f"t{i}"] = cn

        new_caches = None
        if new_stack_caches is not None or new_tail_caches:
            new_caches = {"stack": new_stack_caches, "tail": new_tail_caches}
        return x, aux, new_caches

    # -- inputs ---------------------------------------------------------------

    def _embed_inputs(self, params, batch):
        """Returns (x, positions, labels, valid)."""
        cfg = self.cfg
        dt = _dtype(cfg)
        if cfg.family == "audio":
            x = batch["frame_embeds"].astype(dt) @ params["in_proj"].astype(dt)
            B, S, _ = x.shape
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            return x, positions, batch.get("targets"), batch.get("mask")
        if cfg.family == "vlm":
            pe = batch["patch_embeds"].astype(dt)
            proj = params["projector"]
            vis = jax.nn.gelu(pe @ proj["w1"].astype(dt)) @ proj["w2"].astype(dt)
            txt = L.embed_tokens(params["embed"], batch["tokens"], cfg).astype(dt)
            x = jnp.concatenate([vis, txt], axis=1)
            B, S, _ = x.shape
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            labels = batch.get("labels")
            valid = None
            if labels is not None:
                np_ = vis.shape[1]
                valid = jnp.concatenate(
                    [jnp.zeros((B, np_), jnp.float32), jnp.ones((B, labels.shape[1]), jnp.float32)],
                    axis=1)
                labels = jnp.concatenate(
                    [jnp.zeros((B, np_), labels.dtype), labels], axis=1)
            return x, positions, labels, valid
        tokens = batch["tokens"]
        x = L.embed_tokens(params["embed"], tokens, cfg).astype(dt)
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return x, positions, batch.get("labels"), batch.get("valid")

    def _final_logits(self, params, h):
        cfg = self.cfg
        if cfg.family == "audio":
            h = L.rms_norm(h, params["final_norm"].astype(h.dtype), cfg.norm_eps)
            return L.softcap(h @ params["head"].astype(h.dtype), cfg.final_logit_softcap)
        return L.logits_from_hidden(_cast(params["embed"], h.dtype), h, cfg)

    # -- public API -----------------------------------------------------------

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x, positions, labels, valid = self._embed_inputs(params, batch)
        h, aux, _ = self._run_stack(params, x, positions, mode="train")
        if cfg.family == "audio":
            logits = self._final_logits(params, h)
            ce = L.cross_entropy(logits, labels, valid)
        elif cfg.loss_chunk > 0 and cfg.family != "audio":
            emb = _cast(params["embed"], h.dtype)
            ce = L.chunked_lm_loss(emb, h, labels, cfg, valid)
        else:
            logits = self._final_logits(params, h)
            ce = L.cross_entropy(logits, labels, valid)
        aux_w = cfg.moe.router_aux_weight if cfg.moe else 0.0
        total = ce + aux_w * aux
        return total, {"ce": ce, "aux": aux}

    def prefill(self, params, batch):
        """Full-sequence pass producing last-token logits + KV/state caches."""
        x, positions, _, _ = self._embed_inputs(params, batch)
        h, _, caches = self._run_stack(params, x, positions, mode="prefill")
        logits = self._final_logits(params, h[:, -1:])
        return logits, caches

    def decode_step(self, params, token, caches, pos):
        """token: (B, 1) int; pos: (B,) absolute position; returns (logits, caches)."""
        cfg = self.cfg
        dt = _dtype(cfg)
        x = L.embed_tokens(params["embed"], token, cfg).astype(dt) \
            if cfg.family != "audio" else None
        h, _, new_caches = self._run_stack(params, x, None, mode="decode",
                                           caches=caches, pos=pos)
        logits = self._final_logits(params, h)
        return logits, new_caches

    # -- caches ---------------------------------------------------------------

    def init_cache(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        dt = _dtype(cfg)

        def stacked_cache(kind):
            one = init_block_cache(kind, cfg, batch_size, max_seq, dt)
            return jax.tree.map(lambda a: jnp.broadcast_to(a, (self.repeat,) + a.shape), one)

        stack = {f"c{i}": stacked_cache(kind) for i, kind in enumerate(self.pattern)}
        tail = {f"t{i}": init_block_cache(kind, cfg, batch_size, max_seq, dt)
                for i, kind in enumerate(self.tail)}
        return {"stack": stack, "tail": tail}

    def cache_specs(self, batch_size: int, max_seq: int):
        concrete = jax.eval_shape(lambda: self.init_cache(batch_size, max_seq))
        return concrete


def build_model(cfg: ModelConfig) -> Model:
    """Construct the family-dispatched Model for a config."""
    return Model(cfg)


def count_params(cfg: ModelConfig) -> int:
    """Exact parameter count via eval_shape (no allocation)."""
    m = Model(cfg)
    shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    return sum(int(l.size) for l in jax.tree.leaves(shapes))


def count_active_params(cfg: ModelConfig) -> int:
    """Exact count minus non-routed expert weights (MoE top-k activation)."""
    total = count_params(cfg)
    if cfg.family != "moe":
        return total
    m = cfg.moe
    expert = 3 * cfg.d_model * m.d_expert
    n_moe_layers = cfg.n_layers // m.moe_every
    return total - (m.n_experts - m.top_k) * expert * n_moe_layers
