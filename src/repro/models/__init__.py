"""Model zoo: family-dispatched builders behind one Model interface."""
from .transformer import Model, build_model, block_pattern
