from .transformer import Model, build_model, block_pattern
