"""Shared neural-net layers: norms, rotary embeddings, attention (GQA/MQA,
qk-norm, logit softcap, sliding window, full cache & ring-buffer cache
decode), and gated MLPs.  Pure functions over explicit param dicts."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# initialisers / norms
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    """Fan-in-scaled normal init (LeCun-style) for dense weights."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    return (jax.random.normal(key, shape) / math.sqrt(fan_in)).astype(dtype)


def rms_norm(x, weight, eps: float):
    """RMSNorm with (1 + weight) gain, computed in f32."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def softcap(x, cap: Optional[float]):
    """Gemma-style tanh soft-capping; identity when cap is None."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    """Inverse RoPE frequencies for a head dim under base theta."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: (..., S) absolute positions."""
    Dh = x.shape[-1]
    inv = rope_frequencies(Dh, theta)                       # (Dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, Dh/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]  # (.., S, 1, Dh/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype):
    """Init one attention block's params (GQA-aware, optional qk-norm)."""
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (D, H * Dh), dtype=dtype),
        "wk": dense_init(ks[1], (D, KV * Dh), dtype=dtype),
        "wv": dense_init(ks[2], (D, KV * Dh), dtype=dtype),
        "wo": dense_init(ks[3], (H * Dh, D), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((Dh,), dtype)
        p["k_norm"] = jnp.zeros((Dh,), dtype)
    return p


def _repeat_kv(k, n_rep: int):
    """(B, S, KV, Dh) -> (B, S, KV*n_rep, Dh)"""
    if n_rep == 1:
        return k
    b, s, kv, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, dh)).reshape(b, s, kv * n_rep, dh)


def _attn_weights(q, k, cfg: ModelConfig, mask):
    """q: (B,Sq,H,Dh) k: (B,Sk,H,Dh) -> (B,H,Sq,Sk) softmax weights."""
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    logits = softcap(logits.astype(jnp.float32), cfg.attn_logit_softcap)
    logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    return jax.nn.softmax(logits, axis=-1).astype(q.dtype)


def _chunked_attention(q, k, v, cfg: ModelConfig, positions, *, local: bool):
    """Flash-style attention: lax.scan over KV blocks with online softmax.
    Never materialises the (B, H, Sq, Sk) weight tensor — the pure-jnp
    analogue of kernels/flash_attention.py (which is the TPU target).
    q: (B,S,H,Dh); k,v: (B,S,H,Dh) (already GQA-repeated)."""
    B, S, H, Dh = q.shape
    blk = min(cfg.attn_chunk, S)
    assert S % blk == 0, (S, blk)
    nb = S // blk
    scale = 1.0 / math.sqrt(Dh)
    qf = q.astype(jnp.float32) * scale
    qpos = positions                                     # (B, S)

    kb = k.reshape(B, nb, blk, H, Dh)
    vb = v.reshape(B, nb, blk, H, Dh)
    pb = positions.reshape(B, nb, blk)

    def body(carry, inp):
        m, l, acc = carry                                # (B,H,S) (B,H,S) (B,H,S,Dh)
        k_t, v_t, p_t = inp                              # (B,blk,H,Dh) ..., (B,blk)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf, k_t.astype(jnp.float32))
        logits = softcap(logits, cfg.attn_logit_softcap)
        mask = jnp.ones((B, 1, S, blk), bool)
        if cfg.causal:
            mask = p_t[:, None, None, :] <= qpos[:, None, :, None]
        if local and cfg.sliding_window is not None:
            mask = jnp.logical_and(
                mask, p_t[:, None, None, :] > qpos[:, None, :, None] - cfg.sliding_window)
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        pexp = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(pexp, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", pexp, v_t.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    body = jax.checkpoint(body)
    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.swapaxes(kb, 0, 1), jnp.swapaxes(vb, 0, 1), jnp.swapaxes(pb, 0, 1)),
        unroll=nb if cfg.scan_unroll else 1)   # exact HLO flops in dry-run
    out = acc / jnp.maximum(l, 1e-30)[..., None]         # (B,H,S,Dh)
    return jnp.swapaxes(out, 1, 2).astype(q.dtype)       # (B,S,H,Dh)


NEG_INF = -1e30


def attention(p, x, cfg: ModelConfig, positions, *, local: bool = False,
              attn_mask: Optional[jax.Array] = None):
    """Full-sequence attention (train / prefill).

    positions: (B, S) absolute positions.  `local` selects the sliding-window
    mask (cfg.sliding_window).  Returns (out, (k, v)) so callers can build a
    KV cache during prefill.
    """
    B, S, D = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, Dh)
    k = (x @ p["wk"]).reshape(B, S, KV, Dh)
    v = (x @ p["wv"]).reshape(B, S, KV, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    kv_cache = (k, v)
    k, v = _repeat_kv(k, H // KV), _repeat_kv(v, H // KV)

    if cfg.attn_impl == "chunked" and attn_mask is None:
        out = _chunked_attention(q, k, v, cfg, positions, local=local)
        return out.reshape(B, S, H * Dh) @ p["wo"], kv_cache

    qpos, kpos = positions[:, None, :, None], positions[:, None, None, :]
    # mask (B, 1, Sq, Sk) -> broadcast over heads
    if cfg.causal:
        mask = kpos <= qpos
    else:
        mask = jnp.ones((B, 1, S, S), bool)
    if local and cfg.sliding_window is not None:
        mask = jnp.logical_and(mask, kpos > qpos - cfg.sliding_window)
    if attn_mask is not None:
        mask = jnp.logical_and(mask, attn_mask)

    w = _attn_weights(q, k, cfg, mask)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(B, S, H * Dh)
    return out @ p["wo"], kv_cache


def decode_attention(p, x, cfg: ModelConfig, cache_k, cache_v, pos, *,
                     local: bool = False):
    """Single-token decode.  x: (B, 1, D); cache_k/v: (B, C, KV, Dh) where
    C = max_seq (global) or sliding_window (local ring buffer); pos: (B,)
    current absolute position.  Returns (out, new_cache_k, new_cache_v)."""
    B, _, D = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    C = cache_k.shape[1]
    q = (x @ p["wq"]).reshape(B, 1, H, Dh)
    k = (x @ p["wk"]).reshape(B, 1, KV, Dh)
    v = (x @ p["wv"]).reshape(B, 1, KV, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)

    slot = (pos % C) if local else pos
    # Write the new k/v at `slot`.  Decode steps are batch-synchronous (all
    # requests share the position), so a single scalar-indexed
    # dynamic_update_slice is used: SPMD partitions it cleanly, whereas a
    # vmapped per-batch scatter forces GSPMD to all-gather the whole cache
    # (95 GB/step for gemma2 decode_32k — see EXPERIMENTS.md §Perf C2).
    z = jnp.zeros((), slot.dtype)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (z, slot[0], z, z))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (z, slot[0], z, z))

    # GQA-native grouped attention: contract the cache directly with the
    # grouped query tensor.  Broadcasting the cache to H heads (_repeat_kv)
    # makes GSPMD replicate the whole cache when KV < model-axis size
    # (95 GB/step all-gathers on gemma2 decode_32k — EXPERIMENTS.md §Perf C2).
    rep = H // KV
    qg = q.reshape(B, 1, KV, rep, Dh)
    scale = 1.0 / math.sqrt(Dh)
    logits = jnp.einsum("bqkrd,bckd->bkrqc", qg, cache_k) * scale
    logits = softcap(logits.astype(jnp.float32), cfg.attn_logit_softcap)
    idx = jnp.arange(C)[None, :]                      # (1, C) slot ids
    if local:
        filled = jnp.minimum(pos + 1, C)[:, None]
        mask = idx < filled                           # ring buffer: all filled slots valid
    else:
        mask = idx <= pos[:, None]
    mask = mask[:, None, None, None, :]               # (B,1,1,1,C)
    logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrqc,bckd->bqkrd", w, cache_v).reshape(B, 1, H * Dh)
    return out @ p["wo"], cache_k, cache_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None):
    """Init MLP params for the configured type (swiglu/geglu/gelu)."""
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], (D, F), dtype=dtype),
                "w_up": dense_init(ks[1], (D, F), dtype=dtype),
                "w_down": dense_init(ks[2], (F, D), dtype=dtype)}
    return {"w_up": dense_init(ks[0], (D, F), dtype=dtype),
            "w_down": dense_init(ks[1], (F, D), dtype=dtype)}


def mlp(p, x, cfg: ModelConfig):
    """Apply the configured MLP (swiglu / geglu / plain gelu)."""
    if cfg.mlp_type == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if cfg.mlp_type == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"], approximate=True) @ p["w_down"]


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig, dtype):
    """Init token embedding, final norm, and (untied) unembed params."""
    ks = jax.random.split(key, 2)
    p = {"tok": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
         "final_norm": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype=dtype)
    return p


def embed_tokens(p, tokens, cfg: ModelConfig):
    """Token lookup (gemma-style sqrt(D) scaling when embeddings tie)."""
    x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.tie_embeddings:              # gemma-style scaled embedding
        x = x * math.sqrt(cfg.d_model)
    return x


def logits_from_hidden(p, h, cfg: ModelConfig):
    """Final norm -> (tied or untied) unembed -> optional logit softcap."""
    h = rms_norm(h, p["final_norm"], cfg.norm_eps)
    w = p["tok"].T if cfg.tie_embeddings else p["unembed"]
    out = h @ w
    return softcap(out, cfg.final_logit_softcap)


def cross_entropy(logits, labels, valid=None):
    """Mean CE over valid positions.  logits (..., V), labels (...) int."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if valid is None:
        return jnp.mean(nll)
    valid = valid.astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def chunked_lm_loss(p, h, labels, cfg: ModelConfig, valid=None):
    """Cross-entropy over the vocab computed in sequence chunks so the full
    (B, S, V) logits tensor is never materialised (beyond-paper memory opt,
    enabled via cfg.loss_chunk)."""
    if cfg.loss_chunk <= 0 or h.shape[1] % cfg.loss_chunk != 0:
        return cross_entropy(logits_from_hidden(p, h, cfg), labels, valid)
    B, S, D = h.shape
    n = S // cfg.loss_chunk
    hc = h.reshape(B, n, cfg.loss_chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, cfg.loss_chunk).transpose(1, 0, 2)
    vc = (valid.reshape(B, n, cfg.loss_chunk).transpose(1, 0, 2)
          if valid is not None else jnp.ones_like(lc, jnp.float32))

    def chunk_loss(args):
        hh, ll, vv = args
        logits = logits_from_hidden(p, hh, cfg)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, ll[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * vv), jnp.sum(vv)

    sums, counts = jax.lax.map(chunk_loss, (hc, lc, vc))
    return jnp.sum(sums) / jnp.maximum(jnp.sum(counts), 1.0)
