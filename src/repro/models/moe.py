"""Mixture-of-Experts FFN with grouped einsum dispatch (expert-parallel
friendly: the expert dimension shards over the `model` mesh axis, XLA turns
the dispatch/combine einsums into all-to-alls under GSPMD).

Supports qwen3-moe (128e top-8) and llama4-maverick (128e top-1 + shared
expert, MoE interleaved every other layer).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import dense_init, init_mlp, mlp


def init_moe(key, cfg: ModelConfig, dtype):
    """Init router (f32) + stacked expert MLP params for one MoE block."""
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts, m.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (E, D, F), in_axis=-2, dtype=dtype),
        "w_up": dense_init(ks[2], (E, D, F), in_axis=-2, dtype=dtype),
        "w_down": dense_init(ks[3], (E, F, D), in_axis=-2, dtype=dtype),
    }
    if m.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, dtype, d_ff=m.n_shared_experts * cfg.d_ff)
    return p


def _capacity(group: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(group * top_k * factor / n_experts)
    return max(c, top_k)


def moe_ffn(p, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss).

    Grouped dispatch: tokens are chunked into groups of m.group_size; within a
    group each token picks top_k experts; per-expert capacity C bounds the
    dispatched tensor (E, G, C, D).  Overflow tokens are dropped (standard
    switch-style), recovered by the residual connection.
    """
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    g = min(m.group_size, B * S)
    T = B * S
    assert T % g == 0, f"tokens {T} not divisible by group {g}"
    G = T // g
    C = _capacity(g, K, E, m.capacity_factor)

    xt = x.reshape(G, g, D)
    scores = jax.nn.softmax((xt.astype(jnp.float32) @ p["router"]), axis=-1)  # (G,g,E)
    gate_vals, expert_idx = jax.lax.top_k(scores, K)                          # (G,g,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert's dispatch buffer
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)                   # (G,g,K,E)
    pos_in_expert = jnp.cumsum(onehot.reshape(G, g * K, E), axis=1).reshape(G, g, K, E) - 1
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)                            # (G,g,K)

    # dispatch/combine tensors (G, g, E, C); contraction over K stays fused so
    # the (G,g,K,E,C) outer product is never materialised.  one_hot(pos, C)
    # is all-zero for overflow tokens (pos >= C) -> switch-style dropping.
    oh_e = jax.nn.one_hot(expert_idx, E, dtype=x.dtype)                       # (G,g,K,E)
    oh_c = jax.nn.one_hot(pos, C, dtype=x.dtype)                              # (G,g,K,C)
    disp = jnp.einsum("gske,gskc->gsec", oh_e, oh_c)
    comb = jnp.einsum("gske,gskc->gsec", oh_e, oh_c * gate_vals[..., None].astype(x.dtype))

    xe = jnp.einsum("ygec,ygd->eycd", disp, xt)                               # (E,G,C,D)
    h = jax.nn.silu(jnp.einsum("eycd,edf->eycf", xe, p["w_gate"])) \
        * jnp.einsum("eycd,edf->eycf", xe, p["w_up"])
    ye = jnp.einsum("eycf,efd->eycd", h, p["w_down"])                         # (E,G,C,D)
    y = jnp.einsum("ygec,eycd->ygd", comb, ye)
    if m.combine_seq_shard:
        # beyond-paper: constrain the combine output to be group-sharded over
        # the model axis so the expert-contraction all-reduce becomes a
        # reduce-scatter (+ all-gather at the residual) — see EXPERIMENTS §Perf B
        from jax.sharding import PartitionSpec as _P
        y = jax.lax.with_sharding_constraint(y, _P("model", None, None))
    y = y.reshape(B, S, D)

    # switch-style load-balance aux loss: E * sum_e f_e * p_e
    density = jnp.mean(jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=2),
                       axis=(0, 1)) / K                                       # fraction per expert
    router_prob = jnp.mean(scores, axis=(0, 1))
    aux = E * jnp.sum(density * router_prob)

    if m.n_shared_experts:
        y = y + mlp(p["shared"], x, cfg)
    return y, aux
