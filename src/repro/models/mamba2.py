"""Mamba2 (SSD) block — chunked matmul formulation (Dao & Gu 2024), which is
the TPU-native layout: intra-chunk work is MXU matmuls, only the inter-chunk
recurrence is a short scan over S/chunk steps.

Per head h with state N and head dim P:
    h_t = a_t * h_{t-1} + b_t x_t^T        (h in R^{N x P},  a_t = exp(dt_t * A))
    y_t = c_t^T h_t  + D x_t

Projections are separate leaves (w_x / w_z / w_B / w_C / w_dt) so tensor
parallelism can shard the inner dim (heads) of w_x/w_z over the `model` mesh
axis while the small B/C/dt projections stay replicated.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import dense_init, rms_norm


def init_mamba(key, cfg: ModelConfig, dtype):
    """Init one Mamba-2 mixer's params (SSD heads, conv, gates)."""
    s = cfg.ssm
    D = cfg.d_model
    di = s.expand * D
    H = di // s.head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_x": dense_init(ks[0], (D, di), dtype=dtype),
        "w_z": dense_init(ks[1], (D, di), dtype=dtype),
        "w_B": dense_init(ks[2], (D, s.d_state), dtype=dtype),
        "w_C": dense_init(ks[3], (D, s.d_state), dtype=dtype),
        "w_dt": dense_init(ks[4], (D, H), dtype=dtype),
        "conv_x": (jax.random.normal(ks[5], (s.d_conv, di)) * 0.1).astype(dtype),
        "conv_B": (jax.random.normal(ks[6], (s.d_conv, s.d_state)) * 0.1).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], (s.d_conv, s.d_state)) * 0.1).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "w_out": dense_init(jax.random.fold_in(ks[0], 7), (di, D), dtype=dtype),
    }


def _segsum(a):
    """a: (..., Q) log-decays -> (..., Q, Q) lower-triangular cumulative sums
    L[i, j] = sum_{k=j+1..i} a_k  (i >= j), -inf above diagonal."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def _causal_conv(x, w, d_conv: int):
    """Depthwise causal conv.  x: (B, S, C), w: (d_conv, C)."""
    S = x.shape[1]
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    return sum(pad[:, i:i + S] * w[i][None, None, :] for i in range(d_conv))


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD.
    x:  (B, S, H, P)    dt: (B, S, H)    A: (H,) negative decay rates
    Bm: (B, S, N)       Cm: (B, S, N)    (B/C shared across heads, mamba2-style)
    returns y: (B, S, H, P), final_state: (B, H, N, P)
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} % chunk {Q} != 0"
    nc = S // Q

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    a = dtc * A[None, None, None, :]                 # (B,nc,Q,H) log-decay per step
    a_cum = jnp.cumsum(a, axis=2)                    # within-chunk cumulative

    # 1. intra-chunk (diagonal blocks):  y = (C B^T  *  decay  * causal) @ (dt x)
    L = jnp.exp(_segsum(jnp.swapaxes(a, 2, 3)))      # (B,nc,H,Q,Q)
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)       # (B,nc,Q,Q)
    xdt = xc * dtc[..., None]                        # (B,nc,Q,H,P)
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", CB, L, xdt)

    # 2. chunk summary states: state_c = sum_t decay_to_end * B_t (dt x)_t
    decay_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)             # (B,nc,Q,H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", Bc, decay_end, xdt)

    # 3. inter-chunk recurrence (scan over nc chunks)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])                    # (B,nc,H)

    def scan_body(h, inp):
        st, dec = inp                                # (B,H,N,P), (B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h                              # emit state *entering* the chunk

    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    final, h_in = jax.lax.scan(
        scan_body, h0,
        (jnp.swapaxes(states, 0, 1).astype(jnp.float32),
         jnp.swapaxes(chunk_decay, 0, 1).astype(jnp.float32)))
    h_in = jnp.swapaxes(h_in, 0, 1)                  # (B,nc,H,N,P)

    # 4. inter-chunk contribution: y += C_t decay_from_start h_in
    decay_start = jnp.exp(a_cum)                     # (B,nc,Q,H)
    y_off = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cc, decay_start, h_in)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, final


def mamba_forward(p, x, cfg: ModelConfig, want_cache: bool = False):
    """Full-sequence Mamba2 mixer.  x: (B, S, D) -> (B, S, D)
    (or (out, cache) when want_cache, for prefill)."""
    s = cfg.ssm
    B, S, D = x.shape
    di = s.expand * D
    H, P, N = di // s.head_dim, s.head_dim, s.d_state

    xz = x @ p["w_x"]                                              # (B,S,di)
    z = x @ p["w_z"]
    Bm = x @ p["w_B"]                                              # (B,S,N)
    Cm = x @ p["w_C"]
    dt = x @ p["w_dt"]                                             # (B,S,H)

    xz = jax.nn.silu(_causal_conv(xz, p["conv_x"], s.d_conv))
    Bm = jax.nn.silu(_causal_conv(Bm, p["conv_B"], s.d_conv))
    Cm = jax.nn.silu(_causal_conv(Cm, p["conv_C"], s.d_conv))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                   # (H,)
    xh = xz.reshape(B, S, H, P)
    y, final = _ssd_chunked(xh, dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32), s.chunk)
    y = y + xh * p["D"][None, None, :, None]
    y = (y.reshape(B, S, di) * jax.nn.silu(z)).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = (y @ p["w_out"]).astype(x.dtype)
    if want_cache:
        # store the raw (pre-activation) conv inputs for the last d_conv-1 steps
        cache = {
            "ssm": final.astype(x.dtype),
            "conv_x": (x @ p["w_x"])[:, -(s.d_conv - 1):],
            "conv_B": (x @ p["w_B"])[:, -(s.d_conv - 1):],
            "conv_C": (x @ p["w_C"])[:, -(s.d_conv - 1):],
        }
        return out, cache
    return out


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    """Zeroed decode cache: SSM state + conv tail for one mixer."""
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H, P, N = di // s.head_dim, s.head_dim, s.d_state
    return {
        "ssm": jnp.zeros((batch, H, N, P), dtype),
        "conv_x": jnp.zeros((batch, s.d_conv - 1, di), dtype),
        "conv_B": jnp.zeros((batch, s.d_conv - 1, N), dtype),
        "conv_C": jnp.zeros((batch, s.d_conv - 1, N), dtype),
    }


def mamba_decode_step(p, x, cache, cfg: ModelConfig):
    """One-token recurrent step.  x: (B, 1, D)."""
    s = cfg.ssm
    B, _, D = x.shape
    di = s.expand * D
    H, P, N = di // s.head_dim, s.head_dim, s.d_state

    x0 = x[:, 0]
    xz_new = x0 @ p["w_x"]                                         # (B,di)
    z = x0 @ p["w_z"]
    Bm_new = x0 @ p["w_B"]
    Cm_new = x0 @ p["w_C"]
    dt = x0 @ p["w_dt"]

    def conv_step(cache_w, new, w):
        window = jnp.concatenate([cache_w, new[:, None]], axis=1)  # (B,d_conv,C)
        out = jnp.einsum("btc,tc->bc", window, w)
        return jax.nn.silu(out), window[:, 1:]

    xz, cx = conv_step(cache["conv_x"], xz_new, p["conv_x"])
    Bm, cB = conv_step(cache["conv_B"], Bm_new, p["conv_B"])
    Cm, cC = conv_step(cache["conv_C"], Cm_new, p["conv_C"])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A[None, :])                                   # (B,H)
    xh = xz.reshape(B, H, P)
    h = cache["ssm"].astype(jnp.float32) * a[..., None, None] \
        + jnp.einsum("bn,bh,bhp->bhnp", Bm.astype(jnp.float32), dt,
                     xh.astype(jnp.float32))
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), h) \
        + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = (y.reshape(B, di) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = (y @ p["w_out"])[:, None].astype(x.dtype)
    new_cache = {"ssm": h.astype(cache["ssm"].dtype), "conv_x": cx,
                 "conv_B": cB, "conv_C": cC}
    return out, new_cache
