"""Decentralized trainer: CHOCO-SGD over a device mesh.

State layout: every decentralized leaf (params, x_hat, s, optimizer moments)
carries a leading node dim of size n_nodes, sharded over the gossip mesh axis.
One train step =
    per-node grad (vmap over the node dim -> zero cross-node collectives)
  -> local optimizer half-step
  -> CHOCO gossip exchange (shard_map + ppermute of compressed payloads).
"""
from __future__ import annotations

import contextlib
import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ChocoConfig, parse_topology
from repro.core.compression import make_compressor
from repro.core.choco_gossip import GammaSpec, theorem2_stepsize
from repro.core.topology import is_directed, make_topology, torus2d
from repro.comm.gossip import make_gossip_exchange
from repro.comm.schedule import compile_directed_schedule, compile_schedules
from repro.models.transformer import Model
from repro.optim.sgd import Optimizer, OptState
from repro.launch.sharding import param_pspecs, batch_pspecs

#: ChocoConfig fields deliberately OUTSIDE the checkpoint fingerprint, with
#: the reason each omission is safe.  The fingerprint-coverage lint
#: (analysis/fingerprint_lint.py) enforces that every field is either read
#: by fingerprint() or listed here — silently un-fingerprinted fields are a
#: restore-correctness hazard.
FINGERPRINT_EXEMPT = {
    "kernel_backend": "execution detail: flipping jnp<->pallas changes "
                      "neither the state layout nor the wire bytes, so "
                      "resumes must stay backend-portable",
    "gossip_axis": "covered structurally: fingerprint() records the mesh "
                   "axis sizes and the resolved gossip_axes tuple, which "
                   "subsumes the raw axis-name string",
    "consensus_gamma": "stepsize override, like lr: it scales the mixing "
                       "update but changes no state layout, bucket spec, "
                       "or wire format — resuming under a different gamma "
                       "is a hyperparameter change, not a shape change",
    "data_skew_alpha": "data-pipeline knob: the Dirichlet shard shapes "
                       "which samples each node draws but touches no "
                       "state layout, mixing matrix, gamma, or wire "
                       "format — resuming under a different skew is a "
                       "data change, like swapping the input stream",
}


class TrainState(NamedTuple):
    params: Any      # (n_nodes, ...) leaves — the x_i of Algorithm 2
    x_hat: Any       # public copies (list of per-round reference trees when
                     #   a matching topology process is active)
    s: Any           # weighted neighbour aggregates (list of per-round
                     #   source-replica trees under a topology process)
    opt: OptState    # per-node optimizer moments
    step: jax.Array
    key: jax.Array
    psw: Any = None  # push-sum (n, 1) weight column; None outside pushsum
                     #   mode (None leaves vanish from the pytree, so every
                     #   non-pushsum state keeps its pre-PR structure)


@dataclasses.dataclass
class DecentralizedTrainer:
    model: Model
    choco: ChocoConfig
    mesh: Any
    n_nodes: int
    optimizer: Optimizer
    lr_fn: Callable[[jax.Array], jax.Array]
    mode: str = "choco"          # choco | plain | allreduce

    def __post_init__(self):
        cfg = self.model.cfg
        self.compressor = (make_compressor(self.choco.compressor, **self.choco.comp_dict())
                           if self.mode in ("choco", "pushsum") else None)
        axes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        names = parse_topology(self.choco.topology)
        directed = [n for n in names if is_directed(n)]
        if directed and self.mode != "pushsum":
            raise ValueError(
                f"topology={self.choco.topology!r} is directed "
                f"(column-stochastic): the symmetric {self.mode!r} engine "
                f"would average with a non-row-stochastic matrix and "
                f"converge to a Perron-biased point, not the mean.  Directed "
                f"graphs require the push-sum engine: mode='pushsum' "
                f"(comm/pushsum.py, de-biased x/w).")
        if self.mode == "pushsum" and len(names) != 1:
            raise ValueError(
                f"push-sum runs one directed schedule; time-varying "
                f"sequences are not supported (got topology="
                f"{self.choco.topology!r})")
        if self.choco.topology_process is not None:
            if self.mode not in ("choco", "plain"):
                raise ValueError(
                    f"topology_process={self.choco.topology_process!r} runs "
                    f"on the choco/plain engines; mode={self.mode!r} (the "
                    f"push-sum engine owns its directed schedule, allreduce "
                    f"has no gossip graph)")
            if len(names) != 1:
                raise ValueError(
                    f"a topology process IS the per-step mixing "
                    f"distribution; combining it with the time-varying "
                    f"sequence {self.choco.topology!r} is ambiguous")
            if directed:
                raise ValueError(
                    f"topology processes sample symmetric mixing matrices; "
                    f"{self.choco.topology!r} is directed — use "
                    f"mode='pushsum' without a process")
        # torus on a multi-pod mesh maps onto the (pod, data) ICI grid —
        # paper Table 1 delta = O(1/n) instead of the ring's O(1/n^2); every
        # other topology (and single-pod torus) lives on one gossip axis
        # whose flat index carries the schedule's node ids.  A time-varying
        # sequence containing a torus lifts the WHOLE sequence onto the
        # (pod, data) pair (schedules address flat row-major ids, so any
        # graph runs on the axis tuple) — comma order never changes the
        # node set.
        self.torus = ("torus" in names and "pod" in self.mesh.axis_names)
        if self.torus:
            self.gossip_axis = ("pod", "data")
            n = axes["pod"] * axes["data"]
            self.fsdp_axis = None
            grid = (axes["pod"], axes["data"])
        else:
            self.gossip_axis = self.choco.gossip_axis
            n = axes[self.gossip_axis]
            self.fsdp_axis = "data" if self.gossip_axis == "pod" else None
            grid = None
        assert n == self.n_nodes, \
            f"gossip over {self.gossip_axis} = {n} nodes != n_nodes {self.n_nodes}"
        # compile the (possibly time-varying) topology sequence into static
        # permutation-round schedules — the engine replays them with one
        # lax.ppermute per round.  Directed topologies compile through the
        # bipartite-coloring compiler for the push-sum engine.
        self.topologies = tuple(
            torus2d(*grid) if (name == "torus" and grid is not None)
            else make_topology(name, n) for name in names)
        if directed:
            self.schedules = (compile_directed_schedule(self.topologies[0]),)
        else:
            self.schedules = compile_schedules(self.topologies, grid=grid)
        if (len(self.schedules) > 1
                and self.choco.gossip_steps % len(self.schedules) != 0):
            raise ValueError(
                f"topology={self.choco.topology!r} is a time-varying "
                f"sequence of {len(self.schedules)} graphs: gossip_steps "
                f"must be a multiple of the sequence length so every graph "
                f"runs each SGD step (got {self.choco.gossip_steps})")
        # stochastic topology process over the compiled schedule
        if self.choco.topology_process is not None:
            if (self.choco.topology_process == "staleness"
                    and self.mode != "choco"):
                raise ValueError(
                    f"topology_process='staleness' runs on the compressed "
                    f"choco engine only (mode={self.mode!r}): the stale "
                    f"snapshots are reconstructed from rings of compressed "
                    f"increments — the plain engine ships fresh iterates "
                    f"with no increment stream to ring-buffer")
            from repro.comm.stochastic import make_topology_process
            stragglers = sprobs = None
            if (self.choco.straggler_edges is not None
                    or self.choco.straggler_delay_probs is not None):
                if self.choco.topology_process != "staleness":
                    raise ValueError(
                        f"straggler edges model per-edge DELAYS — they "
                        f"require topology_process='staleness', got "
                        f"{self.choco.topology_process!r}")
                if self.choco.straggler_edges is None:
                    raise ValueError("straggler_delay_probs given without "
                                     "straggler_edges")
                from repro.configs.base import (parse_delay_probs,
                                                parse_straggler_edges)
                stragglers = parse_straggler_edges(
                    self.choco.straggler_edges)
                if self.choco.straggler_delay_probs is not None:
                    sprobs = parse_delay_probs(
                        self.choco.straggler_delay_probs)
            self.process = make_topology_process(
                self.choco.topology_process, self.schedules[0],
                matching_sampler=self.choco.matching_sampler,
                edge_drop_prob=self.choco.edge_drop_prob,
                max_staleness=self.choco.max_staleness,
                straggler_edges=stragglers,
                straggler_delay_probs=sprobs)
        else:
            if (self.choco.straggler_edges is not None
                    or self.choco.straggler_delay_probs is not None):
                raise ValueError(
                    "straggler edges model per-edge DELAYS — they require "
                    "topology_process='staleness', got no topology process")
            self.process = None
        # pipelined engine (comm/pipelined.py): the exchange is issued on
        # the PRE-gradient iterate and its payload lands in the NEXT step's
        # update — validity requires the compressed-increment recursion
        # (choco), a single static graph, and no stochastic process (the
        # tau=1 delay surrogate below IS this engine's process)
        if self.choco.pipeline_gossip:
            if self.mode != "choco":
                raise ValueError(
                    f"pipeline_gossip hides the COMPRESSED exchange behind "
                    f"the backward pass via the error-feedback recursion; "
                    f"mode={self.mode!r} has no (x_hat, s) carry to "
                    f"double-buffer — it requires mode='choco'")
            if self.process is not None:
                raise ValueError(
                    f"pipeline_gossip is itself a deterministic delay-1 "
                    f"staleness process; stacking topology_process="
                    f"{self.choco.topology_process!r} on top would compound "
                    f"two delay models with no Theorem-2 gamma for the "
                    f"composite — run one or the other")
            if len(self.schedules) > 1:
                raise ValueError(
                    f"pipeline_gossip needs one static schedule: a payload "
                    f"compressed under graph W_k but integrated a step "
                    f"later under W_{{k+1}} breaks the recursion (got "
                    f"time-varying topology={self.choco.topology!r})")
        # Theorem-2 consensus stepsize from the topology and compression;
        # a time-varying sequence takes the conservative worst case, a
        # stochastic process the EXPECTED mixing matrix's (delta, beta)
        # (Koloskova et al. 2020 analyze exactly that quantity)
        self.gamma_spec = None
        if self.choco.consensus_gamma is not None:
            self.gamma = self.choco.consensus_gamma
        elif self.mode in ("choco", "pushsum"):
            omega = self._worst_omega()
            omega_scale = 1.0
            if self.process is not None:
                delta, beta = self.process.expected_delta_beta()
                # staleness folds its bound into the compression quality
                # (omega / (1 + tau)); matching/linkfail leave omega as-is
                omega = self.process.effective_omega(omega)
            elif self.choco.pipeline_gossip:
                # tau=1 surrogate: every payload is exactly one round late,
                # so (delta, beta) come from E_eff = (W + I) / 2 and the
                # staleness bound folds omega -> omega / 2
                from repro.comm.pipelined import pipeline_delay_process
                surrogate = pipeline_delay_process(self.schedules[0])
                delta, beta = surrogate.expected_delta_beta()
                omega_scale = 0.5
                omega = surrogate.effective_omega(omega)
            else:
                delta = min(t.delta for t in self.topologies)
                beta = max(t.beta for t in self.topologies)
            self.gamma = theorem2_stepsize(delta, beta, omega)
            # per-bucket Theorem-2 gamma (packed engine): ship the (delta,
            # beta, omega_scale) recipe instead of the worst-case scalar so
            # each bucket contracts at ITS omega — exact buckets stop being
            # dragged to the top-k stepsize.  self.gamma stays the scalar
            # worst case for logging and the per-leaf/pushsum engines;
            # single-bucket specs resolve to exactly that scalar.
            if (self.mode == "choco" and self.process is None
                    and self.choco.packed_gossip):
                self.gamma_spec = GammaSpec(delta=delta, beta=beta,
                                            omega_scale=omega_scale)
        else:
            self.gamma = 1.0

    def _bucket_spec(self):
        """The packed engine's BucketSpec, derived exactly as the exchange
        derives it (local shard shapes under the param PartitionSpecs);
        None for the legacy per-leaf engine.  Shared by the omega/gamma
        derivation below and the telemetry run header
        (``obs/metrics.py::bucket_telemetry``)."""
        if not self.choco.packed_gossip or self.compressor is None:
            return None
        from repro.comm.gossip import _leaf_routes, _pack_align
        from repro.comm.packing import make_bucket_spec
        shape = self.state_shape()
        specs = param_pspecs(shape.params, self.model.cfg,
                             node_axis=self.gossip_axis,
                             fsdp_axis=self.fsdp_axis, model_size=0)
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        # under a matching process x_hat is a LIST of reference trees; the
        # engine compresses one tree's worth of deltas per round either way
        hat_shape = (shape.x_hat[0] if isinstance(shape.x_hat, (list, tuple))
                     else shape.x_hat)
        local = [jax.ShapeDtypeStruct(
                     _local_shape(l.shape, sp, dict(self.mesh.shape)), l.dtype)
                 for l, sp in zip(jax.tree.leaves(hat_shape), spec_leaves)]
        return make_bucket_spec(
            local, align=_pack_align(self.compressor, self.choco.pack_align),
            exact_small_leaves=self.choco.exact_small_leaves,
            small_leaf_threshold=self.choco.small_leaf_threshold,
            routes=_leaf_routes(specs, self.gossip_axis))

    def _worst_omega(self) -> float:
        """Assumption-1 omega for the stepsize: computed from the ACTUAL
        packed bucket sizes (the packed engine compresses per bucket, so the
        contraction is governed by the worst bucket), not a fixed
        representative dimension.  Legacy per-leaf engine keeps the old
        1M-coordinate representative value."""
        if not self.choco.packed_gossip:
            return self.compressor.omega(1 << 20)
        from repro.comm.packing import bucket_omega_worst
        return bucket_omega_worst(self._bucket_spec(), self.compressor)

    # -- state ----------------------------------------------------------------

    def _init_state_fn(self):
        model, n = self.model, self.n_nodes

        sdt = jnp.dtype(self.choco.state_dtype)
        # replica layout under a topology process (comm/gossip.py
        # make_process_choco_fn): matching keeps R per-round own references
        # in x_hat and R source replicas in s; linkfail keeps the single
        # public copy in x_hat and R replicas in s.  ONLY the compressed
        # engine needs replicas — the plain engine ships the fresh iterate,
        # so its x_hat/s stay the (unused) single trees.  Push-sum adds the
        # (n, 1) weight column, init 1.
        replicas = self.process is not None and self.mode == "choco"
        n_rounds = len(self.process.schedule.rounds) if replicas else 0
        matching = replicas and self.process.kind == "matching"
        # bounded staleness (comm/async_gossip.py): x_hat is the
        # [public copy + depth-tau own ring] list, s the [R replicas +
        # R*tau receive rings] list
        stale = replicas and self.process.kind == "staleness"
        tau = self.process.max_staleness if stale else 0
        pushsum = self.mode == "pushsum"

        def init(key):
            pkeys = jax.random.split(key, n)
            params = jax.vmap(model.init)(pkeys)
            ef_zeros = lambda: jax.tree.map(
                lambda p: jnp.zeros(p.shape, sdt if jnp.issubdtype(p.dtype, jnp.floating)
                                    else p.dtype), params)
            opt = self.optimizer.init(params)
            x_hat = ([ef_zeros() for _ in range(n_rounds)] if matching
                     else [ef_zeros() for _ in range(1 + tau)] if stale
                     else ef_zeros())
            s = ([ef_zeros() for _ in range(n_rounds * (1 + tau))] if stale
                 else [ef_zeros() for _ in range(n_rounds)] if n_rounds
                 else ef_zeros())
            psw = jnp.ones((n, 1), jnp.float32) if pushsum else None
            return TrainState(params=params, x_hat=x_hat, s=s,
                              opt=opt, step=jnp.zeros((), jnp.int32),
                              key=key, psw=psw)
        return init

    def state_shape(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self._init_state_fn(), key)

    def state_pspecs(self, state_shape) -> TrainState:
        cfg = self.model.cfg
        pspec = lambda tree: param_pspecs(tree, cfg, node_axis=self.gossip_axis,
                                          fsdp_axis=self.fsdp_axis, model_size=0)
        opt_shape = state_shape.opt
        opt_spec = OptState(
            mu=None if opt_shape.mu is None else pspec(opt_shape.mu),
            nu=None if opt_shape.nu is None else pspec(opt_shape.nu),
            count=P())
        psw_spec = (None if state_shape.psw is None
                    else P(self.gossip_axis, None))
        return TrainState(params=pspec(state_shape.params),
                          x_hat=pspec(state_shape.x_hat),
                          s=pspec(state_shape.s),
                          opt=opt_spec, step=P(), key=P(), psw=psw_spec)

    def state_shardings(self, state_shape=None) -> TrainState:
        """NamedSharding pytree for the TrainState — the target layout the
        sharded checkpoint restore builds global arrays under directly."""
        shape = state_shape if state_shape is not None else self.state_shape()
        specs = self.state_pspecs(shape)
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))

    def init_state(self, key) -> TrainState:
        shape = self.state_shape(key)
        return jax.jit(self._init_state_fn(),
                       out_shardings=self.state_shardings(shape))(key)

    # -- checkpointing ---------------------------------------------------------

    def fingerprint(self) -> dict:
        """Manifest fingerprint: everything the restore path needs to decide
        whether a checkpoint is resume-exact, elastic, or incompatible."""
        axes = (self.gossip_axis if isinstance(self.gossip_axis, tuple)
                else (self.gossip_axis,))
        return {
            "mesh": {a: int(s) for a, s in zip(self.mesh.axis_names,
                                               self.mesh.devices.shape)},
            "gossip_axes": list(axes),
            "n_nodes": int(self.n_nodes),
            "topology": self.choco.topology,
            "gossip_steps": int(self.choco.gossip_steps),
            "mode": self.mode,
            "compressor": self.choco.compressor,
            # hyperparameters behind the name: a resumed run with a
            # different fraction / qsgd_s has a different Assumption-1
            # omega, so its EF state and Theorem-2 gamma are NOT the
            # checkpoint's — restore routes mismatches through the elastic
            # re-mix path.  Packing knobs change the bucket spec the
            # per-bucket gammas are derived from, so they count too.
            "compressor_config": dict(self.choco.comp_kwargs),
            "packed_gossip": bool(self.choco.packed_gossip),
            "pack_align": self.choco.pack_align,
            "exact_small_leaves": bool(self.choco.exact_small_leaves),
            "small_leaf_threshold": int(self.choco.small_leaf_threshold),
            "pipeline_gossip": bool(self.choco.pipeline_gossip),
            "state_dtype": self.choco.state_dtype,
            "topology_process": self.choco.topology_process,
            "edge_drop_prob": self.choco.edge_drop_prob,
            "matching_sampler": self.choco.matching_sampler,
            "max_staleness": self._effective_staleness(),
            # per-edge delay heterogeneity changes the expected mixing
            # matrix (and hence the Theorem-2 gamma the EF state was built
            # under), same hazard class as edge_drop_prob; recorded so a
            # straggler change is visible in the manifest
            "straggler_edges": self.choco.straggler_edges,
            "straggler_delay_probs": self.choco.straggler_delay_probs,
        }

    def _effective_staleness(self) -> int:
        """Staleness bound the state layout actually depends on: tau under
        topology_process='staleness', else 0 — so pre-staleness manifests
        (missing key -> 0) stay resume-exact for every non-async config."""
        return (self.choco.max_staleness
                if self.choco.topology_process == "staleness" else 0)

    def save_checkpoint(self, path: str, state: TrainState,
                        metadata: Optional[dict] = None,
                        keep_last: Optional[int] = None) -> str:
        """Sharded per-host save of the full TrainState (including the CHOCO
        error-feedback states — Theorem 2 needs them across restarts).

        keep_last: after a successful save (manifest rename), delete all but
        the newest k sibling checkpoint dirs (never the one just written) —
        see checkpoint/checkpointing.py gc_checkpoints."""
        from repro.checkpoint.checkpointing import save_sharded
        return save_sharded(path, state, step=int(jax.device_get(state.step)),
                            fingerprint=self.fingerprint(),
                            metadata=metadata or {}, keep_last=keep_last)

    def restore_checkpoint(self, path: str) -> Tuple[TrainState, Any, int]:
        """Restore a sharded checkpoint directly under this trainer's
        shardings (no host-gather, no throwaway init_state donor).

        Returns (state, manifest, warmup_rounds): warmup_rounds > 0 means
        the checkpoint needed an elastic / re-mixed restore — params (and
        optimizer moments) were re-mapped across the node dim, x_hat and s
        were re-zeroed (old public copies are invalid under the new mixing
        matrix W and its Theorem-2 gamma), and the caller should run
        ``consensus_warmup(state, warmup_rounds)`` before training.
        """
        from repro.checkpoint.checkpointing import restore_sharded
        from repro.checkpoint.manifest import read_manifest
        from repro.checkpoint.elastic import (consensus_warmup_rounds,
                                              elastic_ratio)
        man = read_manifest(path)
        shape = self.state_shape()
        shardings = self.state_shardings(shape)
        n_old = man.n_nodes
        saved_topo = man.fingerprint.get("topology")
        same_nodes = n_old is None or n_old == self.n_nodes
        same_graph = saved_topo is None or saved_topo == self.choco.topology
        # a topology-process change re-shapes the replica state (x_hat / s
        # become per-round lists), so it takes the same re-mix path as a
        # graph change; likewise a staleness-bound change re-shapes the
        # ring buffers (stale-buffer subtrees live under the x_hat / s
        # reset prefixes, so the re-shaped lists restore clean)
        fp = man.fingerprint
        same_proc = (fp.get("topology_process", None)
                     == self.choco.topology_process
                     and fp.get("max_staleness", 0)
                     == self._effective_staleness())
        # compression / packing fingerprint: the EF state (x_hat, s) and
        # gamma were built under the checkpoint's omega — a changed
        # compression ratio or bucket layout re-mixes like a graph change.
        # Every key compares with missing-key-matches (.get with the
        # CURRENT value as default) so pre-PR-6 manifests stay resume-exact.
        same_comp = (fp.get("compressor", self.choco.compressor)
                     == self.choco.compressor
                     and fp.get("compressor_config", self.choco.comp_dict())
                     == self.choco.comp_dict()
                     and fp.get("packed_gossip", self.choco.packed_gossip)
                     == bool(self.choco.packed_gossip)
                     and fp.get("pack_align", self.choco.pack_align)
                     == self.choco.pack_align
                     and fp.get("pipeline_gossip",
                                self.choco.pipeline_gossip)
                     == bool(self.choco.pipeline_gossip)
                     and fp.get("exact_small_leaves",
                                self.choco.exact_small_leaves)
                     == bool(self.choco.exact_small_leaves)
                     and fp.get("small_leaf_threshold",
                                self.choco.small_leaf_threshold)
                     == self.choco.small_leaf_threshold)
        same_graph = same_graph and same_proc and same_comp
        if self.mode == "pushsum" and not (same_nodes and same_graph):
            from repro.checkpoint.manifest import ElasticRestoreError
            raise ElasticRestoreError(
                f"elastic restore is not supported for push-sum: the weight "
                f"column w encodes conserved mass (1^T w = n) that a node-"
                f"count or graph change would corrupt (checkpoint "
                f"n_nodes={n_old}, topology={saved_topo!r} -> "
                f"n_nodes={self.n_nodes}, topology={self.choco.topology!r})")
        if same_nodes and (self.mode != "choco" or same_graph):
            return restore_sharded(path, shape, shardings), man, 0
        if not same_nodes:
            elastic_ratio(n_old, self.n_nodes)   # fail fast on bad resize
            state = restore_sharded(path, shape, shardings,
                                    node_remap=(n_old, self.n_nodes),
                                    reset_prefixes=("x_hat", "s"))
        else:
            # same n, different gossip graph: s = sum_j w_ij x_hat_j is an
            # OLD-W aggregate — stale under the new schedule, so re-mix too
            state = restore_sharded(path, shape, shardings,
                                    reset_prefixes=("x_hat", "s"))
        if self.mode != "choco":      # no EF state to re-seed in exact modes
            return state, man, 0
        # warmup contracts at the graph the warmup actually runs on: the
        # process's EXPECTED eigengap when one is active (matching the gamma
        # derivation), the static worst case otherwise
        if self.process is not None:
            delta = self.process.expected_delta_beta()[0]
        else:
            delta = min(t.delta for t in self.topologies)
        return state, man, consensus_warmup_rounds(delta)

    def consensus_warmup(self, state: TrainState, rounds: int) -> TrainState:
        """k rounds of CHOCO-GOSSIP (Algorithm 1) on the current params, no
        gradient step: rebuilds the public copies x_hat and the neighbour
        aggregates s under the CURRENT mixing matrix / Theorem-2 gamma after
        an elastic restore.  Key folds are salted so warmup randomness never
        collides with a training step's fold_in(key, step)."""
        if rounds <= 0 or self.mode != "choco":
            return state
        exchange = self._exchange(state.params)

        def warm(st):
            x, xh, s = st.params, st.x_hat, st.s
            base = jax.random.fold_in(st.key, 0x5EED)
            for r in range(rounds):
                x, xh, s = exchange(jax.random.fold_in(base, r), x, xh, s)
            return st._replace(params=x, x_hat=xh, s=s)

        shardings = self.state_shardings(jax.eval_shape(lambda: state))
        return jax.jit(warm, out_shardings=shardings,
                       donate_argnums=0)(state)

    # -- step -----------------------------------------------------------------

    def make_train_step(self, phase_scopes: bool = False):
        model, opt, lr_fn = self.model, self.optimizer, self.lr_fn
        pushsum = self.mode == "pushsum"
        pipelined = self.choco.pipeline_gossip and self.mode == "choco"
        # jax.named_scope lands in HLO op metadata, so phase names are
        # opt-in (--profile-dir): the default build keeps the compiled step
        # byte-identical to the pre-telemetry HLO (telemetry_off invariant)
        scope = (jax.named_scope if phase_scopes
                 else (lambda name: contextlib.nullcontext()))

        def pipelined_step(state: TrainState, batch) -> Tuple[TrainState, dict]:
            # Two-phase carry (comm/pipelined.py).  Phase A traces the
            # round-k exchange FIRST, on the PRE-gradient iterate: its
            # ppermute payload is Q(x_k - x_hat_k) and its output
            # gx = x_k + gamma (s_k - x_hat_k) consumes only last round's
            # carry — nothing downstream of the batch.  Phase B (grad +
            # optimizer half-step) therefore shares no data dependency
            # with the collective, and XLA overlaps the transfer with the
            # backward matmuls (benchmarks/bench_overlap.py audits this).
            gkey = jax.random.fold_in(state.key, state.step)
            exchange = self._exchange(state.params)
            with scope("obs:exchange"):
                gx, new_hat, new_s = exchange(gkey, state.params,
                                              state.x_hat, state.s)

            def loss_fn(p, b):
                loss, metrics = model.loss(p, b)
                return loss, metrics
            with scope("obs:grad"):
                (losses, metrics), grads = jax.vmap(
                    jax.value_and_grad(loss_fn, has_aux=True))(state.params,
                                                               batch)
            lr = lr_fn(state.step)
            with scope("obs:optimizer"):
                x_half, new_opt = opt.update(state.params, grads,
                                             state.opt, lr)

            # merge the independent halves elementwise:
            #   x_{k+1} = x_k - lr g + gamma (s_k - x_hat_k)
            #           = gx + (x_half - x_k)
            new_params = jax.tree.map(lambda g, xh, x: g + (xh - x),
                                      gx, x_half, state.params)
            out = TrainState(params=new_params, x_hat=new_hat, s=new_s,
                             opt=new_opt, step=state.step + 1, key=state.key,
                             psw=state.psw)
            mets = {"loss": jnp.mean(losses), "lr": lr,
                    "grad_norm": _global_norm(grads),
                    # per-node loss dispersion: the first-order symptom of
                    # non-IID shards (diag/node_loss_spread in the run log)
                    "node_loss_spread": (jnp.max(losses) - jnp.min(losses))}
            for k, v in metrics.items():
                mets[k] = jnp.mean(v)
            return out, mets

        if pipelined:
            return pipelined_step

        def train_step(state: TrainState, batch) -> Tuple[TrainState, dict]:
            # 1. per-node stochastic gradient (no cross-node collectives).
            # Push-sum (SGP, Assran et al. 2019): x <- x - lr * gradF(z) —
            # the gradient is EVALUATED at the de-biased estimate z = x / w
            # (x itself is Perron-biased mid-consensus) but is the gradient
            # w.r.t. z, NOT d/dx F(x/w): differentiating through the
            # division would scale node i's step by a spurious 1/w_i.
            def loss_fn(p, b):
                loss, metrics = model.loss(p, b)
                return loss, metrics
            if pushsum:
                from repro.comm.pushsum import debias
                z = debias(state.params, state.psw)
            else:
                z = state.params
            with scope("obs:grad"):
                (losses, metrics), grads = jax.vmap(
                    jax.value_and_grad(loss_fn, has_aux=True))(z, batch)

            # 2. local optimizer half-step  x^{t+1/2}
            lr = lr_fn(state.step)
            with scope("obs:optimizer"):
                x_half, new_opt = opt.update(state.params, grads,
                                             state.opt, lr)

            # 3. gossip exchange (CHOCO / plain / all-reduce / push-sum)
            gkey = jax.random.fold_in(state.key, state.step)
            exchange = self._exchange(state.params)   # specs from leaf ndims
            with scope("obs:exchange"):
                if pushsum:
                    new_params, new_hat, new_s, new_w = exchange(
                        gkey, x_half, state.x_hat, state.s, state.psw)
                else:
                    new_params, new_hat, new_s = exchange(
                        gkey, x_half, state.x_hat, state.s)
                    new_w = state.psw

            out = TrainState(params=new_params, x_hat=new_hat, s=new_s,
                             opt=new_opt, step=state.step + 1, key=state.key,
                             psw=new_w)
            mets = {"loss": jnp.mean(losses), "lr": lr,
                    "grad_norm": _global_norm(grads),
                    # per-node loss dispersion: the first-order symptom of
                    # non-IID shards (diag/node_loss_spread in the run log)
                    "node_loss_spread": (jnp.max(losses) - jnp.min(losses))}
            for k, v in metrics.items():
                mets[k] = jnp.mean(v)
            return out, mets

        return train_step

    def _exchange(self, params_shape):
        specs = param_pspecs(params_shape, self.model.cfg,
                             node_axis=self.gossip_axis, fsdp_axis=self.fsdp_axis,
                             model_size=0)
        gamma = (self.gamma_spec if self.gamma_spec is not None
                 else self.gamma)
        return make_gossip_exchange(
            mode=self.mode, mesh=self.mesh, state_specs=specs,
            axis=self.gossip_axis, compressor=self.compressor, gamma=gamma,
            exact_small_leaves=self.choco.exact_small_leaves,
            small_leaf_threshold=self.choco.small_leaf_threshold,
            packed=self.choco.packed_gossip,
            pack_align=self.choco.pack_align,
            schedules=self.schedules,
            gossip_steps=self.choco.gossip_steps,
            process=self.process,
            pipelined=self.choco.pipeline_gossip,
            weight_specs=(P(self.gossip_axis, None)
                          if self.mode == "pushsum" else None),
            kernel_backend=self.choco.kernel_backend)

    # -- jit with shardings -----------------------------------------------------

    def jitted_train_step(self, state_shape, batch_shape,
                          phase_scopes: bool = False):
        state_specs = self.state_pspecs(state_shape)
        bspecs = batch_pspecs(batch_shape, node_axis=self.gossip_axis,
                              dp_axis=self.fsdp_axis)
        shard = lambda tree: jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P))
        step = self.make_train_step(phase_scopes=phase_scopes)
        return jax.jit(step,
                       in_shardings=(shard(state_specs), shard(bspecs)),
                       out_shardings=(shard(state_specs), None),
                       donate_argnums=(0,))

    def jitted_diagnostics(self, state_shape):
        """Jitted Lyapunov/consensus diagnostics (``obs/metrics.py``) — a
        SEPARATE executable from the train step; the lazy import keeps
        ``obs`` entirely out of the telemetry-off import path."""
        from repro.obs import metrics as obs_metrics
        return obs_metrics.jitted_diagnostics(self, state_shape)


def _global_shape_error(shape, sp, axes, dim, extent):
    return ValueError(
        f"leaf of global shape {tuple(shape)} cannot be sharded by "
        f"PartitionSpec {sp}: dim {dim} of size {shape[dim]} is not "
        f"divisible by the mesh extent {extent} of axes {axes} — a floored "
        f"local size would mis-derive the bucket spec and its Theorem-2 "
        f"omega.  Pad the dimension to a multiple of {extent} or change "
        f"the partitioning.")


def _local_shape(shape, sp, mesh_axis_sizes) -> Tuple[int, ...]:
    """Per-shard leaf shape under a PartitionSpec — what the exchange's
    bucket spec actually sees inside shard_map.  Raises on non-divisible
    partitioning: XLA would pad such shards, so silently flooring here
    hands the bucket-spec builder (and the omega / gamma derivation built
    on it) a local size the engine never actually sees."""
    dims = list(shape)
    if isinstance(sp, P):
        for i, entry in enumerate(sp):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            f = 1
            for a in axes:
                f *= mesh_axis_sizes[a]
            if f > 1 and dims[i] % f != 0:
                raise _global_shape_error(shape, sp, axes, i, f)
            dims[i] //= f
    return tuple(dims)


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))
