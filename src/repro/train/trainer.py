"""Decentralized trainer: CHOCO-SGD over a device mesh.

State layout: every decentralized leaf (params, x_hat, s, optimizer moments)
carries a leading node dim of size n_nodes, sharded over the gossip mesh axis.
One train step =
    per-node grad (vmap over the node dim -> zero cross-node collectives)
  -> local optimizer half-step
  -> CHOCO gossip exchange (shard_map + ppermute of compressed payloads).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ChocoConfig
from repro.core.compression import make_compressor
from repro.core.choco_gossip import theorem2_stepsize
from repro.core.topology import ring, torus2d
from repro.comm.gossip import make_gossip_exchange
from repro.models.transformer import Model
from repro.optim.sgd import Optimizer, OptState
from repro.launch.sharding import param_pspecs, batch_pspecs


class TrainState(NamedTuple):
    params: Any      # (n_nodes, ...) leaves — the x_i of Algorithm 2
    x_hat: Any       # public copies
    s: Any           # weighted neighbour aggregates
    opt: OptState    # per-node optimizer moments
    step: jax.Array
    key: jax.Array


@dataclasses.dataclass
class DecentralizedTrainer:
    model: Model
    choco: ChocoConfig
    mesh: Any
    n_nodes: int
    optimizer: Optimizer
    lr_fn: Callable[[jax.Array], jax.Array]
    mode: str = "choco"          # choco | plain | allreduce

    def __post_init__(self):
        cfg = self.model.cfg
        self.compressor = (make_compressor(self.choco.compressor, **self.choco.comp_dict())
                           if self.mode == "choco" else None)
        axes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        # torus topology: gossip over the (pod, data) grid — paper Table 1
        # delta = O(1/n) instead of the ring's O(1/n^2)
        self.torus = (self.choco.topology == "torus"
                      and "pod" in self.mesh.axis_names)
        if self.torus:
            self.gossip_axis = ("pod", "data")
            n = axes["pod"] * axes["data"]
            self.fsdp_axis = None
            topo = torus2d(axes["pod"], axes["data"])
        else:
            self.gossip_axis = self.choco.gossip_axis
            n = axes[self.gossip_axis]
            self.fsdp_axis = "data" if self.gossip_axis == "pod" else None
            topo = ring(n)
        assert n == self.n_nodes, \
            f"gossip over {self.gossip_axis} = {n} nodes != n_nodes {self.n_nodes}"
        # Theorem-2 consensus stepsize from the topology and compression
        if self.choco.consensus_gamma is not None:
            self.gamma = self.choco.consensus_gamma
        elif self.mode == "choco":
            # omega depends on leaf size; use a representative 1M-coordinate value
            omega = self.compressor.omega(1 << 20)
            self.gamma = theorem2_stepsize(topo.delta, topo.beta, omega)
        else:
            self.gamma = 1.0

    # -- state ----------------------------------------------------------------

    def _init_state_fn(self):
        model, n = self.model, self.n_nodes

        sdt = jnp.dtype(self.choco.state_dtype)

        def init(key):
            pkeys = jax.random.split(key, n)
            params = jax.vmap(model.init)(pkeys)
            ef_zeros = lambda: jax.tree.map(
                lambda p: jnp.zeros(p.shape, sdt if jnp.issubdtype(p.dtype, jnp.floating)
                                    else p.dtype), params)
            opt = self.optimizer.init(params)
            return TrainState(params=params, x_hat=ef_zeros(), s=ef_zeros(),
                              opt=opt, step=jnp.zeros((), jnp.int32), key=key)
        return init

    def state_shape(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self._init_state_fn(), key)

    def state_pspecs(self, state_shape) -> TrainState:
        cfg = self.model.cfg
        pspec = lambda tree: param_pspecs(tree, cfg, node_axis=self.gossip_axis,
                                          fsdp_axis=self.fsdp_axis, model_size=0)
        opt_shape = state_shape.opt
        opt_spec = OptState(
            mu=None if opt_shape.mu is None else pspec(opt_shape.mu),
            nu=None if opt_shape.nu is None else pspec(opt_shape.nu),
            count=P())
        return TrainState(params=pspec(state_shape.params),
                          x_hat=pspec(state_shape.x_hat),
                          s=pspec(state_shape.s),
                          opt=opt_spec, step=P(), key=P())

    def init_state(self, key) -> TrainState:
        shape = self.state_shape(key)
        specs = self.state_pspecs(shape)
        shardings = jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs,
                                 is_leaf=lambda x: isinstance(x, P))
        return jax.jit(self._init_state_fn(), out_shardings=shardings)(key)

    # -- step -----------------------------------------------------------------

    def make_train_step(self):
        model, opt, lr_fn = self.model, self.optimizer, self.lr_fn

        def train_step(state: TrainState, batch) -> Tuple[TrainState, dict]:
            # 1. per-node stochastic gradient (no cross-node collectives)
            def loss_fn(p, b):
                loss, metrics = model.loss(p, b)
                return loss, metrics
            (losses, metrics), grads = jax.vmap(
                jax.value_and_grad(loss_fn, has_aux=True))(state.params, batch)

            # 2. local optimizer half-step  x^{t+1/2}
            lr = lr_fn(state.step)
            x_half, new_opt = opt.update(state.params, grads, state.opt, lr)

            # 3. gossip exchange (CHOCO / plain / all-reduce)
            gkey = jax.random.fold_in(state.key, state.step)
            exchange = self._exchange(state.params)   # specs from leaf ndims
            new_params, new_hat, new_s = exchange(gkey, x_half, state.x_hat, state.s)

            out = TrainState(params=new_params, x_hat=new_hat, s=new_s,
                             opt=new_opt, step=state.step + 1, key=state.key)
            mets = {"loss": jnp.mean(losses), "lr": lr,
                    "grad_norm": _global_norm(grads)}
            for k, v in metrics.items():
                mets[k] = jnp.mean(v)
            return out, mets

        return train_step

    def _exchange(self, params_shape):
        specs = param_pspecs(params_shape, self.model.cfg,
                             node_axis=self.gossip_axis, fsdp_axis=self.fsdp_axis,
                             model_size=0)
        return make_gossip_exchange(
            mode=self.mode, mesh=self.mesh, state_specs=specs,
            axis=self.gossip_axis, compressor=self.compressor, gamma=self.gamma,
            exact_small_leaves=self.choco.exact_small_leaves,
            small_leaf_threshold=self.choco.small_leaf_threshold,
            packed=self.choco.packed_gossip,
            pack_align=self.choco.pack_align)

    # -- jit with shardings -----------------------------------------------------

    def jitted_train_step(self, state_shape, batch_shape):
        state_specs = self.state_pspecs(state_shape)
        bspecs = batch_pspecs(batch_shape, node_axis=self.gossip_axis,
                              dp_axis=self.fsdp_axis)
        shard = lambda tree: jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P))
        step = self.make_train_step()
        return jax.jit(step,
                       in_shardings=(shard(state_specs), shard(bspecs)),
                       out_shardings=(shard(state_specs), None),
                       donate_argnums=(0,))


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))
