"""Model / training / distribution configuration system.

Every assigned architecture gets a ``configs/<id>.py`` exporting ``CONFIG``
(the exact full-size config) and ``SMOKE_CONFIG`` (reduced same-family
variant: <=2 layers, d_model<=512, <=4 experts) used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                  # hidden size of each expert FFN
    capacity_factor: float = 1.25
    group_size: int = 512          # dispatch group (tokens) for the einsum path
    moe_every: int = 1             # 1 = every layer is MoE; 2 = alternate dense/MoE
    n_shared_experts: int = 0      # always-on shared expert(s) (llama4)
    router_aux_weight: float = 0.01
    combine_seq_shard: bool = False  # constrain combine output group-sharded
                                     # over `model` (RS instead of AR)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"           # mamba2 | rwkv6
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64             # SSM head dim (mamba2 P / rwkv head size)
    chunk: int = 128               # SSD chunk length (mamba2)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """zamba2-style: mamba backbone + one weight-shared attention block
    applied every `shared_every` positions."""
    shared_every: int = 6


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Stubbed modality frontend (assignment carve-out): input_specs()
    provides precomputed embeddings of this shape."""
    kind: str                       # "vision" | "audio"
    n_tokens: int                   # patches / frames per example
    embed_dim: int                  # frontend output dim
    text_tokens: int = 0            # VLM: text positions appended after patches


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # attention behaviour
    causal: bool = True
    qk_norm: bool = False
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None    # window for local layers
    local_global_pattern: int = 0           # k>0: alternate k local : 1 global
    rope_theta: float = 10_000.0
    # mlp
    mlp_type: str = "swiglu"                # swiglu | geglu | gelu
    # subsystem configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    frontend: Optional[FrontendConfig] = None
    # numerics / training
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "none"                     # none | full | dots
    loss_chunk: int = 0                     # 0 = unchunked cross-entropy
    scan_unroll: bool = False               # unroll the layer scan (dry-run
                                            # analysis: exact HLO flops/collectives)
    attn_impl: str = "naive"                # naive | chunked (flash-style online
                                            # softmax, never materialises SxS)
    attn_chunk: int = 1024                  # KV block size for chunked attention
    # paper-technique defaults for this arch
    source: str = ""                        # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        D, F, V, Hd = self.d_model, self.d_ff, self.vocab_size, self.resolved_head_dim
        H, KV, L = self.n_heads, self.n_kv_heads, self.n_layers
        emb = V * D * (1 if self.tie_embeddings else 2)
        attn = D * H * Hd + 2 * D * KV * Hd + H * Hd * D
        if self.mlp_type in ("swiglu", "geglu"):
            mlp = 3 * D * F
        else:
            mlp = 2 * D * F
        per_layer = attn + mlp + 2 * D
        if self.family == "moe":
            m = self.moe
            expert = 3 * D * m.d_expert
            moe_layer = attn + m.n_experts * expert + D * m.n_experts + 2 * D \
                + m.n_shared_experts * 3 * D * self.d_ff
            n_moe = L // m.moe_every
            total_blocks = (L - n_moe) * per_layer + n_moe * moe_layer
        elif self.family == "ssm" and self.ssm.kind == "rwkv6":
            # rwkv: timemix (r,k,v,g,o ~ 5 D^2 + decay lora) + channelmix ~ 2*D*F
            total_blocks = L * (5 * D * D + 2 * D * F + 2 * D)
        elif self.family in ("ssm", "hybrid"):
            di = self.ssm.expand * D
            mamba = D * (2 * di + 2 * self.ssm.d_state * (di // self.ssm.head_dim)) \
                + di * D + di * self.ssm.d_conv
            if self.family == "hybrid":
                n_shared = L // (self.hybrid.shared_every + 1) if self.hybrid else 0
                total_blocks = (L - n_shared) * (mamba + 2 * D) + (attn + mlp + 2 * D)
            else:
                total_blocks = L * (mamba + 2 * D)
        else:
            total_blocks = L * per_layer
        proj = 0
        if self.frontend is not None and self.frontend.kind == "vision":
            proj = self.frontend.embed_dim * D + D * D
        if self.family == "audio":
            emb = self.frontend.embed_dim * D + V * D   # in-proj + class head
        return emb + total_blocks + proj + D

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.n_params()
        m = self.moe
        D = self.d_model
        expert = 3 * D * m.d_expert
        inactive = (m.n_experts - m.top_k) * expert * (self.n_layers // m.moe_every)
        return self.n_params() - inactive


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


ARCH_IDS = [
    "yi-9b", "hubert-xlarge", "qwen3-1.7b", "zamba2-1.2b", "qwen3-moe-30b-a3b",
    "llama4-maverick-400b-a17b", "gemma2-9b", "rwkv6-3b",
    "llava-next-mistral-7b", "gemma-7b",
]


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    """Load the named arch's ModelConfig (or its tiny SMOKE_CONFIG) from
    its ``repro.configs.<arch>`` module, lazily imported."""
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def parse_topology(spec: str) -> Tuple[str, ...]:
    """Comma-separated topology spec -> name tuple ("ring,hypercube" ->
    ("ring", "hypercube")).  The single parser for both the CLI validation
    (launch/train.py, pre-jax) and the trainer — this module is jax-free, so
    the two can never drift."""
    return tuple(t.strip() for t in spec.split(",") if t.strip())


def parse_straggler_edges(spec: str) -> Tuple[Tuple[int, int], ...]:
    """Comma-separated edge spec -> node-pair tuple ("0-1,2-3" ->
    ((0, 1), (2, 3))).  Syntax-level validation only (integers, 'a-b'
    shape, no self-edges, nonnegative ids) so the CLI can fail fast
    pre-jax; membership in the compiled schedule's edge support is
    checked by ``StalenessProcess`` once the schedule exists.  This
    module is jax-free, so launcher and trainer share one parser."""
    out = []
    for part in (p.strip() for p in spec.split(",") if p.strip()):
        halves = part.split("-")
        if len(halves) != 2:
            raise ValueError(f"straggler edge {part!r} is not of the "
                             f"form 'a-b'")
        try:
            a, b = int(halves[0]), int(halves[1])
        except ValueError:
            raise ValueError(f"straggler edge {part!r} has non-integer "
                             f"node ids") from None
        if a < 0 or b < 0:
            raise ValueError(f"straggler edge {part!r} has negative "
                             f"node ids")
        if a == b:
            raise ValueError(f"straggler edge {part!r} is a self-edge")
        out.append((min(a, b), max(a, b)))
    if not out:
        raise ValueError(f"empty straggler edge spec {spec!r}")
    return tuple(out)


def parse_delay_probs(spec: str) -> Tuple[float, ...]:
    """Comma-separated probability list ("0.1,0.2,0.7" -> floats).
    Syntax + sign/mass validation only; the arity-vs-max_staleness check
    lives with the consumer (CLI pre-jax, ``StalenessProcess`` at build
    time).  Jax-free, shared by launcher and trainer."""
    try:
        probs = tuple(float(p.strip())
                      for p in spec.split(",") if p.strip())
    except ValueError:
        raise ValueError(f"delay probs {spec!r} must be a comma-"
                         f"separated float list") from None
    if not probs:
        raise ValueError(f"empty delay-probs spec {spec!r}")
    if min(probs) < 0 or sum(probs) <= 0:
        raise ValueError(f"delay probs must be nonnegative with positive "
                         f"mass, got {probs}")
    return probs


@dataclasses.dataclass(frozen=True)
class ChocoConfig:
    """Paper-technique settings for decentralized training."""
    compressor: str = "top_k"       # compression.make_compressor name
    comp_kwargs: tuple = (("fraction", 0.01),)
    gossip_axis: str = "data"       # mesh axis carrying the gossip graph
    # gossip graph name (core.topology registry: ring | torus | hypercube |
    # star | chain | fully_connected), or a comma-separated sequence
    # ("ring,hypercube") for time-varying mixing — the schedule compiler
    # (comm/schedule.py) compiles one schedule per name and the engine
    # cycles through them across the gossip_steps rounds of each SGD step
    topology: str = "ring"
    # CHOCO gossip rounds per SGD step (Hashemi et al. 2020: multiple gossip
    # steps per update dramatically improve communication-constrained
    # convergence); the packed engine builds the bucket spec once per step,
    # so k rounds amortize k compressions into one pack
    gossip_steps: int = 1
    consensus_gamma: Optional[float] = None   # None = Theorem-2 stepsize
    # which leaves gossip exactly (uncompressed): tiny leaves where compression
    # overhead > saving (beyond-paper optimisation, off for paper-faithful runs)
    exact_small_leaves: bool = False
    small_leaf_threshold: int = 8_192
    # dtype of the error-feedback states x_hat and s (beyond-paper memory
    # optimisation: bf16 halves the 2N-state overhead and the wire payload)
    state_dtype: str = "float32"
    # bucketed flat-buffer gossip engine (comm/packing.py): pack the pytree
    # into a few dtype-homogeneous buckets, compress once per bucket, ship
    # one payload per neighbour.  False = legacy per-leaf exchange.
    packed_gossip: bool = True
    # segment alignment inside compressed buckets; None = the compressor's
    # block width (block_top_k) or the 128-lane unit
    pack_align: Optional[int] = None
    # stochastic topology process (comm/stochastic.py): None = static
    # schedule replay; "matching" samples one compiled round per gossip
    # round (one permute launch/step, replica-based engine); "linkfail"
    # drops each edge i.i.d. with edge_drop_prob per round (weights
    # renormalized into the diagonal).  Theorem-2 gamma is re-derived from
    # the EXPECTED mixing matrix's eigengap.
    # "staleness" runs the bounded-staleness async engine
    # (comm/async_gossip.py): every edge's payload may arrive up to
    # max_staleness rounds late (per-edge delay sampled from the shared
    # exchange key) and nodes proceed on the freshest copy they hold.
    topology_process: Optional[str] = None
    edge_drop_prob: float = 0.1          # linkfail Bernoulli drop probability
    matching_sampler: str = "uniform"    # matching round sampler: uniform|weighted
    # staleness bound tau for topology_process="staleness": per-edge delays
    # are sampled uniformly from {0..tau} (tau=0 degenerates to the always-
    # fresh replica engine).  Theorem-2 gamma folds tau into omega and uses
    # the delay-averaged mixing matrix phi*W + (1-phi)*I, phi = E[1/(1+d)].
    max_staleness: int = 1
    # pipelined engine (comm/pipelined.py): compress the PRE-gradient
    # iterate and integrate the received payload at the NEXT step's update
    # so the collective overlaps the backward pass (tau=1 deterministic
    # staleness; gamma re-derived from (W+I)/2 with omega/2).  Requires
    # mode='choco', a single static topology, and no topology_process.
    pipeline_gossip: bool = False
    # kernel backend for the gossip hot path (kernels/dispatch.py):
    # 'auto' probes the toolchain and picks the fused Pallas kernels when
    # they can run compiled (TPU), 'pallas'/'jnp' force.  Never part of the
    # checkpoint fingerprint: flipping it changes neither the state layout
    # nor the wire bytes, so resumes are backend-portable.
    kernel_backend: str = "auto"
    # non-IID data skew (data/partition.py): Dirichlet(alpha) per-node
    # vocab/label shards — alpha -> inf is IID ("shuffled"), alpha -> 0 is
    # disjoint shards ("sorted").  None = the legacy heterogeneity knob.
    data_skew_alpha: Optional[float] = None
    # per-edge straggler links for topology_process="staleness": canonical
    # "a-b,c-d" edge list whose delays come from straggler_delay_probs
    # (comma-separated P(d=0..tau); None = point mass at tau, a maximally
    # slow link) instead of the global uniform/delay_probs distribution.
    straggler_edges: Optional[str] = None
    straggler_delay_probs: Optional[str] = None

    def comp_dict(self):
        return dict(self.comp_kwargs)
