"""HuBERT-XLarge — encoder-only audio transformer (same arch as wav2vec2)
[arXiv:2106.07447].  Conv feature extractor is a stub (carve-out):
input_specs() provides 512-dim frame embeddings; the model is the 48-layer
bidirectional encoder + masked-prediction head over 504 cluster classes.
No autoregressive decode — decode shapes are skipped (see DESIGN.md §4)."""
from repro.configs.base import ModelConfig, FrontendConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504,
    causal=False, mlp_type="gelu",
    frontend=FrontendConfig(kind="audio", n_tokens=0, embed_dim=512),
    remat="dots",
    source="arXiv:2106.07447",
)

SMOKE_CONFIG = ModelConfig(
    name="hubert-xlarge-smoke", family="audio",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=64,
    causal=False, mlp_type="gelu",
    frontend=FrontendConfig(kind="audio", n_tokens=0, embed_dim=128),
    source="arXiv:2106.07447",
)
