"""Llama-4 Maverick 400B-A17B — MoE 128 routed experts top-1 + 1 shared
expert, MoE interleaved every other layer; early-fusion multimodal frontend
stubbed (text backbone only, per assignment carve-out)
[hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    mlp_type="swiglu", rope_theta=500_000.0,
    moe=MoEConfig(n_experts=128, top_k=1, d_expert=8192, moe_every=2,
                  n_shared_experts=1, capacity_factor=1.25, group_size=512),
    remat="dots", loss_chunk=512,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE_CONFIG = ModelConfig(
    name="llama4-maverick-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=256,
    mlp_type="swiglu",
    moe=MoEConfig(n_experts=4, top_k=1, d_expert=128, moe_every=2,
                  n_shared_experts=1, capacity_factor=2.0, group_size=64),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
