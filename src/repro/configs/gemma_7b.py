"""Gemma 7B — GeGLU, head_dim=256 (16 MHA heads), huge GeGLU FFN, tied
embeddings [arXiv:2403.08295]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000,
    mlp_type="geglu", tie_embeddings=True,
    remat="dots", loss_chunk=512,
    source="arXiv:2403.08295",
)

SMOKE_CONFIG = ModelConfig(
    name="gemma-7b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=512,
    mlp_type="geglu", tie_embeddings=True,
    source="arXiv:2403.08295",
)
