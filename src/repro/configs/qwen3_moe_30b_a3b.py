"""Qwen3-30B-A3B — MoE, 128 experts top-8, every layer MoE, QK-norm GQA
[hf:Qwen/Qwen3-30B-A3B].  d_ff=768 is the per-expert hidden size."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936,
    qk_norm=True, mlp_type="swiglu", rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=768, moe_every=1,
                  capacity_factor=1.25, group_size=512),
    remat="dots", loss_chunk=512,
    source="hf:Qwen/Qwen3-30B-A3B",
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=64, vocab_size=256,
    qk_norm=True, mlp_type="swiglu",
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, moe_every=1,
                  capacity_factor=2.0, group_size=64),
    source="hf:Qwen/Qwen3-30B-A3B",
)
