"""Gemma-2 9B — alternating local(4096-window)/global attention, logit
softcaps, GeGLU, tied embeddings [arXiv:2408.00118]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    mlp_type="geglu", tie_embeddings=True,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    sliding_window=4096, local_global_pattern=1,
    remat="dots", loss_chunk=512,
    source="arXiv:2408.00118",
)

SMOKE_CONFIG = ModelConfig(
    name="gemma2-9b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512,
    mlp_type="geglu", tie_embeddings=True,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    sliding_window=16, local_global_pattern=1,
    source="arXiv:2408.00118",
)
