"""Qwen3-1.7B — dense decoder with QK-norm and GQA [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=6144, vocab_size=151936,
    qk_norm=True, mlp_type="swiglu", rope_theta=1_000_000.0,
    remat="dots", loss_chunk=512,
    source="hf:Qwen/Qwen3-8B",
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-1.7b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512,
    qk_norm=True, mlp_type="swiglu",
    source="hf:Qwen/Qwen3-8B",
)
