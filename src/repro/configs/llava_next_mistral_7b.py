"""LLaVA-NeXT (Mistral-7B backbone) — VLM with anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf].  Vision tower (CLIP ViT-L) is a stub
per the assignment carve-out: input_specs() provides 1024-dim patch
embeddings for 5 anyres tiles x 576 patches = 2880 image tokens; the model is
the 2-layer MLP projector + the Mistral-7B decoder."""
from repro.configs.base import ModelConfig, FrontendConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    mlp_type="swiglu", rope_theta=1_000_000.0,
    frontend=FrontendConfig(kind="vision", n_tokens=2880, embed_dim=1024),
    remat="dots", loss_chunk=512,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

SMOKE_CONFIG = ModelConfig(
    name="llava-next-smoke", family="vlm",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512,
    mlp_type="swiglu",
    frontend=FrontendConfig(kind="vision", n_tokens=16, embed_dim=64),
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
