"""RWKV-6 "Finch" 3B — attention-free, data-dependent decay linear attention
[arXiv:2404.05892].  40 heads of size 64 (d_model 2560)."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=8960, vocab_size=65536,
    ssm=SSMConfig(kind="rwkv6", head_dim=64),
    remat="dots", loss_chunk=512,
    source="arXiv:2404.05892",
)

SMOKE_CONFIG = ModelConfig(
    name="rwkv6-3b-smoke", family="ssm",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=512,
    ssm=SSMConfig(kind="rwkv6", head_dim=64),
    source="arXiv:2404.05892",
)
