"""Yi-9B — llama-arch dense decoder with GQA [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64000,
    mlp_type="swiglu", rope_theta=10_000.0,
    remat="dots", loss_chunk=512,
    source="arXiv:2403.04652",
)

SMOKE_CONFIG = ModelConfig(
    name="yi-9b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512,
    mlp_type="swiglu",
    source="arXiv:2403.04652",
)
