"""Zamba2-1.2B — hybrid: Mamba2 backbone + one weight-SHARED attention block
applied every 6th position [arXiv:2411.15242].  38 blocks total:
(5 mamba + 1 shared-attn) x 6 + 2 tail mamba."""
from repro.configs.base import ModelConfig, SSMConfig, HybridConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    mlp_type="swiglu",
    ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    hybrid=HybridConfig(shared_every=6),
    remat="dots",
    source="arXiv:2411.15242",
)

SMOKE_CONFIG = ModelConfig(
    name="zamba2-1.2b-smoke", family="hybrid",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=512,
    mlp_type="swiglu",
    ssm=SSMConfig(kind="mamba2", d_state=16, d_conv=4, expand=2, head_dim=64, chunk=32),
    hybrid=HybridConfig(shared_every=2),
    source="arXiv:2411.15242",
)
