"""Elastic restore policy: remap the leading node dim when n_nodes changes.

Every decentralized leaf (params, optimizer moments, CHOCO x_hat / s) carries
a leading node dim of size n_nodes — the rows x_i of Algorithm 2.  When a
checkpoint saved with ``n_old`` nodes is restored onto ``n_new`` nodes, the
mixing matrix W, its spectral gap delta, and hence the Theorem-2 consensus
stepsize gamma all change, so the old state cannot be used verbatim.  The
policy (documented here and in EXPERIMENTS.md):

  * **grow** (``n_new % n_old == 0``, ratio r): cyclic tile —
    ``new[j] = old[j % n_old]``.  Replicas of the same old node land r node
    ids apart, so on ring / torus / chain graphs adjacent new nodes hold
    DIFFERENT models and the first gossip rounds mix real disagreement
    instead of shuffling identical copies.
  * **shrink** (``n_old % n_new == 0``, ratio r): strided mean —
    ``new[j] = mean(old[j::n_new])`` (computed in float32, cast back).
    This is the exact inverse of the grow policy (tile then shrink
    round-trips bit-wise for r=1, value-wise otherwise) and matches
    consensus semantics: the surviving node represents the average of the
    models it absorbs.
  * anything else raises :class:`ElasticRestoreError` — a non-divisible
    resize has no canonical correspondence between old and new rows.

The CHOCO error-feedback states x_hat and s are NOT remapped: x_hat_i is the
*public* copy every neighbour j integrated via the old W, and s_i is the
old-W-weighted aggregate sum_j w_ij x_hat_j.  Under the new W both are stale
in a way error feedback cannot repair (Theorem 2's Lyapunov function couples
them to the fixed mixing matrix), so they are re-zeroed and re-built by a
logged consensus warmup of k CHOCO-GOSSIP rounds (Algorithm 1) before
training resumes — see :func:`consensus_warmup_rounds`.
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np

from repro.checkpoint.manifest import ElasticRestoreError


def elastic_ratio(n_old: int, n_new: int) -> Tuple[str, int]:
    """("grow"|"shrink"|"same", ratio) or raise ElasticRestoreError."""
    if n_old == n_new:
        return "same", 1
    if n_old <= 0 or n_new <= 0:
        raise ElasticRestoreError(f"invalid node counts {n_old} -> {n_new}")
    if n_new % n_old == 0:
        return "grow", n_new // n_old
    if n_old % n_new == 0:
        return "shrink", n_old // n_new
    raise ElasticRestoreError(
        f"cannot elastically restore n_nodes={n_old} -> {n_new}: the policy "
        f"needs one count to divide the other (cyclic tile on grow, strided "
        f"mean on shrink); resize to a multiple or re-initialise")


def source_rows(new_row: int, n_old: int, n_new: int) -> Tuple[int, ...]:
    """Old node rows feeding new node ``new_row`` under the policy."""
    kind, _ = elastic_ratio(n_old, n_new)
    if kind in ("same", "grow"):
        return (new_row % n_old,)
    return tuple(range(new_row, n_old, n_new))          # strided mean set


def remap_rows(old: np.ndarray, n_new: int) -> np.ndarray:
    """Apply the policy to a host array with leading node dim (reference
    implementation; the sharded restore applies the same map per shard)."""
    n_old = old.shape[0]
    kind, _ = elastic_ratio(n_old, n_new)
    if kind == "same":
        return old
    if kind == "grow":
        return old[np.arange(n_new) % n_old]
    acc = old.astype(np.float32).reshape(-1, n_new, *old.shape[1:])
    return acc.mean(axis=0).astype(old.dtype)


def consensus_warmup_rounds(delta: float, *, target: float = 0.25,
                            cap: int = 64) -> int:
    """Rounds k of CHOCO-GOSSIP warmup after an elastic restore.

    Exact gossip contracts consensus error by (1 - delta) per round
    (spectral gap of the NEW graph), so k = ceil(log(target)/log(1-delta))
    rounds shrink the tile/mean-induced disagreement — and the re-zeroed
    ||x - x_hat|| term, which starts at ||x|| and contracts at least as fast
    once the public copies are seeded — to a `target` fraction.  The
    Theorem-2 rate (1 - delta^2 omega / 82) is the worst-case guarantee for
    the COUPLED Lyapunov function; using it here would prescribe ~1e6 rounds
    of pure warmup, which is the bound's looseness, not a real requirement
    (see EXPERIMENTS.md §Checkpointing).  `cap` bounds pathological graphs
    (chain/ring at large n, delta -> 0).
    """
    if not 0.0 < delta <= 1.0:
        raise ElasticRestoreError(f"spectral gap delta={delta} outside (0, 1]")
    if delta == 1.0:                                    # fully connected
        return 1
    k = math.ceil(math.log(target) / math.log(1.0 - delta))
    return max(1, min(cap, k))
