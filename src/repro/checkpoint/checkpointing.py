"""Checkpointing: sharded, manifest-driven save/restore (no external deps).

Saves the full decentralized TrainState — including the CHOCO error-feedback
states x_hat and s, which MUST survive restarts (dropping them resets the
compression error memory and breaks the convergence guarantee of Theorem 2).

Two formats:

  * **sharded** (default; a directory) — each process writes ONLY its
    addressable shards into ``shards-p<idx>.npz`` plus a sidecar index; no
    host ever gathers the global state.  ``manifest.json`` (see
    ``manifest.py``) records tree structure, true dtypes (bfloat16 is
    bit-cast to uint16 on disk, halving bytes vs the legacy f32 widening),
    global shapes, the mesh/topology/gossip fingerprint, and the step.
    Restore builds global arrays directly under the target shardings via
    ``jax.make_array_from_callback`` — each device reads only its slice —
    and supports **elastic** restore across a node-count change (policy in
    ``elastic.py``).
  * **legacy flat npz** (a single ``.npz`` file) — kept for small
    single-host trees; still readable and writable, now with real
    validation errors instead of a bare ``assert``.
"""
from __future__ import annotations

import glob
import json
import os
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.checkpoint.manifest import (
    FLAT_KEY_SEP, CheckpointError, LeafSpec, Manifest, ManifestError,
    ShardCoverageError, TreeMismatchError, is_sharded_checkpoint, key_prefix,
    read_manifest, storage_dtype, validate_tree, write_manifest)
from repro.checkpoint.elastic import elastic_ratio, source_rows

_SEP = FLAT_KEY_SEP
_ENTRY_SEP = "@"          # npz entry name: "<leaf key>@<shard number>"


def _path_key(path) -> str:
    return _SEP.join(
        str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
        for k in path)


def _flatten_with_keys(tree) -> List[Tuple[str, Any]]:
    return [(_path_key(p), leaf)
            for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]]


def tree_leaf_specs(like) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    """Flat {key: (shape, dtype name)} for a pytree of arrays or
    ShapeDtypeStructs — the validation target for restore."""
    return {key: (tuple(leaf.shape), np.dtype(leaf.dtype).name)
            for key, leaf in _flatten_with_keys(like)}


def _to_storage(arr: np.ndarray) -> np.ndarray:
    """Lossless on-disk form: bit-cast dtypes npz cannot serialize."""
    sdt = storage_dtype(arr.dtype.name)
    return arr if sdt == arr.dtype.name else arr.view(np.dtype(sdt))


def _slices_to_bounds(index: Tuple, shape: Tuple[int, ...]):
    starts = [s.start if s.start is not None else 0 for s in index]
    stops = [s.stop if s.stop is not None else dim
             for s, dim in zip(index, shape)]
    return starts, stops


# ---------------------------------------------------------------------------
# sharded save
# ---------------------------------------------------------------------------

def save_sharded(ckpt_dir: str, tree, *, step: int,
                 fingerprint: Optional[Dict[str, Any]] = None,
                 metadata: Optional[Dict[str, Any]] = None,
                 keep_last: Optional[int] = None) -> str:
    """Per-host sharded save.  Each process writes the shards it owns
    (``replica_id == 0`` — exactly one owner per global tile, so shards
    never overlap across hosts) plus an index sidecar; process 0 writes the
    manifest LAST, so a manifest's presence marks the checkpoint complete.

    keep_last: retention/GC — after the manifest is published, delete all
    but the newest ``keep_last`` completed sibling checkpoints (directories
    of ``ckpt_dir``'s parent that hold a readable manifest), never the one
    just written.  Runs only on process 0, only after the save succeeded, so
    a crashed save can never delete the checkpoints it was meant to
    supersede.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    pidx = jax.process_index()
    arrays: Dict[str, np.ndarray] = {}
    entries: Dict[str, Dict[str, Any]] = {}
    leaves: Dict[str, LeafSpec] = {}
    for key, leaf in _flatten_with_keys(tree):
        if isinstance(leaf, jax.Array):
            # per-device shards even when fully addressable, so a restore
            # onto a different sharding reads only what it needs
            shards = [s for s in leaf.addressable_shards if s.replica_id == 0]
        else:
            leaf = np.asarray(leaf)
            shards = [None] if pidx == 0 else []
        dt = np.dtype(leaf.dtype)
        leaves[key] = LeafSpec(shape=tuple(leaf.shape), dtype=dt.name,
                               storage=storage_dtype(dt.name))
        for j, sh in enumerate(shards):
            if sh is None:
                data, index = leaf, tuple(slice(0, d) for d in leaf.shape)
            else:
                data, index = np.asarray(sh.data), sh.index
            starts, stops = _slices_to_bounds(index, leaf.shape)
            entry = f"{key}{_ENTRY_SEP}{j}"
            arrays[entry] = _to_storage(np.asarray(data))
            entries[entry] = {"key": key, "start": starts, "stop": stops}
    np.savez(os.path.join(ckpt_dir, f"shards-p{pidx:05d}.npz"), **arrays)
    with open(os.path.join(ckpt_dir, f"shards-p{pidx:05d}.index.json"),
              "w") as f:
        json.dump({"process": pidx, "entries": entries}, f)
    if jax.process_count() > 1:
        # every host must finish its shard files BEFORE process 0 publishes
        # the manifest — its presence is the completeness marker
        from jax.experimental import multihost_utils
        multihost_utils.sync_global_devices("checkpoint_shards_written")
    if pidx == 0:
        write_manifest(ckpt_dir, Manifest(
            step=int(step), leaves=leaves,
            fingerprint=dict(fingerprint or {}),
            metadata=dict(metadata or {}),
            process_count=jax.process_count()))
        if keep_last is not None:
            gc_checkpoints(os.path.dirname(os.path.abspath(ckpt_dir)),
                           keep_last, protect=ckpt_dir)
    return ckpt_dir


def gc_checkpoints(parent_dir: str, keep_last: int,
                   protect: Optional[str] = None) -> List[str]:
    """Delete all but the newest ``keep_last`` COMPLETED checkpoints under
    ``parent_dir`` (subdirectories with a readable manifest, ordered by
    manifest step).  ``protect`` (the checkpoint just written) is never
    deleted even if ``keep_last`` would drop it.  Torn directories without a
    manifest are left alone — they were never published, and deleting them
    here could race a concurrent writer.  Returns the deleted paths."""
    import shutil
    if keep_last < 1:
        raise ValueError(f"keep_last must be >= 1, got {keep_last}")
    protect_abs = os.path.abspath(protect) if protect else None
    done = []
    for name in sorted(os.listdir(parent_dir)):
        path = os.path.join(parent_dir, name)
        if not os.path.isdir(path) or not is_sharded_checkpoint(path):
            continue
        try:
            man = read_manifest(path)
        except CheckpointError:
            continue
        done.append((man.step, path))
    done.sort(key=lambda sp: sp[0])
    deleted = []
    excess = len(done) - keep_last
    for step_, path in done:
        if excess <= 0:
            break
        if protect_abs and os.path.abspath(path) == protect_abs:
            continue
        shutil.rmtree(path)
        deleted.append(path)
        excess -= 1
    return deleted


# ---------------------------------------------------------------------------
# sharded restore
# ---------------------------------------------------------------------------

class _ShardStore:
    """Lazy reader over every ``shards-p*.npz`` in a checkpoint dir: maps a
    requested global region of a leaf to the union of stored shard slices
    covering it.  npz members are only decompressed when touched, so each
    host reads just the bytes its devices need."""

    def __init__(self, ckpt_dir: str):
        self.dir = ckpt_dir
        self.by_key: Dict[str, List[Tuple[str, str, List[int], List[int]]]] = {}
        self._npz: Dict[str, Any] = {}
        self._cache: Dict[Tuple[str, str], np.ndarray] = {}
        for ipath in sorted(glob.glob(os.path.join(ckpt_dir,
                                                   "shards-p*.index.json"))):
            with open(ipath) as f:
                idx = json.load(f)
            npz_path = re.sub(r"\.index\.json$", ".npz", ipath)
            for entry, rec in idx["entries"].items():
                self.by_key.setdefault(rec["key"], []).append(
                    (npz_path, entry, rec["start"], rec["stop"]))

    def _entry(self, npz_path: str, entry: str) -> np.ndarray:
        # memoize decoded members: NpzFile.__getitem__ decompresses the whole
        # entry per access, and the per-device / per-row (elastic) callbacks
        # revisit the same stored shard many times
        got = self._cache.get((npz_path, entry))
        if got is None:
            if npz_path not in self._npz:
                self._npz[npz_path] = np.load(npz_path)
            got = self._npz[npz_path][entry]
            self._cache[(npz_path, entry)] = got
        return got

    def close(self):
        for z in self._npz.values():
            z.close()
        self._npz.clear()
        self._cache.clear()

    def read_region(self, key: str, starts: Sequence[int],
                    stops: Sequence[int], storage: str) -> np.ndarray:
        """Assemble [starts, stops) of leaf `key` in its STORAGE dtype from
        every stored shard intersecting it (shards are disjoint by
        construction, so intersections tile the region exactly)."""
        shape = tuple(b - a for a, b in zip(starts, stops))
        out = np.empty(shape, np.dtype(storage))
        filled = 0
        for npz_path, entry, s_start, s_stop in self.by_key.get(key, ()):
            lo = [max(a, sa) for a, sa in zip(starts, s_start)]
            hi = [min(b, sb) for b, sb in zip(stops, s_stop)]
            if any(l >= h for l, h in zip(lo, hi)):
                continue
            data = self._entry(npz_path, entry)
            src = tuple(slice(l - sa, h - sa)
                        for l, h, sa in zip(lo, hi, s_start))
            dst = tuple(slice(l - a, h - a)
                        for l, h, a in zip(lo, hi, starts))
            out[dst] = data[src]
            filled += int(np.prod([h - l for l, h in zip(lo, hi)], dtype=np.int64))
        want = int(np.prod(shape, dtype=np.int64))
        if filled != want:
            raise ShardCoverageError(
                f"leaf {key!r}: stored shards cover {filled} of {want} "
                f"elements of region {list(starts)}..{list(stops)} — shard "
                f"file missing from {self.dir!r}? (saved by "
                f"{len(self.by_key.get(key, ()))} shard entries)")
        return out


def _reset_key_set(leaves: Dict[str, Any],
                   reset_prefixes: Sequence[str]) -> set:
    pref = set(reset_prefixes)
    return {k for k in leaves if key_prefix(k) in pref}


def restore_sharded(ckpt_dir: str, like, shardings=None, *,
                    node_remap: Optional[Tuple[int, int]] = None,
                    reset_prefixes: Sequence[str] = ()) -> Any:
    """Restore a sharded checkpoint into the structure of ``like``.

    like: pytree of arrays or ShapeDtypeStructs — target structure, GLOBAL
    shapes and true dtypes (validated against the manifest with typed
    errors; a ``state_dtype`` change is a dtype mismatch, not silent data
    corruption).
    shardings: matching pytree of ``jax.sharding.Sharding`` — each leaf is
    built in place under its target sharding via
    ``jax.make_array_from_callback`` (each device reads only its slice; no
    host-gather, no throwaway donor state).  None returns host numpy arrays.
    node_remap=(n_old, n_new): elastic restore — leaves saved with leading
    node dim n_old are re-mapped to n_new by the ``elastic.py`` policy
    (cyclic tile on grow, strided mean on shrink).
    reset_prefixes: top-level tree fields to zero-fill instead of read
    (x_hat / s under elastic restore: old public copies are invalid under
    the new mixing matrix W).
    """
    man = read_manifest(ckpt_dir)
    expected = tree_leaf_specs(like)
    reset_keys = _reset_key_set(expected, reset_prefixes)
    validate_tree(man.leaves, expected, node_remap=node_remap,
                  reset_keys=reset_keys, reset_prefixes=reset_prefixes)
    store = _ShardStore(ckpt_dir)
    flat_like = _flatten_with_keys(like)
    flat_shards = (dict(_flatten_with_keys(shardings))
                   if shardings is not None else {})
    out = []
    try:
        for key, leaf in flat_like:
            true_dt = np.dtype(leaf.dtype)
            shape = tuple(leaf.shape)
            # reset keys may be absent from the checkpoint entirely (an
            # engine change re-shaped the zero-filled subtree)
            spec = man.leaves.get(key)
            remap = (node_remap is not None and shape and spec is not None
                     and spec.shape != shape
                     and spec.shape[0] == node_remap[0])

            if key in reset_keys:
                def build(starts, stops, _shape=shape, _dt=true_dt):
                    return np.zeros([b - a for a, b in zip(starts, stops)],
                                    _dt)
            elif remap:
                n_old, n_new = node_remap

                def build(starts, stops, _key=key, _spec=spec, _dt=true_dt,
                          _n_old=n_old, _n_new=n_new):
                    rows = []
                    for j in range(starts[0], stops[0]):
                        srcs = source_rows(j, _n_old, _n_new)
                        reads = [store.read_region(
                            _key, [r] + list(starts[1:]),
                            [r + 1] + list(stops[1:]),
                            _spec.storage).view(_dt) for r in srcs]
                        if len(reads) == 1:
                            rows.append(reads[0])
                        else:       # strided mean, computed in f32
                            acc = np.mean([r.astype(np.float32)
                                           for r in reads], axis=0)
                            rows.append(acc.astype(_dt))
                    return np.concatenate(rows, axis=0)
            else:
                def build(starts, stops, _key=key, _spec=spec, _dt=true_dt):
                    return store.read_region(_key, starts, stops,
                                             _spec.storage).view(_dt)

            sharding = flat_shards.get(key)
            if sharding is None:
                full = build([0] * len(shape), list(shape))
                out.append(full.reshape(shape))
            else:
                def cb(index, _build=build, _shape=shape):
                    starts, stops = _slices_to_bounds(index, _shape)
                    return _build(starts, stops)
                out.append(jax.make_array_from_callback(shape, sharding, cb))
    finally:
        store.close()
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# legacy flat npz (single-host, host-gathered; kept for small trees)
# ---------------------------------------------------------------------------

def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for key, leaf in _flatten_with_keys(tree):
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":     # npz cannot store ml_dtypes
            arr = arr.astype(np.float32)     # lossless widening
        flat[key] = arr
    return flat


def save_pytree(path: str, tree, metadata: Dict[str, Any] | None = None):
    """Legacy flat format: gather the full tree to host, one .npz."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    if metadata is not None:
        with open(re.sub(r"\.npz$", "", path) + ".meta.json", "w") as f:
            json.dump(metadata, f, indent=2)


def restore_pytree(path: str, like) -> Any:
    """Restore a legacy flat npz into the structure of ``like`` (a pytree of
    arrays or ShapeDtypeStructs).

    Validation raises :class:`TreeMismatchError` enumerating every missing,
    extra, and shape-mismatched key (dtypes cannot be checked — the flat
    format widened bf16 to f32 without recording the true dtype; that is
    what the manifest of the sharded format exists for)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    flat_like = _flatten_with_keys(like)
    expected = {key: leaf for key, leaf in flat_like}
    missing = sorted(set(expected) - set(flat))
    extra = sorted(set(flat) - set(expected))
    mismatched = [(key, "shape", str(flat[key].shape),
                   str(tuple(expected[key].shape)))
                  for key in sorted(set(flat) & set(expected))
                  if flat[key].shape != tuple(expected[key].shape)]
    if missing or extra or mismatched:
        raise TreeMismatchError(missing, extra, mismatched)
    treedef = jax.tree_util.tree_structure(like)
    out = [flat[key].astype(leaf.dtype)   # restore original dtype (bf16 etc.)
           for key, leaf in flat_like]
    return jax.tree_util.tree_unflatten(treedef, out)


def load_metadata(path: str) -> Dict[str, Any]:
    """Sidecar metadata of a legacy flat .npz checkpoint (step, config);
    sharded checkpoints carry theirs in manifest.json instead."""
    with open(re.sub(r"\.npz$", "", path) + ".meta.json") as f:
        return json.load(f)
