"""Checkpointing: flat-npz pytree save/restore (no external deps).

Saves the full decentralized TrainState — including the CHOCO error-feedback
states x_hat and s, which MUST survive restarts (dropping them resets the
compression error memory and breaks the convergence guarantee of Theorem 2).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Tuple

import jax
import numpy as np


_SEP = "__"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":     # npz cannot store ml_dtypes
            arr = arr.astype(np.float32)     # lossless widening
        flat[key] = arr
    return flat


def save_pytree(path: str, tree, metadata: Dict[str, Any] | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    if metadata is not None:
        with open(re.sub(r"\.npz$", "", path) + ".meta.json", "w") as f:
            json.dump(metadata, f, indent=2)


def restore_pytree(path: str, like) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    out = []
    for p, leaf in leaves_with_path:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in p)
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        out.append(arr.astype(leaf.dtype))   # restore original dtype (bf16 etc.)
    return jax.tree_util.tree_unflatten(treedef, out)


def load_metadata(path: str) -> Dict[str, Any]:
    with open(re.sub(r"\.npz$", "", path) + ".meta.json") as f:
        return json.load(f)
