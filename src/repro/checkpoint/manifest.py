"""Checkpoint manifest: schema, typed errors, and tree validation.

A sharded checkpoint is a directory:

    <dir>/
      manifest.json             # written LAST, by process 0 — its presence
                                #   marks the checkpoint complete
      shards-p00000.npz         # process 0's addressable shards
      shards-p00000.index.json  # entry name -> (leaf key, global offsets)
      shards-p00001.npz ...     # one pair per host

``manifest.json`` records the flat tree structure (keys joined with "__",
matching the legacy flat-npz naming), per-leaf GLOBAL shape, the TRUE dtype
(``bfloat16`` — not the ``uint16`` bit-cast it is stored as), the training
fingerprint (mesh axes, gossip topology, ``gossip_steps``, ``n_nodes``,
``state_dtype``) and the step.  Restore validates the target tree against it
and raises :class:`TreeMismatchError` enumerating every missing / extra /
shape- or dtype-mismatched leaf — never a bare ``assert`` (stripped under
``python -O``) or a raw ``KeyError``.

This module is jax-free on purpose: launchers can read a manifest (to anchor
the LR schedule, pick the mesh, or decide on elastic restore) before jax is
imported and XLA_FLAGS are frozen.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

MANIFEST_NAME = "manifest.json"
FORMAT_NAME = "choco-sharded"
FORMAT_VERSION = 1

#: separator joining pytree path components into flat leaf keys — the single
#: definition shared by the writer (checkpointing._path_key) and the
#: validator's reset-prefix accounting below
FLAT_KEY_SEP = "__"


def key_prefix(key: str) -> str:
    """Top-level tree field of a flat leaf key ("x_hat__0__w" -> "x_hat")."""
    return key.split(FLAT_KEY_SEP, 1)[0]

# dtypes npz cannot serialize natively -> lossless bit-cast storage dtype
STORAGE_DTYPES = {"bfloat16": "uint16"}


class CheckpointError(Exception):
    """Base for every checkpoint-layer failure."""


class ManifestError(CheckpointError):
    """Missing, unreadable, or incompatible manifest.json."""


class TreeMismatchError(CheckpointError):
    """Checkpoint tree does not match the restore target.

    Carries the full enumeration so one failed restore reports every
    problem at once instead of dying on the first key.
    """

    def __init__(self, missing: Sequence[str], extra: Sequence[str],
                 mismatched: Sequence[Tuple[str, str, str, str]]):
        self.missing = tuple(missing)      # keys absent from the checkpoint
        self.extra = tuple(extra)          # checkpoint keys the target lacks
        self.mismatched = tuple(mismatched)  # (key, field, saved, expected)
        lines = []
        if self.missing:
            lines.append("missing from checkpoint: " + ", ".join(self.missing))
        if self.extra:
            lines.append("extra in checkpoint: " + ", ".join(self.extra))
        for key, field, saved, expected in self.mismatched:
            lines.append(f"{key}: saved {field} {saved} != expected {expected}")
        super().__init__("checkpoint tree mismatch — " + "; ".join(lines))


class ShardCoverageError(CheckpointError):
    """Stored shards do not fully cover a requested leaf region (host file
    deleted, or a save from a partial set of processes)."""


class ElasticRestoreError(CheckpointError):
    """Node-count change the elastic remap policy cannot express."""


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    shape: Tuple[int, ...]
    dtype: str            # true dtype, e.g. "bfloat16"
    storage: str          # on-disk dtype, e.g. "uint16" (bit-cast)

    def to_json(self) -> Dict[str, Any]:
        return {"shape": list(self.shape), "dtype": self.dtype,
                "storage": self.storage}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "LeafSpec":
        return cls(shape=tuple(d["shape"]), dtype=d["dtype"],
                   storage=d["storage"])


@dataclasses.dataclass(frozen=True)
class Manifest:
    step: int
    leaves: Dict[str, LeafSpec]             # flat key -> leaf spec
    fingerprint: Dict[str, Any]             # mesh / topology / gossip_steps...
    metadata: Dict[str, Any]
    process_count: int = 1
    version: int = FORMAT_VERSION

    @property
    def n_nodes(self) -> Optional[int]:
        return self.fingerprint.get("n_nodes")


def storage_dtype(dtype_name: str) -> str:
    """On-disk dtype for a leaf dtype (bit-cast for npz-hostile dtypes)."""
    return STORAGE_DTYPES.get(dtype_name, dtype_name)


def manifest_path(ckpt_dir: str) -> str:
    """Path of the manifest.json inside a sharded checkpoint dir."""
    return os.path.join(ckpt_dir, MANIFEST_NAME)


def is_sharded_checkpoint(path: str) -> bool:
    """True iff path is a COMPLETE sharded checkpoint dir (the manifest is
    renamed into place last, so a torn save answers False)."""
    return os.path.isfile(manifest_path(path))


def write_manifest(ckpt_dir: str, manifest: Manifest) -> str:
    """Atomically write manifest.json (tmp + rename: a torn write must never
    look like a complete checkpoint)."""
    doc = {
        "format": FORMAT_NAME,
        "version": manifest.version,
        "step": manifest.step,
        "process_count": manifest.process_count,
        "fingerprint": manifest.fingerprint,
        "metadata": manifest.metadata,
        "leaves": {k: s.to_json() for k, s in manifest.leaves.items()},
    }
    path = manifest_path(ckpt_dir)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    os.replace(tmp, path)
    return path


def read_manifest(ckpt_dir: str) -> Manifest:
    """Parse a sharded checkpoint's manifest.json; ManifestError on a
    missing or torn (schema-invalid) manifest."""
    path = manifest_path(ckpt_dir)
    if not os.path.isfile(path):
        raise ManifestError(
            f"no {MANIFEST_NAME} under {ckpt_dir!r} — not a sharded "
            f"checkpoint (legacy flat-npz checkpoints are a single .npz "
            f"file, restored via restore_pytree)")
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise ManifestError(f"unreadable manifest {path!r}: {e}") from e
    if doc.get("format") != FORMAT_NAME:
        raise ManifestError(
            f"{path!r} has format {doc.get('format')!r}, expected "
            f"{FORMAT_NAME!r}")
    if doc.get("version", 0) > FORMAT_VERSION:
        raise ManifestError(
            f"{path!r} is version {doc['version']}, newer than this "
            f"reader's {FORMAT_VERSION}")
    return Manifest(
        step=int(doc["step"]),
        leaves={k: LeafSpec.from_json(s) for k, s in doc["leaves"].items()},
        fingerprint=doc.get("fingerprint", {}),
        metadata=doc.get("metadata", {}),
        process_count=int(doc.get("process_count", 1)),
        version=int(doc.get("version", FORMAT_VERSION)),
    )


def validate_tree(saved: Dict[str, LeafSpec],
                  expected: Dict[str, Tuple[Tuple[int, ...], str]],
                  *, node_remap: Optional[Tuple[int, int]] = None,
                  reset_keys: Sequence[str] = (),
                  reset_prefixes: Sequence[str] = ()) -> None:
    """Check the saved leaf set against the restore target's
    ``{key: (shape, dtype)}``; raise :class:`TreeMismatchError` enumerating
    every problem.

    node_remap=(n_old, n_new): an elastic restore — leaves whose saved shape
    is ``(n_old, *rest)`` where the target expects ``(n_new, *rest)`` are
    accepted (the restore remaps the leading node dim).
    reset_keys: flat keys the restore will zero-fill instead of read (the
    CHOCO x_hat / s states under elastic restore); their node extent and
    dtype are not compared.
    reset_prefixes: top-level tree fields being reset — keys under them are
    also exempt from missing/extra accounting, because a gossip-engine
    change can legitimately re-shape those subtrees (e.g. a topology
    process turns the single x_hat tree into a per-round reference list);
    the restore zero-fills the TARGET structure without reading any of the
    saved bytes, so structural drift there is not a mismatch.
    """
    pref = set(reset_prefixes)
    under_reset = lambda key: key_prefix(key) in pref
    missing = sorted(k for k in set(expected) - set(saved)
                     if not under_reset(k))
    extra = sorted(k for k in set(saved) - set(expected)
                   if not under_reset(k))
    mismatched: List[Tuple[str, str, str, str]] = []
    reset = set(reset_keys)
    for key in sorted(set(saved) & set(expected)):
        spec = saved[key]
        shape, dtype = expected[key]
        shape_ok = spec.shape == tuple(shape)
        if not shape_ok and node_remap is not None and spec.shape and shape:
            n_old, n_new = node_remap
            shape_ok = (spec.shape[0] == n_old and shape[0] == n_new
                        and spec.shape[1:] == tuple(shape[1:]))
        if not shape_ok and key in reset and spec.shape and shape:
            shape_ok = spec.shape[1:] == tuple(shape[1:])
        if not shape_ok:
            mismatched.append((key, "shape", str(spec.shape),
                               str(tuple(shape))))
        # reset keys are zero-filled in the TARGET dtype without reading the
        # saved bytes, so a state_dtype change there is not a mismatch
        if spec.dtype != dtype and key not in reset:
            mismatched.append((key, "dtype", spec.dtype, dtype))
    if missing or extra or mismatched:
        raise TreeMismatchError(missing, extra, mismatched)
