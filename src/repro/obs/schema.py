"""The metric registry: every key a sink may carry, with units and
meaning.

Mirrors the ``FINGERPRINT_EXEMPT`` pattern: the registry is a literal
data structure, linted statically (``analysis/metrics_lint.py`` parses
this file's AST) so an emitted-but-unregistered key or a stale registry
entry is a CI finding, and validated dynamically (:func:`validate_record`)
so a malformed record dies at the emit site, not in a downstream parser.

Record shape (one JSON object per line in the JSONL sink):

* ``kind="header"`` — one per run: config fingerprint, jax version, mesh,
  resolved gamma, per-bucket wire/gamma telemetry.  Free-form payload
  (validated for the reserved keys only).
* ``kind="metrics"`` — ``step`` plus registered metric keys; unregistered
  keys are rejected.  Host-only annotations ride in the reserved
  ``extra`` dict, outside the schema.
* ``kind="log"`` — a plain ``msg`` string (the stdout sink renders it
  verbatim, which is how the launchers route their historical prints
  through the sink without changing the line format).

Module is jax-free at import: the launchers import it pre-XLA_FLAGS.
"""
from __future__ import annotations

import re
from typing import Dict, NamedTuple, Tuple

#: keys with reserved meaning in every record; never metric names
RESERVED_KEYS = ("kind", "step", "msg", "extra")

#: metric names are namespaced ``<subsystem>/<snake_case>``
METRIC_KEY_RE = re.compile(r"^[a-z]+/[a-z0-9_]+$")


class MetricSpec(NamedTuple):
    """One registered metric: wire name, units, one-line meaning."""

    name: str
    units: str
    description: str


#: The registry.  Kept a pure literal — ``analysis/metrics_lint.py``
#: parses it from the AST without importing this module.
METRIC_SPECS: Tuple[MetricSpec, ...] = (
    # -- training loop (launch/train.py) -----------------------------------
    MetricSpec("train/loss", "nats",
               "mean per-node LM loss of the step's batch"),
    MetricSpec("train/lr", "1", "learning rate at the step"),
    MetricSpec("train/grad_norm", "1",
               "global l2 norm of the per-node gradients"),
    MetricSpec("train/compile_s", "s",
               "wall time of the first (compiling) train step, reported "
               "once so the steady-state s/step is not skewed by it"),
    MetricSpec("train/s_per_step", "s",
               "post-warmup seconds per train step between taps "
               "(block_until_ready on tap steps only)"),
    # -- in-graph Lyapunov / consensus diagnostics (obs/metrics.py) --------
    MetricSpec("diag/consensus_dist", "1",
               "consensus distance sum_i ||x_i - xbar||^2 over all "
               "parameter leaves"),
    MetricSpec("diag/ef_residual", "1",
               "error-feedback residual sum_i ||x_i - x_hat_i||^2 "
               "(replica-averaged under process/staleness engines)"),
    MetricSpec("diag/lyapunov", "1",
               "Theorem-2 Lyapunov Xi_t = consensus_dist + ef_residual; "
               "must contract linearly under the derived gamma"),
    MetricSpec("diag/compress_err", "1",
               "measured ||Q(d) - d||^2 / ||d||^2 on the current "
               "x - x_hat deltas (one compression sample per leaf)"),
    MetricSpec("diag/compress_err_bound", "1",
               "Assumption-1 bound 1 - omega the measured compression "
               "error must stay under (in expectation)"),
    MetricSpec("diag/psw_spread", "1",
               "push-sum weight spread max_i w_i / min_i w_i (1.0 at "
               "perfect mixing; push-sum mode only)"),
    MetricSpec("diag/gamma", "1",
               "resolved worst-bucket Theorem-2 consensus stepsize"),
    MetricSpec("diag/wire_bytes_round", "bytes",
               "analytic compressed payload bytes one node ships per "
               "gossip round (all buckets)"),
    MetricSpec("diag/node_loss_spread", "1",
               "max_i loss_i - min_i loss_i across the per-node training "
               "losses this step — divergence under data skew made "
               "observable"),
    MetricSpec("diag/data_skew_tv", "1",
               "mean total-variation distance of the per-node sampling "
               "distributions from their average (0 = IID; constant per "
               "run, from the data pipeline's Dirichlet/heterogeneity "
               "settings)"),
    # -- serving latency (launch/serve.py) ---------------------------------
    MetricSpec("serve/ttft_p50_s", "s",
               "median time-to-first-token across requests (prefill + "
               "first decode, blocked on the token)"),
    MetricSpec("serve/ttft_p99_s", "s",
               "p99 time-to-first-token across requests"),
    MetricSpec("serve/tok_p50_s", "s",
               "median per-token decode latency across generated tokens"),
    MetricSpec("serve/tok_p99_s", "s",
               "p99 per-token decode latency across generated tokens"),
    MetricSpec("serve/throughput_tok_s", "tok/s",
               "aggregate generated tokens per second over the run"),
    # -- dry-run compile audit (launch/dryrun.py) --------------------------
    MetricSpec("dryrun/compile_s", "s",
               "phase-A compile wall time of one arch x shape combo"),
    MetricSpec("dryrun/total_s", "s",
               "total wall time of one arch x shape combo (compile + "
               "roofline extrapolation)"),
)

#: name -> spec lookup
METRICS: Dict[str, MetricSpec] = {m.name: m for m in METRIC_SPECS}


def validate_record(record: dict) -> dict:
    """Validate one record against the registry; returns it unchanged.

    ``header``/``log`` records are free-form (reserved keys checked);
    ``metrics`` records must carry an integer-like ``step`` and only
    registered metric keys with scalar values.  Raises ``ValueError`` so a
    bad emit fails at the call site.
    """
    kind = record.get("kind")
    if kind not in ("header", "metrics", "log"):
        raise ValueError(f"record kind must be header|metrics|log, got "
                         f"{kind!r}")
    if kind != "metrics":
        return record
    step = record.get("step")
    if not isinstance(step, int) or isinstance(step, bool):
        raise ValueError(f"metrics record needs an int step, got {step!r}")
    for key, value in record.items():
        if key in RESERVED_KEYS:
            continue
        if key not in METRICS:
            raise ValueError(
                f"unregistered metric key {key!r}: add a MetricSpec "
                f"(name, units, description) to obs/schema.py — the "
                f"metrics lint enforces the registry statically too")
        if not isinstance(value, (int, float)) or isinstance(value, bool):
            raise ValueError(f"metric {key!r} must be a scalar number, "
                             f"got {type(value).__name__}")
    return record
