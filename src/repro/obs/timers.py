"""Async-dispatch-aware step timing.

A jitted train-step call returns as soon as the work is *dispatched* —
wrapping it in ``time.perf_counter()`` measures Python overhead, not the
step.  Honest timing therefore needs a device barrier, but blocking every
step would serialize the dispatch pipeline the engines are built to keep
full.  :class:`StepTimer` resolves the tension the way profilers do: the
caller blocks **only on tap steps** (every k-th report line), and the
timer amortizes the wall time over the steps dispatched since the last
tap.  The first (compiling) step is marked separately so the reported
steady-state s/step is never skewed by compile time — the bug this class
replaced in ``launch/train.py`` averaged compile into every line of the
run.

Host-side by design (wall clocks are its whole job): on the traced-purity
exemption list, jax-free at import.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Sequence


class StepTimer:
    """Compile-aware tap timer for an async-dispatched step loop.

    Protocol::

        timer = StepTimer()
        timer.start()
        for i in range(steps):
            state = step_fn(state, batch)          # async dispatch
            if i == 0:
                compile_s = timer.mark_compile(blocker)   # block once
            elif tap_step(i):
                s_per_step = timer.tap(i, blocker)        # block on taps

    ``blocker`` is any callable that synchronizes the device (e.g.
    ``lambda: jax.block_until_ready(state)``); injecting it keeps this
    module jax-free.  ``tap`` returns the post-warmup seconds/step since
    the previous tap (compile excluded by construction), or ``None``
    before any post-compile step has completed.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._mark: Optional[float] = None
        self._mark_step = 0
        self.compile_s: Optional[float] = None

    def start(self) -> None:
        """Start the run clock (call immediately before the first step)."""
        self._mark = self._clock()
        self._mark_step = 0

    def mark_compile(self, blocker: Callable[[], None]) -> float:
        """Block after the first step; records and returns its wall time
        (compile + one execute) and re-bases the tap clock so steady-state
        taps never include it."""
        if self._mark is None:
            raise ValueError("start() must precede mark_compile()")
        blocker()
        now = self._clock()
        self.compile_s = now - self._mark
        self._mark, self._mark_step = now, 1
        return self.compile_s

    def tap(self, step_index: int, blocker: Callable[[], None]
            ) -> Optional[float]:
        """Block, then return mean seconds/step over the steps dispatched
        since the last tap (or since compile).  ``step_index`` counts
        completed steps, 0-based like the loop variable."""
        if self._mark is None:
            raise ValueError("start() must precede tap()")
        done = step_index + 1
        if done <= self._mark_step:
            return None
        blocker()
        now = self._clock()
        per_step = (now - self._mark) / (done - self._mark_step)
        self._mark, self._mark_step = now, done
        return per_step


def percentile(values: Sequence[float], p: float) -> float:
    """Nearest-rank percentile (p in [0, 100]) without numpy — the serve
    launcher computes p50/p99 latencies pre-jax."""
    if not values:
        raise ValueError("percentile of an empty sequence")
    if not 0.0 <= p <= 100.0:
        raise ValueError(f"percentile p must be in [0, 100], got {p}")
    ordered: List[float] = sorted(float(v) for v in values)
    rank = max(1, -(-int(p * len(ordered)) // 100))  # ceil(p*n/100), >= 1
    return ordered[rank - 1]
