"""Runtime telemetry subsystem: registered-schema metrics, structured
sinks, async-dispatch-aware timers, profiler hooks, and the in-graph
Lyapunov/consensus diagnostics.

Layering contract (enforced by ``analysis/source_lint.py``):

* ``schema.py``, ``sinks.py``, ``timers.py``, ``trace.py`` are jax-free at
  import — the launchers import them before XLA_FLAGS is frozen — and are
  the only obs modules allowed host-side wall clocks / file I/O;
* ``metrics.py`` is traced code (it builds the jitted diagnostics
  function) and is held to the same purity contract as ``comm``/``core``;
* nothing in ``comm``/``core``/``train`` imports obs — the trainer's
  ``jitted_diagnostics`` pulls ``obs.metrics`` in lazily, so the fast-path
  train step's compiled HLO stays byte-identical when telemetry is off
  (asserted by ``benchmarks/bench_telemetry.py`` + the
  ``telemetry_off`` invariant row).
"""
