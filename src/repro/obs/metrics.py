"""In-graph Lyapunov / consensus diagnostics.

Computes the paper's quantities on the live TrainState, every
``--diag-every`` steps:

* consensus distance ``sum_i ||x_i - xbar||^2`` (the curve of Figs 2-3),
* error-feedback residual ``sum_i ||x_i - x_hat_i||^2`` — replica-aware:
  a matching process keeps R per-round reference trees (averaged), the
  bounded-staleness engine keeps [public copy + tau ring] (the public
  copy is the residual's x_hat),
* their sum Xi_t, the Theorem-2 Lyapunov that must contract linearly,
* a measured compression-error sample vs the Assumption-1 bound
  ``1 - omega``,
* the push-sum weight spread ``max w / min w``.

The diagnostics are a **separate** jitted function — the fast-path train
step is never touched, so with telemetry off the compiled train-step HLO
is byte-identical to the pre-telemetry build (``telemetry_off``
invariant, ``benchmarks/bench_telemetry.py``).  This module is traced
code: it lives under the same purity contract as ``comm``/``core`` (no
wall clocks, no host RNG, no file I/O) — host-side emission lives in
``obs/sinks.py``.
"""
from __future__ import annotations

from typing import Callable, List

import jax
import jax.numpy as jnp


def _sq(x) -> jax.Array:
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def _consensus_distance(params) -> jax.Array:
    """sum_i ||x_i - xbar||^2 over every leaf (node dim leading)."""
    total = jnp.zeros((), jnp.float32)
    for leaf in jax.tree.leaves(params):
        x = leaf.astype(jnp.float32)
        total = total + _sq(x - jnp.mean(x, axis=0, keepdims=True))
    return total


def _residual(params, hat_tree) -> jax.Array:
    """sum_i ||x_i - x_hat_i||^2 for one reference tree."""
    total = jnp.zeros((), jnp.float32)
    for x, h in zip(jax.tree.leaves(params), jax.tree.leaves(hat_tree)):
        total = total + _sq(x.astype(jnp.float32) - h.astype(jnp.float32))
    return total


def _ef_trees(trainer, x_hat) -> List:
    """Reference trees the EF residual averages over, engine-aware."""
    if not isinstance(x_hat, (list, tuple)):
        return [x_hat]
    if trainer.process is not None and trainer.process.kind == "staleness":
        return [x_hat[0]]   # [public copy + tau ring]: the copy is x_hat
    return list(x_hat)      # matching: R per-round references


def _compression_error(compressor, key, params, hat_tree):
    """One measured sample of ||Q(d) - d||^2 / ||d||^2 on the current
    deltas d = x - x_hat (per node, per leaf — the quantity Assumption 1
    bounds by 1 - omega in expectation)."""
    num = jnp.zeros((), jnp.float32)
    den = jnp.zeros((), jnp.float32)
    leaves = list(zip(jax.tree.leaves(params), jax.tree.leaves(hat_tree)))
    for idx, (x, h) in enumerate(leaves):
        d = (x.astype(jnp.float32) - h.astype(jnp.float32))
        d = d.reshape(d.shape[0], -1)          # (n_nodes, leaf)
        if compressor.stochastic:
            keys = jax.random.split(jax.random.fold_in(key, idx), d.shape[0])
            q = jax.vmap(compressor.apply)(keys, d)
        else:
            q = jax.vmap(lambda row: compressor.apply(None, row))(d)
        num = num + _sq(q - d)
        den = den + _sq(d)
    return num / jnp.maximum(den, jnp.float32(1e-30))


def make_diagnostics_fn(trainer) -> Callable:
    """Build the (unjitted) diagnostics function ``state -> {metric key:
    f32 scalar}`` for one trainer.  Keys are registry names
    (``obs/schema.py``); modes without error-feedback state (plain /
    allreduce) emit the consensus distance only."""
    ef = trainer.mode in ("choco", "pushsum")
    compressor = trainer.compressor
    bound = (1.0 - trainer._worst_omega()) if compressor is not None else None

    def diagnostics(state) -> dict:
        out = {"diag/consensus_dist": _consensus_distance(state.params)}
        if ef:
            trees = _ef_trees(trainer, state.x_hat)
            res = sum(_residual(state.params, t) for t in trees) / len(trees)
            out["diag/ef_residual"] = res
            out["diag/lyapunov"] = out["diag/consensus_dist"] + res
            # same key derivation as the exchange, salted so the measured
            # sample never replays a payload draw
            key = jax.random.fold_in(
                jax.random.fold_in(state.key, state.step), 0xD1A6)
            out["diag/compress_err"] = _compression_error(
                compressor, key, state.params, trees[0])
            out["diag/compress_err_bound"] = jnp.float32(bound)
        if state.psw is not None:
            w = state.psw.astype(jnp.float32)
            out["diag/psw_spread"] = jnp.max(w) / jnp.maximum(
                jnp.min(w), jnp.float32(1e-30))
        return out

    return diagnostics


def jitted_diagnostics(trainer, state_shape):
    """Jit the diagnostics under the trainer's state shardings — a
    SEPARATE executable from the train step (the fast path never pays for
    it, compiled or not).  Returns ``fn(state) -> {key: scalar array}``."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    specs = trainer.state_pspecs(state_shape)
    shard = jax.tree.map(lambda s: NamedSharding(trainer.mesh, s), specs,
                         is_leaf=lambda x: isinstance(x, P))
    return jax.jit(make_diagnostics_fn(trainer), in_shardings=(shard,))


def bucket_telemetry(trainer) -> dict:
    """Host-side static telemetry for the run header: per-bucket wire
    bytes and effective Theorem-2 gamma of the packed exchange (empty
    bucket list for per-leaf / uncompressed modes)."""
    out = {"gamma": float(trainer.gamma), "wire_bytes_round": 0,
           "buckets": []}
    if trainer.compressor is None:
        return out
    spec = trainer._bucket_spec()
    if spec is None:    # legacy per-leaf engine: representative-d analytics
        out["wire_bytes_round"] = int(
            trainer.compressor.wire_bits(1 << 20)) // 8
        return out
    from repro.comm.packing import bucket_omegas, bucket_wire_bits
    omegas = bucket_omegas(spec, trainer.compressor)
    bits = bucket_wire_bits(spec, trainer.compressor)
    for b, omega, wb in zip(spec.buckets, omegas, bits):
        gamma = (trainer.gamma_spec.value(omega)
                 if trainer.gamma_spec is not None else trainer.gamma)
        out["buckets"].append({
            "index": int(b.index), "elems": int(b.logical),
            "exact": bool(b.exact), "omega": float(omega),
            "gamma": float(gamma), "wire_bytes": int(wb) // 8})
    out["wire_bytes_round"] = sum(e["wire_bytes"]
                                  for e in out["buckets"])
    return out

