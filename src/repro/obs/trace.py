"""Profiler hooks: phase annotations and TensorBoard trace capture.

Two layers, both jax-free at import (jax is pulled in lazily so the
launchers can import this module before XLA_FLAGS is frozen):

* :func:`annotate` — host-side ``jax.profiler.TraceAnnotation`` context
  manager around launcher phases (dispatch, checkpoint save, decode
  request); a no-op string context when profiling machinery is absent.
* :class:`ProfileSession` — drives ``jax.profiler.start_trace`` /
  ``stop_trace`` over a step window (``--profile-dir`` +
  ``--profile-steps``), skipping the compiling first step so the trace
  shows steady state, and emitting a TensorBoard-loadable trace dir.

In-graph phase names (exchange/grad/optimizer) come from
``jax.named_scope`` inside the trainer and are only enabled under
``phase_scopes=True`` — named scopes land in HLO metadata, so the default
path keeps the compiled train step byte-identical to the pre-telemetry
HLO (the ``telemetry_off`` invariant).
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Optional


@contextlib.contextmanager
def annotate(name: str) -> Iterator[None]:
    """Host-side profiler annotation: wraps the block in a
    ``jax.profiler.TraceAnnotation`` so it shows as a named span in a
    captured trace; degrades to a no-op if the profiler is unavailable."""
    try:
        from jax.profiler import TraceAnnotation
    except Exception:   # pragma: no cover - profiler-less builds
        yield
        return
    with TraceAnnotation(name):
        yield


class ProfileSession:
    """Trace a window of steps into a TensorBoard-loadable directory.

    ``maybe_start(i)`` / ``maybe_stop(i)`` bracket the loop body:
    tracing starts before step ``start_step`` (default 1 — skip the
    compiling step 0) and stops after ``n_steps`` traced steps, with the
    caller expected to synchronize the device before ``maybe_stop`` so
    the trace covers real execution, not just dispatch.  A ``None``
    profile dir makes every method a no-op, so the launcher loop carries
    no conditionals.
    """

    def __init__(self, profile_dir: Optional[str], n_steps: int = 3,
                 start_step: int = 1):
        if profile_dir is not None and n_steps < 1:
            raise ValueError(f"need n_steps >= 1, got {n_steps}")
        self.profile_dir = profile_dir
        self.start_step = int(start_step)
        self.stop_after = int(start_step) + int(n_steps)
        self.active = False
        self.done = False

    def maybe_start(self, step_index: int) -> bool:
        """Start tracing when the window opens; returns True on start."""
        if (self.profile_dir is None or self.active or self.done
                or step_index != self.start_step):
            return False
        import jax
        jax.profiler.start_trace(self.profile_dir)
        self.active = True
        return True

    def maybe_stop(self, step_index: int) -> bool:
        """Stop tracing when the window closes (caller has synchronized);
        returns True on stop."""
        if not self.active or step_index + 1 < self.stop_after:
            return False
        import jax
        jax.profiler.stop_trace()
        self.active, self.done = False, True
        return True

    def close(self) -> None:
        """Stop an in-flight trace (loop ended inside the window)."""
        if self.active:
            import jax
            jax.profiler.stop_trace()
            self.active, self.done = False, True
