"""Structured metric sinks: registered-schema records to JSONL / CSV /
stdout, written off the hot loop by a background thread.

Design (docs/ARCHITECTURE.md §Observability):

* records are plain dicts validated against ``obs/schema.py`` **on the
  emitting thread** — a typo'd key raises at the call site, never inside
  the writer thread;
* the writer thread owns all file/stdout I/O, so a tap-step emit costs
  one queue put (the training loop never blocks on a disk flush);
* the stdout sink takes a formatter so the launchers keep their
  historical line formats byte-for-byte while still flowing through the
  sink (``kind="log"`` records render their ``msg`` verbatim);
* :class:`DivergenceMonitor` watches the logged Lyapunov series Xi_t and
  warns/aborts when it stops contracting — the runtime counterpart of
  the Theorem-2 linear-contraction test in ``tests/test_obs.py``.

Module is jax-free at import (launchers import it pre-XLA_FLAGS) and is
host-side by design: it is on the traced-purity exemption list, unlike
``obs/metrics.py``.
"""
from __future__ import annotations

import csv
import json
import os
import queue
import threading
from typing import Callable, List, Optional, Sequence

from repro.obs.schema import METRIC_SPECS, validate_record


class Sink:
    """Destination for validated records; subclasses own one output."""

    def write(self, record: dict) -> None:
        """Consume one validated record."""
        raise NotImplementedError

    def close(self) -> None:
        """Flush and release the output (idempotent)."""


class StdoutSink(Sink):
    """Print records to stdout through a caller-supplied formatter.

    ``formatter(record)`` returns the line to print, or ``None`` to skip
    the record on stdout (e.g. the train launcher prints step lines and
    log lines but keeps header records file-only).  Default formatter:
    ``msg`` verbatim for log records, compact JSON otherwise.
    """

    def __init__(self, formatter: Optional[Callable[[dict],
                                                    Optional[str]]] = None):
        self._format = formatter or self._default

    @staticmethod
    def _default(record: dict) -> str:
        if record.get("kind") == "log":
            return str(record.get("msg", ""))
        return json.dumps(record, sort_keys=True)

    def write(self, record: dict) -> None:
        """Format and print one record (flushes: lines must interleave
        correctly with subprocess capture)."""
        line = self._format(record)
        if line is not None:
            print(line, flush=True)


class JsonlSink(Sink):
    """One JSON object per line; the machine-readable run log."""

    def __init__(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "a", encoding="utf-8")

    def write(self, record: dict) -> None:
        """Append one record as a JSON line."""
        self._f.write(json.dumps(record, sort_keys=True) + "\n")

    def close(self) -> None:
        """Flush and close the file (idempotent)."""
        if not self._f.closed:
            self._f.flush()
            self._f.close()


class CsvSink(Sink):
    """Fixed-column CSV: ``kind, step`` plus every registered metric in
    registry order — blank cells for metrics a record does not carry, so
    the header never depends on emission order."""

    def __init__(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._cols = ["kind", "step"] + [m.name for m in METRIC_SPECS]
        self._f = open(path, "a", encoding="utf-8", newline="")
        self._w = csv.writer(self._f)
        if self._f.tell() == 0:
            self._w.writerow(self._cols)

    def write(self, record: dict) -> None:
        """Append one row (metrics records only — header/log records have
        no tabular shape)."""
        if record.get("kind") != "metrics":
            return
        self._w.writerow([record.get(c, "") for c in self._cols])

    def close(self) -> None:
        """Flush and close the file (idempotent)."""
        if not self._f.closed:
            self._f.flush()
            self._f.close()


class MetricLog:
    """Validating front end + non-blocking background writer for a set of
    sinks.

    ``emit``/``header``/``log`` validate on the calling thread, then hand
    the record to a daemon writer thread; ``close()`` drains the queue and
    closes every sink.  Usable as a context manager.
    """

    def __init__(self, sinks: Sequence[Sink]):
        self._sinks: List[Sink] = list(sinks)
        self._q: "queue.Queue[Optional[dict]]" = queue.Queue()
        self._thread = threading.Thread(target=self._drain, daemon=True,
                                        name="obs-metric-writer")
        self._thread.start()
        self._closed = False

    def _drain(self) -> None:
        while True:
            record = self._q.get()
            if record is None:
                return
            for sink in self._sinks:
                sink.write(record)

    def write(self, record: dict) -> None:
        """Validate and enqueue one raw record."""
        if self._closed:
            raise ValueError("MetricLog is closed")
        self._q.put(validate_record(dict(record)))

    def header(self, **fields) -> None:
        """Emit the run-header record (config fingerprint, jax version,
        mesh, resolved gamma, ...)."""
        self.write({"kind": "header", **fields})

    def emit(self, step: int, metrics: dict,
             extra: Optional[dict] = None) -> None:
        """Emit one metrics record at ``step``; unregistered keys raise
        here, at the call site."""
        record = {"kind": "metrics", "step": int(step), **metrics}
        if extra:
            record["extra"] = extra
        self.write(record)

    def log(self, msg: str) -> None:
        """Emit a log record (rendered verbatim by the stdout sink)."""
        self.write({"kind": "log", "msg": msg})

    def close(self) -> None:
        """Drain the queue, stop the writer, close every sink."""
        if self._closed:
            return
        self._closed = True
        self._q.put(None)
        self._thread.join(timeout=30.0)
        for sink in self._sinks:
            sink.close()

    def __enter__(self) -> "MetricLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class DivergenceMonitor:
    """Trips when the Lyapunov series Xi_t stops contracting.

    Theorem 2 guarantees E[Xi_{t+1}] <= (1 - delta^2 omega / 82) Xi_t
    under the derived gamma, so a healthy run keeps making new bests and
    any excursion above ``tolerance * best`` is transient.  The monitor
    trips when Xi exceeds that band for ``patience`` consecutive
    observations — wobble at the numerical convergence floor stays inside
    the band and never false-positives.  ``update`` returns the warning
    string once, at the trip; ``tripped`` stays set so the caller decides
    warn-vs-abort.
    """

    def __init__(self, tolerance: float = 1.05, patience: int = 3):
        if tolerance < 1.0 or patience < 1:
            raise ValueError(f"need tolerance >= 1 and patience >= 1, got "
                             f"{tolerance}, {patience}")
        self.tolerance = float(tolerance)
        self.patience = int(patience)
        self.best: Optional[float] = None
        self.streak = 0
        self.tripped = False

    def update(self, step: int, xi: float) -> Optional[str]:
        """Observe Xi at ``step``; returns the trip message, or None."""
        xi = float(xi)
        if self.best is None or xi < self.best:
            self.best, self.streak = xi, 0
            return None
        if xi <= self.tolerance * self.best:
            self.streak = 0          # contracting-enough band: not a sign
            return None
        self.streak += 1
        if self.streak < self.patience or self.tripped:
            return None
        self.tripped = True
        return (f"divergence monitor tripped at step {step}: Lyapunov "
                f"Xi = {xi:.3e} has stayed above {self.tolerance:g}x the "
                f"best {self.best:.3e} for {self.streak} consecutive "
                f"observations — Theorem 2 demands linear contraction "
                f"under the derived gamma; check for an overscaled "
                f"--consensus-gamma or a mis-tuned compressor")
