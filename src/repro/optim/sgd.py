"""Optimizers for decentralized training.

CHOCO-SGD's local half-step is plain SGD in the paper (Algorithm 2, line 3).
We also provide momentum-SGD and AdamW as optional local optimizers (the
error-feedback analysis of Assumption 3 is agnostic to how x^{t+1/2} is
produced from x^t), plus the paper's decaying schedules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    mu: Any          # first moment (momentum / Adam m); empty tree for plain SGD
    nu: Any          # second moment (Adam only); empty tree otherwise
    count: jax.Array


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], OptState]
    update: Callable[[Any, Any, OptState, jax.Array], Tuple[Any, OptState]]
    # update(params, grads, state, lr) -> (new_params_half_step, new_state)


def sgd(weight_decay: float = 0.0) -> Optimizer:
    """Plain SGD (stateless apart from the step count)."""
    def init(params):
        return OptState(mu=None, nu=None, count=jnp.zeros((), jnp.int32))

    def update(params, grads, state, lr):
        def upd(p, g):
            g = g + weight_decay * p if weight_decay else g
            return p - lr * g.astype(p.dtype)
        return jax.tree.map(upd, params, grads), state._replace(count=state.count + 1)

    return Optimizer("sgd", init, update)


def momentum_sgd(beta: float = 0.9, weight_decay: float = 0.0,
                 nesterov: bool = False) -> Optimizer:
    """Heavy-ball (optionally Nesterov) momentum SGD."""
    def init(params):
        return OptState(mu=jax.tree.map(jnp.zeros_like, params), nu=None,
                        count=jnp.zeros((), jnp.int32))

    def update(params, grads, state, lr):
        def mom(m, g):
            return beta * m + g
        mu = jax.tree.map(mom, state.mu, grads)

        def upd(p, g, m):
            d = g + beta * m if nesterov else m
            d = d + weight_decay * p if weight_decay else d
            return p - lr * d.astype(p.dtype)
        new_params = jax.tree.map(upd, params, grads, mu)
        return new_params, OptState(mu=mu, nu=None, count=state.count + 1)

    return Optimizer("momentum", init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    """AdamW with f32 moments and bias correction."""
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(mu=z, nu=jax.tree.map(jnp.copy, z),
                        count=jnp.zeros((), jnp.int32))

    def update(params, grads, state, lr):
        c = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(p, m, v):
            d = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            d = d + weight_decay * p.astype(jnp.float32) if weight_decay else d
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype)
        return jax.tree.map(upd, params, mu, nu), OptState(mu=mu, nu=nu, count=c)

    return Optimizer("adamw", init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    """Optimizer factory by name: sgd | momentum | adamw."""
    return {"sgd": sgd, "momentum": momentum_sgd, "adamw": adamw}[name](**kw)


# -- schedules ---------------------------------------------------------------

def paper_decay_schedule(m: int, a: float, b: float):
    """eta_t = m a / (t + b)   (paper §5.3, Table 4)."""
    def lr(t):
        return m * a / (t.astype(jnp.float32) + b)
    return lr


def constant_schedule(lr0: float):
    """Constant learning rate."""
    def lr(t):
        return jnp.float32(lr0)
    return lr


def cosine_schedule(lr0: float, warmup: int, total: int):
    """Linear warmup then cosine decay to zero over ``total`` steps."""
    def lr(t):
        t = t.astype(jnp.float32)
        warm = lr0 * t / max(warmup, 1)
        prog = jnp.clip((t - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = lr0 * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(t < warmup, warm, cos)
    return lr
