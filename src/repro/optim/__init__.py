"""Per-node optimizers and LR schedules for the decentralized trainer."""
from .sgd import (Optimizer, OptState, sgd, momentum_sgd, adamw, make_optimizer,
                  paper_decay_schedule, constant_schedule, cosine_schedule)
