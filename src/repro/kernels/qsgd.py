"""Pallas TPU kernel: qsgd_s stochastic quantization (paper §3.5).

    q = sign(x) * floor(s |x| / ||x|| + xi),   xi ~ U[0,1)^d
    dequant(q) = q * ||x|| / (s * tau)

The global norm is a cheap jnp reduction computed once on the UNPADDED
buffer by the caller (so the pallas path shares the exact reduction
order with the jnp path); the kernel does the bandwidth-bound
elementwise pass HBM->VMEM->HBM in (8, 128)-aligned tiles, emitting
int8 codes for s <= 127 and int16 above — the same wire format as
``comm/packing.py::compress_bucket``.  No clip is needed: |x| <= ||x||
bounds every level by s.  The uniform noise is passed in as an input so
the pure-jnp oracle (ref.py) matches bit-exactly; a TPU-native variant
would fuse pltpu.prng_random_bits instead.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8
LANES = 128


def _quant_kernel(x_ref, xi_ref, inv_norm_ref, s_ref, out_ref, *, ctype):
    x = x_ref[...]
    xi = xi_ref[...]
    inv_norm = inv_norm_ref[0]
    s = s_ref[0]
    level = jnp.floor(jnp.abs(x) * inv_norm * s + xi)
    out_ref[...] = (jnp.sign(x) * level).astype(ctype)


def _sign_kernel(x_ref, out_ref):
    out_ref[...] = jnp.sign(x_ref[...]).astype(jnp.int8)


def _dequant_kernel(codes_ref, scale_ref, out_ref):
    out_ref[...] = codes_ref[...].astype(jnp.float32) * scale_ref[0]


def code_dtype(s: int):
    """Wire code dtype for s quantization levels (int8 up to 127)."""
    return jnp.int8 if s <= 127 else jnp.int16


@functools.partial(jax.jit, static_argnames=("s", "interpret", "block_rows"))
def qsgd_quantize_codes(x, xi, inv_norm, s: int, *, interpret: bool = True,
                        block_rows: int = BLOCK_ROWS):
    """Fused quantize pass: the elementwise half of qsgd, codes only.

    x, xi: (R, 128) f32 tiles (R % block_rows == 0); inv_norm: f32
    scalar, precomputed as 1/||x|| (0 for a zero vector) by the caller.
    Returns int8/int16 codes (R, 128) per :func:`code_dtype`.
    """
    R, C = x.shape
    assert C == LANES and R % block_rows == 0, (R, C)
    grid = (R // block_rows,)
    ctype = code_dtype(s)
    return pl.pallas_call(
        functools.partial(_quant_kernel, ctype=ctype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),     # scalars broadcast to every tile
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, LANES), ctype),
        interpret=interpret,
    )(x, xi, jnp.stack([jnp.asarray(inv_norm, jnp.float32)]),
      jnp.full((1,), float(s), jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def signnorm_codes(x, *, interpret: bool = True,
                   block_rows: int = BLOCK_ROWS):
    """SignNorm wire codes: x (R, 128) f32 tiles -> int8 sign(x)."""
    R, C = x.shape
    assert C == LANES and R % block_rows == 0, (R, C)
    return pl.pallas_call(
        _sign_kernel,
        grid=(R // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, LANES), jnp.int8),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("s", "interpret", "block_rows"))
def qsgd_quantize(x, xi, s: int, *, interpret: bool = True,
                  block_rows: int = BLOCK_ROWS):
    """x, xi: (R, 128) f32 tiles (R % block_rows == 0).
    Returns (codes int8/int16 (R,128), scale f32 scalar)."""
    R, C = x.shape
    norm = jnp.sqrt(jnp.sum(jnp.square(x)))
    inv_norm = jnp.where(norm == 0, 0.0, 1.0 / norm)
    codes = qsgd_quantize_codes(x, xi, inv_norm, s, interpret=interpret,
                                block_rows=block_rows)
    d = R * C
    tau = 1.0 + min(d / (s * s), math.sqrt(d) / s)
    scale = norm / (s * tau)
    return codes, scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def qsgd_dequantize(codes, scale, *, interpret: bool = True,
                    block_rows: int = BLOCK_ROWS):
    """codes (R, 128) int8/int16, scale f32 scalar -> f32 (R, 128)."""
    R, C = codes.shape
    grid = (R // block_rows,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, LANES), jnp.float32),
        interpret=interpret,
    )(codes, jnp.stack([scale]))
