"""Pallas TPU kernel: qsgd_s stochastic quantization (paper §3.5).

    q = sign(x) * floor(s |x| / ||x|| + xi),   xi ~ U[0,1)^d
    dequant(q) = q * ||x|| / (s * tau)

The global norm is a cheap jnp reduction; the kernel does the bandwidth-bound
elementwise pass HBM->VMEM->HBM in (8, 128)-aligned tiles, emitting int8
codes (s <= 127).  The uniform noise is passed in as an input so the pure-jnp
oracle (ref.py) matches bit-exactly; a TPU-native variant would fuse
pltpu.prng_random_bits instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8
LANES = 128


def _quant_kernel(x_ref, xi_ref, inv_norm_ref, s_ref, out_ref):
    x = x_ref[...]
    xi = xi_ref[...]
    inv_norm = inv_norm_ref[0]
    s = s_ref[0]
    mag = jnp.abs(x) * inv_norm * s
    level = jnp.floor(mag + xi)
    level = jnp.clip(level, 0.0, 127.0)
    out_ref[...] = (jnp.sign(x) * level).astype(jnp.int8)


def _dequant_kernel(codes_ref, scale_ref, out_ref):
    out_ref[...] = codes_ref[...].astype(jnp.float32) * scale_ref[0]


@functools.partial(jax.jit, static_argnames=("s", "interpret", "block_rows"))
def qsgd_quantize(x, xi, s: int, *, interpret: bool = True,
                  block_rows: int = BLOCK_ROWS):
    """x, xi: (R, 128) f32 tiles (R % block_rows == 0).
    Returns (codes int8 (R,128), scale f32 scalar)."""
    assert s <= 127, "int8 wire format requires s <= 127"
    R, C = x.shape
    assert C == LANES and R % block_rows == 0, (R, C)
    norm = jnp.sqrt(jnp.sum(jnp.square(x)))
    inv_norm = jnp.where(norm == 0, 0.0, 1.0 / norm)
    grid = (R // block_rows,)
    codes = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),     # scalars broadcast to every tile
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, LANES), jnp.int8),
        interpret=interpret,
    )(x, xi, jnp.stack([inv_norm]), jnp.full((1,), float(s), jnp.float32))
    import math
    d = R * C
    tau = 1.0 + min(d / (s * s), math.sqrt(d) / s)
    scale = norm / (s * tau)
    return codes, scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def qsgd_dequantize(codes, scale, *, interpret: bool = True,
                    block_rows: int = BLOCK_ROWS):
    R, C = codes.shape
    grid = (R // block_rows,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, LANES), jnp.float32),
        interpret=interpret,
    )(codes, jnp.stack([scale]))
