"""Kernel-backend dispatch for the packed gossip hot path.

The packed CHOCO exchange has two memory-bound stages per bucket per
round: quantize the error-feedback delta into the wire codes (send
half) and integrate the dequantized self/neighbour payloads into the
``(x, x_hat, s)`` state (recv half — Algorithm 6's five full-size
reads and three writes).  This module picks, per exchange build, which
implementation runs them:

* ``"jnp"`` — the inline jnp expressions (the historical path; XLA's
  fusion decides how many HBM passes the EF update costs).
* ``"pallas"`` — the fused kernels in ``kernels/qsgd.py`` /
  ``kernels/ef_update.py``: one launch per bucket per direction.
* ``"auto"`` — probe the toolchain and prefer pallas when it can
  actually run fused (pallas importable, jax new enough to trace
  ``pallas_call`` under ``shard_map``, real TPU present); fall back to
  jnp otherwise.  Interpret-mode pallas on CPU is a correctness tier,
  not a perf tier, so ``auto`` never selects it — tests force
  ``"pallas"`` explicitly to exercise it.

Both backends are bit-exact: the kernels evaluate the very same
elementwise expressions, in the same association order, as the jnp
path (``tests/test_kernels.py`` + the distributed parity suite in
``tests/test_fused.py`` hold them to ``array_equal``).  The backend is
therefore a pure execution detail — it never enters the checkpoint
fingerprint and resume across backends is exact.

Module level stays jax-free on purpose: the CLI's fail-fast matrix
imports :func:`jax_version_tuple` before jax (and before XLA_FLAGS are
frozen) to reject ``--kernel-backend pallas`` on an old toolchain with
``SystemExit(2)``.
"""
from __future__ import annotations

import dataclasses
import functools

#: Recognised values for ``ChocoConfig.kernel_backend`` / ``--kernel-backend``.
BACKENDS = ("auto", "pallas", "jnp")

#: Oldest jax able to trace ``pallas_call`` under ``shard_map`` at all
#: (via ``check_rep=False`` — see :func:`shard_map_check_rep`).  Older
#: toolchains reject pallas pre-jax in the CLI.
MIN_JAX_FOR_PALLAS = (0, 4, 30)


def jax_version_tuple() -> tuple:
    """The installed jax version as an int 3-tuple, WITHOUT importing jax.

    Read from package metadata so the CLI can gate ``--kernel-backend
    pallas`` before the first jax import (pre-XLA_FLAGS, pre-device
    init).  Returns ``(0, 0, 0)`` when jax is not installed.
    """
    from importlib.metadata import PackageNotFoundError, version
    try:
        raw = version("jax")
    except PackageNotFoundError:
        return (0, 0, 0)
    parts = []
    for tok in raw.split(".")[:3]:
        digits = ""
        for ch in tok:
            if not ch.isdigit():
                break
            digits += ch
        parts.append(int(digits or 0))
    while len(parts) < 3:
        parts.append(0)
    return tuple(parts)


def toolchain_supports_pallas() -> bool:
    """Whether this jax is new enough for the pallas backend (metadata only)."""
    return jax_version_tuple() >= MIN_JAX_FOR_PALLAS


@dataclasses.dataclass(frozen=True)
class Toolchain:
    """Result of the build-time capability probe (:func:`probe_toolchain`)."""

    #: installed jax version (from package metadata)
    jax_version: tuple
    #: ``jax.experimental.pallas`` imports on this toolchain
    pallas_imports: bool
    #: ``pallas_call`` traces under ``shard_map`` with the default
    #: ``check_rep=True`` (jax 0.4.x has no replication rule for it, so
    #: this is False there and the engine passes ``check_rep=False``)
    shard_map_check_rep: bool
    #: no TPU attached — kernels must run in interpret mode
    interpret: bool


@functools.lru_cache(maxsize=1)
def probe_toolchain() -> Toolchain:
    """Probe, once per process, what the pallas backend may rely on.

    Imports jax (call only from exchange-build time or later, never at
    CLI validation time — that is what :func:`jax_version_tuple` is
    for).  The ``shard_map`` probe traces a trivial ``pallas_call``
    through a 1-device ``shard_map`` abstractly (``eval_shape``, no
    device computation) to learn whether the default replication check
    accepts it.
    """
    import jax
    ver = jax_version_tuple()
    try:
        from jax.experimental import pallas  # noqa: F401
        pallas_imports = True
    except Exception:
        pallas_imports = False
    interpret = jax.default_backend() != "tpu"
    check_rep = _probe_shard_map_check_rep() if pallas_imports else False
    return Toolchain(jax_version=ver, pallas_imports=pallas_imports,
                     shard_map_check_rep=check_rep, interpret=interpret)


def _probe_shard_map_check_rep() -> bool:
    """True iff ``pallas_call`` traces under ``shard_map(check_rep=True)``."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental import pallas as pl
    from jax.sharding import Mesh, PartitionSpec as P
    if hasattr(jax, "shard_map"):
        smap = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as smap

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    def local(x):
        return pl.pallas_call(
            kern, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x)

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("_probe",))
    fn = smap(local, mesh=mesh, in_specs=P(), out_specs=P())
    try:
        jax.eval_shape(fn, jax.ShapeDtypeStruct((8, 128), jnp.float32))
        return True
    except Exception:
        return False


def shard_map_check_rep(backend: str) -> bool:
    """The ``check_rep`` flag the engine's ``shard_map`` wrapper needs.

    The jnp backend keeps the default (True).  The pallas backend keeps
    it only when the toolchain has a replication rule for
    ``pallas_call``; on jax 0.4.x it does not, and ``check_rep=False``
    is the documented workaround (it only disables the replication
    *check* — numerics are unchanged).
    """
    if backend != "pallas":
        return True
    return probe_toolchain().shard_map_check_rep


def resolve_backend(requested: str, *, engine_eligible: bool = True) -> str:
    """Resolve a requested backend to the concrete one the engine runs.

    ``engine_eligible`` says whether the exchange being built is the
    packed choco engine the fused kernels are wired into (packed
    buckets, no topology process).  Forcing ``"pallas"`` on an
    ineligible engine or an incapable toolchain raises; ``"auto"``
    degrades to ``"jnp"`` silently (including on CPU, where pallas
    would run interpreted — a debug tier, not a perf win).
    """
    if requested not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {requested!r}; expected one of {BACKENDS}")
    if requested == "jnp":
        return "jnp"
    tc = probe_toolchain()
    if requested == "pallas":
        if not tc.pallas_imports:
            raise RuntimeError(
                "kernel_backend='pallas' requested but jax.experimental.pallas "
                "does not import on this toolchain")
        if jax_version_tuple() < MIN_JAX_FOR_PALLAS:
            raise RuntimeError(
                "kernel_backend='pallas' needs jax >= "
                + ".".join(map(str, MIN_JAX_FOR_PALLAS))
                + " (no shard_map-compatible pallas_call before that); found "
                + ".".join(map(str, jax_version_tuple())))
        if not engine_eligible:
            raise ValueError(
                "kernel_backend='pallas' is wired into the packed static "
                "choco engine only (mode=choco, packed buckets, no topology "
                "process); use 'auto' or 'jnp' here")
        return "pallas"
    # auto: pallas only where it is an actual perf win
    if (engine_eligible and tc.pallas_imports and not tc.interpret
            and jax_version_tuple() >= MIN_JAX_FOR_PALLAS):
        return "pallas"
    return "jnp"


# ---------------------------------------------------------------------------
# fused ops — one entry point per hot-path stage, dispatched on backend
# ---------------------------------------------------------------------------

def qsgd_codes(buf32, xi, inv_norm, s: int, *, backend: str):
    """QSGD wire codes for one packed bucket buffer (send half).

    ``buf32`` is the flat f32 delta, ``xi`` the uniform dither drawn on
    the same shape, ``inv_norm`` the precomputed ``1/||buf||`` (0 for a
    zero bucket — computed once on the unpadded buffer so both backends
    share the exact reduction).  Returns int8 codes for ``s <= 127``,
    int16 above, matching ``packing.compress_bucket``'s wire format.
    The pallas path pads to (rows, 128) tiles, runs the fused
    quantize kernel, and slices the tail; padded lanes quantize to
    code 0 (x == xi == 0 there), so the slice is exact.
    """
    if backend == "pallas":
        from repro.kernels.ops import _to_tiles
        from repro.kernels.qsgd import qsgd_quantize_codes
        xt, d = _to_tiles(buf32)
        xit, _ = _to_tiles(xi)
        tc = probe_toolchain()
        codes = qsgd_quantize_codes(xt, xit, inv_norm, s,
                                    interpret=tc.interpret)
        return codes.reshape(-1)[:d]
    import jax.numpy as jnp
    level = jnp.floor(jnp.abs(buf32) * inv_norm * s + xi)
    ctype = jnp.int8 if s <= 127 else jnp.int16
    return (jnp.sign(buf32) * level).astype(ctype)


def sign_codes(buf32, *, backend: str):
    """SignNorm int8 wire codes for one packed bucket buffer."""
    if backend == "pallas":
        from repro.kernels.ops import _to_tiles
        from repro.kernels.qsgd import signnorm_codes
        xt, d = _to_tiles(buf32)
        codes = signnorm_codes(xt, interpret=probe_toolchain().interpret)
        return codes.reshape(-1)[:d]
    import jax.numpy as jnp
    return jnp.sign(buf32).astype(jnp.int8)


def ef_bucket_update(x_half, x_hat, s, q_self, q_nbr, w_self, w_nbr, gamma,
                     *, backend: str):
    """Fused CHOCO EF integrate for one flat f32 bucket (recv half).

    One sweep producing the Algorithm 5/6 update::

        x_hat' = x_hat + q_self
        s'     = s + (w_self * q_self + w_nbr * q_nbr)
        x'     = x_half + gamma * (s' - x_hat')

    Returns ``(x', x_hat', s')``.  The pallas path is a single kernel
    launch (5 reads, 3 writes); the jnp path spells out the identical
    expressions — same association, so XLA cannot reorder them apart
    and the backends stay bit-exact.
    """
    if backend == "pallas":
        from repro.kernels.ops import ef_gossip_update_vector
        return ef_gossip_update_vector(
            x_half, x_hat, s, q_self, q_nbr, w_self, w_nbr, gamma,
            interpret=probe_toolchain().interpret)
    x_hat_n = x_hat + q_self
    s_n = s + (w_self * q_self + w_nbr * q_nbr)
    x_n = x_half + gamma * (s_n - x_hat_n)
    return x_n, x_hat_n, s_n
