"""Jit'd public wrappers around the Pallas kernels, shape-polymorphic over
flat vectors (pad + reshape to (R, 128) tiles internally)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .qsgd import qsgd_quantize, qsgd_dequantize, LANES
from .topk import block_topk_mask
from .ef_update import ef_gossip_update


def _to_tiles(x, rows_multiple: int = 8):
    """Flat (d,) -> padded (R, 128) with R % rows_multiple == 0."""
    d = x.size
    row_unit = LANES * rows_multiple
    pad = (-d) % row_unit
    xp = jnp.pad(x.ravel(), (0, pad))
    return xp.reshape(-1, LANES), d


def _from_tiles(t, d):
    return t.ravel()[:d]


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def qsgd_compress_vector(x, xi, s: int, *, interpret: bool = True):
    """Flat qsgd: x, xi (d,) -> (codes int8/int16 (d,), scale)."""
    xt, d = _to_tiles(x)
    xit, _ = _to_tiles(xi)
    codes, scale = qsgd_quantize(xt, xit, s, interpret=interpret)
    return _from_tiles(codes, d), scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def qsgd_decompress_vector(codes, scale, *, interpret: bool = True):
    """Flat qsgd dequantize: codes (d,), scale scalar -> f32 (d,)."""
    ct, d = _to_tiles(codes)
    return _from_tiles(qsgd_dequantize(ct, scale, interpret=interpret), d)


@functools.partial(jax.jit, static_argnames=("k_per_block", "interpret"))
def block_topk_compress_vector(x, k_per_block: int, *, interpret: bool = True):
    """Flat block-top-k: select ~k_per_block per 128-lane row.
    Returns the masked dense q (same shape as x)."""
    xt, d = _to_tiles(x)
    mask, _ = block_topk_mask(xt, k_per_block, interpret=interpret)
    return _from_tiles(xt * mask, d)


@functools.partial(jax.jit, static_argnames=("k_per_block", "block"))
def block_topk_select(x, k_per_block: int, *, block: int = 128):
    """Flat blockwise top-k *payload extraction* — the pure-jnp REFERENCE
    path (``lax.top_k`` + gather, no Pallas kernel behind it).  It shares
    the selection rule with the ``block_topk_mask`` kernel, but where the
    mask kernel produces the dense masked q in one tiled pass, this emits
    the compact static-shape (values, indices) wire payload, which needs a
    gather the TPU kernel does not attempt; it stays jnp under every
    ``kernels/dispatch.py`` backend.

    x: (d,) -> (values (R, k), indices (R, k) int32) with R = ceil(d/block);
    the tail block is zero-padded, so padded positions carry zero values.
    """
    assert block % LANES == 0
    d = x.size
    R = -(-d // block)
    rows = jnp.pad(x.ravel(), (0, R * block - d)).reshape(R, block)
    _, idx = jax.lax.top_k(jnp.abs(rows), k_per_block)
    vals = jnp.take_along_axis(rows, idx, axis=1)
    return vals, idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ef_gossip_update_vector(x_half, x_hat, s, q_self, q_nbr,
                            w_self, w_nbr, gamma, *, interpret: bool = True):
    """Flat fused CHOCO update; all args (d,) f32."""
    tiles = [_to_tiles(a, rows_multiple=256)[0]
             for a in (x_half, x_hat, s, q_self, q_nbr)]
    d = x_half.size
    x, xh, sn = ef_gossip_update(*tiles, w_self, w_nbr, gamma,
                                 interpret=interpret)
    return (_from_tiles(x, d), _from_tiles(xh, d), _from_tiles(sn, d))


from .flash_attention import flash_attention  # noqa: E402,F401  (public re-export)
