"""Pallas TPU kernel: flash attention (online-softmax, tiled, causal optional).

The dry-run roofline shows the memory term of every train/prefill shape is
dominated by materialised (B, H, S, S) attention weights; this kernel streams
K/V tiles through VMEM with running max/denominator so HBM traffic drops from
O(S^2) to O(S * Dh) per head — the standard flash recipe adapted to TPU tile
shapes (q block x k block multiples of 128 on the lane dim).

Layout: q, k, v are (S, Dh) per (batch, head) — the ops wrapper vmaps over
(B, H) and handles GQA head repetition.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, softcap,
                  block_q: int, block_k: int, seq_len: int, scale: float):
    qi = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32) * scale           # (block_q, Dh)

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, q.shape[-1]), jnp.float32)

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(kj, carry):
        m, l, acc = carry
        k_tile = k_ref[pl.dslice(kj * block_k, block_k), :]
        v_tile = v_ref[pl.dslice(kj * block_k, block_k), :]
        logits = q @ k_tile.astype(jnp.float32).T        # (block_q, block_k)
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        if causal:
            k_pos = kj * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=1))
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v_tile.astype(jnp.float32)
        return m_new, l_new, acc_new

    n_k = seq_len // block_k
    if causal:
        # only tiles up to (and including) the diagonal contribute
        n_k_eff = jax.lax.div(qi * block_q + block_q - 1, block_k) + 1
    else:
        n_k_eff = n_k
    m, l, acc = jax.lax.fori_loop(0, n_k_eff, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "softcap", "block_q",
                                             "block_k", "interpret"))
def flash_attention_single(q, k, v, *, causal: bool = True, softcap=None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q, k, v: (S, Dh) for one (batch, head).  Returns (S, Dh)."""
    S, Dh = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = 1.0 / math.sqrt(Dh)
    kernel = functools.partial(_flash_kernel, causal=causal, softcap=softcap,
                               block_q=block_q, block_k=block_k, seq_len=S,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(S // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, Dh), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),   # K, V streamed with pl.load
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_q, Dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, Dh), q.dtype),
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.jit, static_argnames=("causal", "softcap", "interpret",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, softcap=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B, S, H, Dh); k, v: (B, S, KV, Dh) (GQA: H % KV == 0).
    Returns (B, S, H, Dh)."""
    B, S, H, Dh = q.shape
    KV = k.shape[2]
    rep = H // KV
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    fn = functools.partial(flash_attention_single, causal=causal,
                           softcap=softcap, block_q=block_q, block_k=block_k,
                           interpret=interpret)
    # vmap over batch then heads: (B, S, H, Dh) -> per (b, h) (S, Dh)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = jax.vmap(jax.vmap(fn))(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
