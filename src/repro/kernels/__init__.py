"""Pallas TPU kernels for the gossip hot path, with pure-jnp oracles.

Layout (the xformers-style kernel/reference discipline):

* ``qsgd.py`` / ``topk.py`` / ``ef_update.py`` / ``flash_attention.py``
  — tiled Pallas kernels for the bandwidth-bound stages (quantize to
  wire codes, block top-k mask, fused CHOCO error-feedback update,
  attention).
* ``ops.py`` — jit'd shape-polymorphic wrappers over flat vectors
  (pad + reshape to (rows, 128) tiles internally).
* ``ref.py`` — bit-exact pure-jnp oracles; every kernel is held to
  parity with its oracle in ``tests/test_kernels.py``.
* ``dispatch.py`` — backend resolution (``auto``/``pallas``/``jnp``)
  and the fused entry points the packed gossip engine calls.

OPTIONAL layer by repo convention: add kernels only for compute
hot-spots the reproduction actually optimizes.
"""
