"""Pallas TPU kernel: block-wise top-k selection mask.

Global top-k needs a full sort; the TPU-native adaptation picks the k largest
magnitudes *per VMEM block* via threshold bisection — pure vector compares and
reductions, no sort, one HBM pass.  Blockwise top-(k/nblocks) satisfies the
paper's Assumption 1 with omega = k/d exactly like global top_k (Stich et al.
2018, Lemma A.1 applied per block).

The kernel emits a {0,1} mask and the per-row thresholds; the ops wrapper
(ops.py) forms the masked dense q and the compact wire payload.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
N_ITER = 24


def _block_topk_kernel(x_ref, k_ref, mask_ref, thresh_ref):
    x = x_ref[...]                       # (rows, C)
    k = k_ref[0]
    mag = jnp.abs(x)
    lo = jnp.zeros((x.shape[0],), jnp.float32)
    hi = jnp.max(mag, axis=1) + 1e-12

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((mag >= mid[:, None]).astype(jnp.int32), axis=1)
        ge = cnt >= k
        return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid)

    lo, hi = jax.lax.fori_loop(0, N_ITER, body, (lo, hi))
    mask_ref[...] = (mag >= lo[:, None]).astype(jnp.float32)
    thresh_ref[...] = lo


@functools.partial(jax.jit, static_argnames=("k", "interpret", "block_rows"))
def block_topk_mask(x, k: int, *, interpret: bool = True, block_rows: int = 8):
    """x: (R, C) with C a multiple of 128.  Per-row top-k mask.
    Returns (mask (R,C) f32, thresholds (R,) f32)."""
    R, C = x.shape
    assert C % LANES == 0 and R % block_rows == 0, (R, C)
    grid = (R // block_rows,)
    mask, thresh = pl.pallas_call(
        _block_topk_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=[pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.float32),
                   jax.ShapeDtypeStruct((R,), jnp.float32)],
        interpret=interpret,
    )(x, jnp.full((1,), k, jnp.int32))
    return mask, thresh
