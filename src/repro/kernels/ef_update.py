"""Pallas TPU kernel: fused CHOCO error-feedback gossip update.

The per-step state update (Algorithm 6 lines 8-10) touches FIVE full-size
streams (x_half, x_hat, s, q_self, q_nbr) and writes THREE (x, x_hat, s) —
at 3 x N parameters of state this is the memory-bound hot loop of CHOCO-SGD.
Unfused, XLA may issue it as several passes; this kernel does one
HBM->VMEM->HBM sweep per tile:

    x_hat' = x_hat + q_self
    s'     = s + (w_self q_self + w_nbr q_nbr)
    x'     = x_half + gamma (s' - x_hat')

The s' parenthesization is load-bearing: it matches the association the
engine's jnp leaf path uses (comm/gossip.py::_choco_leaf_updates), and
XLA does not reassociate floats — so any residual cross-backend
difference is FMA-contraction rounding at fusion boundaries (ulp-level,
bounded in tests/test_fused.py), never association drift.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _ef_kernel(xh_ref, xhat_ref, s_ref, qs_ref, qn_ref, coef_ref,
               x_out, xhat_out, s_out):
    w_self = coef_ref[0]
    w_nbr = coef_ref[1]
    gamma = coef_ref[2]
    q_self = qs_ref[...]
    xhat_n = xhat_ref[...] + q_self
    s_n = s_ref[...] + (w_self * q_self + w_nbr * qn_ref[...])
    x_out[...] = xh_ref[...] + gamma * (s_n - xhat_n)
    xhat_out[...] = xhat_n
    s_out[...] = s_n


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def ef_gossip_update(x_half, x_hat, s, q_self, q_nbr, w_self, w_nbr, gamma,
                     *, interpret: bool = True, block_rows: int = 256):
    """All tensors (R, 128) f32.  Returns (x, x_hat, s)."""
    R, C = x_half.shape
    assert C == LANES and R % block_rows == 0, (R, C)
    grid = (R // block_rows,)
    bs = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    coef = jnp.asarray([w_self, w_nbr, gamma], jnp.float32)
    return pl.pallas_call(
        _ef_kernel,
        grid=grid,
        in_specs=[bs, bs, bs, bs, bs, pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=[bs, bs, bs],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.float32)] * 3,
        interpret=interpret,
    )(x_half, x_hat, s, q_self, q_nbr, coef)
