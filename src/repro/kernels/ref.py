"""Pure-jnp oracles for every Pallas kernel (exact same math, no tiling)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# -- qsgd ---------------------------------------------------------------------

def qsgd_quantize_ref(x, xi, s: int):
    """Quantize oracle: int8 codes for s <= 127, int16 above (the
    ``packing.compress_bucket`` wire format).  No clip — |x| <= ||x||
    already bounds every level by s."""
    norm = jnp.sqrt(jnp.sum(jnp.square(x)))
    inv_norm = jnp.where(norm == 0, 0.0, 1.0 / norm)
    level = jnp.floor(jnp.abs(x) * inv_norm * s + xi)
    ctype = jnp.int8 if s <= 127 else jnp.int16
    codes = (jnp.sign(x) * level).astype(ctype)
    d = x.size
    tau = 1.0 + min(d / (s * s), math.sqrt(d) / s)
    return codes, (norm / (s * tau)).astype(jnp.float32)


def qsgd_dequantize_ref(codes, scale):
    """Dequantize oracle: codes * scale in f32."""
    return codes.astype(jnp.float32) * scale


def signnorm_codes_ref(x):
    """SignNorm wire-code oracle: int8 sign(x)."""
    return jnp.sign(x).astype(jnp.int8)


# -- block top-k --------------------------------------------------------------

def block_topk_mask_ref(x, k: int, n_iter: int = 24):
    """Per-row (block) top-k selection mask via threshold bisection.
    x: (R, C).  Returns (mask f32 (R,C), thresholds (R,)).

    Bisection converges to a magnitude threshold t per row such that
    count(|x| >= t) >= k with the tightest representable t; ties may admit a
    few extra elements (documented operator semantics: count in [k, k+ties))."""
    mag = jnp.abs(x)
    lo = jnp.zeros((x.shape[0],), jnp.float32)
    hi = jnp.max(mag, axis=1) + 1e-12

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum(mag >= mid[:, None], axis=1)
        ge = cnt >= k
        return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid)

    lo, hi = jax.lax.fori_loop(0, n_iter, body, (lo, hi))
    mask = (mag >= lo[:, None]).astype(jnp.float32)
    return mask, lo


# -- fused error-feedback gossip update ---------------------------------------

def ef_gossip_update_ref(x_half, x_hat, s, q_self, q_nbr, w_self, w_nbr, gamma):
    """CHOCO state update (Algorithm 6 lines 8-10), fused:
        x_hat' = x_hat + q_self
        s'     = s + (w_self * q_self + w_nbr * q_nbr)
        x'     = x_half + gamma * (s' - x_hat')
    All arrays same shape; q_nbr is the (already summed) neighbour payload.
    The s' association matches the engine's jnp path exactly (floats do
    not reassociate under XLA) — that is the bit-exactness contract."""
    x_hat_n = x_hat + q_self
    s_n = s + (w_self * q_self + w_nbr * q_nbr)
    x_n = x_half + gamma * (s_n - x_hat_n)
    return x_n, x_hat_n, s_n


# -- flash attention -----------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal: bool = True,
                        softcap: float | None = None):
    """q,k,v: (B, S, H, Dh) -> (B, S, H, Dh), plain softmax attention oracle."""
    Dh = q.shape[-1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(Dh)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    if causal:
        S = q.shape[1]
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)
