"""Typed jaxpr auditing: structural launch counting without compiling.

The fused-kernel contract (kernels/dispatch.py, EXPERIMENTS.md §Perf I)
is asserted on the *jaxpr*, not the HLO: interpret-mode Pallas lowers to
grid loops on CPU, so compiled text is unrepresentative of the TPU
lowering, while the number of ``pallas_call`` equations in the traced
program is backend-independent.  This module is the shared implementation
behind ``benchmarks/bench_fused.py`` and ``tests/test_fused.py``.

Everything here is duck-typed over jaxpr objects (``.eqns`` /
``.jaxpr`` attributes) so it works across jax versions and never imports
jax itself.
"""
from __future__ import annotations

from typing import List


def sub_jaxprs(v) -> List:
    """Duck-typed extraction of nested jaxprs from an eqn param value.

    Accepts a (closed) jaxpr, a ClosedJaxpr-like wrapper carrying
    ``.jaxpr``, or an arbitrarily nested list/tuple of either; returns the
    flat list of inner jaxprs (possibly empty).
    """
    if hasattr(v, "eqns"):
        return [v]
    if hasattr(v, "jaxpr"):
        return [v.jaxpr]
    if isinstance(v, (list, tuple)):
        out = []
        for item in v:
            out.extend(sub_jaxprs(item))
        return out
    return []


def count_primitive(jaxpr, name: str) -> int:
    """Recursively count equations of primitive ``name`` in a jaxpr,
    descending into every nested jaxpr (pjit/closed_call bodies, scan and
    while carries, cond branches, custom_vjp calls, ...)."""
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            total += 1
        for v in eqn.params.values():
            for sub in sub_jaxprs(v):
                total += count_primitive(sub, name)
    return total


def count_pallas_calls(jaxpr) -> int:
    """Recursively count ``pallas_call`` equations in a (closed) jaxpr —
    the fused-launch count the 2-launches-per-bucket contract is stated
    over."""
    return count_primitive(jaxpr, "pallas_call")
