"""repro-lint: the static invariant analyzer's CLI driver.

    PYTHONPATH=src python -m repro.analysis.lint [--root DIR] [--only PASS]

Runs four passes and exits non-zero iff any produced a finding:

* ``source``      — AST repo contracts (``source_lint``): jax-free-at-import
  gates, traced-package purity (clocks/RNG/file-I/O), fail-fast ordering,
  docstring coverage.
* ``fingerprint`` — ChocoConfig / manifest-fingerprint coverage
  (``fingerprint_lint``).
* ``metrics``     — the obs metric registry vs the emit sites
  (``metrics_lint``): unregistered emitted keys and stale registry
  entries are findings.
* ``invariants``  — engine-invariant registry self-check + committed
  BENCH_*.json conformance (``invariants``).

The driver imports no jax and compiles nothing: it is fast-tier by
construction and runs identically over scratch fixture roots (``--root``),
which is how ``tests/test_analysis_lint.py`` proves each pass actually
fires.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional, Sequence

from repro.analysis import (fingerprint_lint, invariants, metrics_lint,
                            source_lint)
from repro.analysis.findings import Finding, sort_findings

PASSES = {
    "source": source_lint.run_source_lint,
    "fingerprint": fingerprint_lint.run_fingerprint_lint,
    "metrics": metrics_lint.run_metrics_lint,
    "invariants": invariants.lint_bench_invariants,
}

#: repo root when invoked in-tree: src/repro/analysis/lint.py -> ../../..
DEFAULT_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def run_passes(root: str,
               only: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the selected passes (default: all) over ``root``; findings come
    back in the stable (path, line, message) order the CLI prints."""
    names = list(only) if only else list(PASSES)
    findings: List[Finding] = []
    for name in names:
        findings.extend(PASSES[name](root))
    return sort_findings(findings)


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI entry point; returns the process exit code (0 = clean)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="static invariant analyzer for traced code, compiled "
                    "HLO records, and repo contracts")
    ap.add_argument("--root", default=DEFAULT_ROOT,
                    help="repo root to analyze (default: this checkout)")
    ap.add_argument("--only", action="append", choices=sorted(PASSES),
                    help="run only this pass (repeatable; default: all)")
    args = ap.parse_args(argv)
    findings = run_passes(os.path.abspath(args.root), args.only)
    for f in findings:
        print(f.render())
    ran = ", ".join(args.only if args.only else PASSES)
    if findings:
        print(f"repro-lint: {len(findings)} finding(s) [{ran}]")
        return 1
    print(f"repro-lint: clean [{ran}]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
