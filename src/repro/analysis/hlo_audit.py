"""Typed HLO-text auditing: the IR half of the repro.analysis subsystem.

Every load-bearing structural claim the repo makes about compiled train
steps is parsed out of ``compiled.as_text()`` by the functions here —
one shared, unit-tested implementation instead of the per-benchmark
copies that used to live in ``benchmarks/bench_{overlap,fused,async}.py``:

* :func:`count_permute_launches` — collective-permute launch counting
  (start/done pairs counted once), whole-module or entry-computation-only
  (the matching engine's "all permutes live inside switch branches" audit).
* :func:`collective_dependency_audit` — the scheduler-independent operand
  closure of the collective-permutes: how many matmuls MUST retire before
  the wire transfer can start (0 == the collective is launchable at step
  start and overlappable with the whole forward/backward — the pipelined
  engine's claim, EXPERIMENTS.md §Perf H).
* :func:`entry_stream_audit` — full-size HBM stream counting over the
  entry computation (post-fusion reads/writes at or above a size
  threshold — the fused-kernel traffic claim, EXPERIMENTS.md §Perf I).
* :func:`hlo_computations` — the underlying module -> computation split.

These parsers never compile anything; they are pure text analysis, so
parser regressions are caught by hand-written HLO fixtures in
``tests/test_hlo_audit.py`` without touching a device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

#: f32 tensors at or above this many elements count as full-size streams
#: in :func:`entry_stream_audit` (gossip state buckets are hundreds of KB;
#: scalars and per-bucket scales are not).
STREAM_THRESHOLD = 1 << 14

#: bytes per element for the dtypes :func:`entry_stream_audit` can count
STREAM_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
}

_CALLED = re.compile(r"(?:calls|to_apply|body|condition)=%([\w\.\-]+)")
_NAMES = re.compile(r"%([\w\.\-]+)")


def hlo_computations(hlo: str) -> Dict[str, List[str]]:
    """Split HLO text into ``{computation_name: [instruction lines]}``.

    The entry computation is additionally keyed ``"__entry__"`` (same list
    object), so callers need not know its mangled name.
    """
    comps, cur, body = {}, None, []
    for line in hlo.splitlines():
        if re.match(r"^\S.*\{\s*$", line):
            cur = line.split()[0].lstrip("%")
            if cur.startswith("ENTRY"):
                cur = line.split()[1].lstrip("%")
            body = comps.setdefault(cur, [])
            if line.startswith("ENTRY"):
                comps["__entry__"] = body
        elif cur is not None and line.strip() and line.strip() != "}":
            body.append(line)
    return comps


def _is_permute_launch(line: str) -> bool:
    """One launch per collective-permute; async start/done pairs count once
    (the ``-done`` half is the completion of an already-counted start)."""
    return "collective-permute" in line and "-done" not in line


def count_permute_launches(hlo: str, *, entry_only: bool = False) -> int:
    """Count collective-permute launches in an HLO module.

    ``entry_only=True`` restricts to the entry computation — the matching
    engine's audit, where every permute must live inside a ``lax.switch``
    branch computation and the entry carries zero unconditional launches.
    """
    if entry_only:
        lines = hlo_computations(hlo).get("__entry__", [])
    else:
        lines = hlo.splitlines()
    return sum(1 for l in lines if _is_permute_launch(l))


def count_dots(comps: Dict[str, List[str]], name: str,
               memo: Optional[dict] = None) -> int:
    """Transitive ``dot(...)`` count of a computation, descending into the
    computations it calls (fusions, while bodies, ``to_apply`` reducers)."""
    memo = {} if memo is None else memo
    if name in memo:
        return memo[name]
    memo[name] = 0          # cycle guard (HLO call graphs are acyclic)
    total = 0
    for line in comps.get(name, ()):
        if "dot(" in line:
            total += 1
        for callee in _CALLED.findall(line):
            total += count_dots(comps, callee, memo)
    memo[name] = total
    return total


@dataclasses.dataclass(frozen=True)
class CollectiveDependencyAudit:
    """Dependency audit of one compiled train-step module.

    ``dots_feeding_collective`` is the matmul work an async scheduler must
    finish BEFORE the wire transfer can start — 0 means the collective is
    launchable at step start and its start/done pair is separable by the
    entire forward/backward compute.
    """

    permute_launches: int
    dots_total: int
    dots_feeding_collective: int

    def as_dict(self) -> dict:
        """The BENCH_overlap.json record shape (stable key names)."""
        return {"permute_launches": self.permute_launches,
                "dots_total": self.dots_total,
                "dots_feeding_collective": self.dots_feeding_collective}


def collective_dependency_audit(hlo: str) -> CollectiveDependencyAudit:
    """Transitive operand closure of every collective-permute in the entry
    computation, counting the matmuls inside it (descending into
    fused/called computations, e.g. a scan-over-layers while loop).

    The CPU backend lowers ``lax.ppermute`` synchronously and printed HLO
    instruction order is not a schedule, so start/done separation cannot be
    read off the text; the DEPENDENCY structure can — an async scheduler
    may move collective-start before, and collective-done after, exactly
    those ops not on a path to/from the collective.
    """
    comps = hlo_computations(hlo)
    entry = comps.get("__entry__", [])
    defs, deps, called = {}, {}, {}
    for line in entry:
        m = re.match(r"\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=", line)
        if not m:
            continue
        name = m.group(1)
        defs[name] = line
        callees = set(_CALLED.findall(line))
        rhs = line.split("=", 1)[1]
        deps[name] = [n for n in _NAMES.findall(rhs)
                      if n != name and n not in callees]
        called[name] = callees
    permutes = [n for n, l in defs.items() if "collective-permute" in l]
    memo = {}
    seen, stack = set(), []
    for p in permutes:
        stack.extend(deps.get(p, []))
    feeding_dots = 0
    while stack:
        n = stack.pop()
        if n in seen or n not in defs:
            continue
        seen.add(n)
        if "dot(" in defs[n]:
            feeding_dots += 1
        for c in called.get(n, ()):
            feeding_dots += count_dots(comps, c, memo)
        stack.extend(deps.get(n, []))
    total = count_dots(comps, "__entry__", {})
    return CollectiveDependencyAudit(
        permute_launches=len(permutes), dots_total=total,
        dots_feeding_collective=feeding_dots)


def _elems(dims: str) -> int:
    total = 1
    for d in dims.split(","):
        if d:
            total *= int(d)
    return total


def entry_stream_audit(hlo: str, threshold: int = STREAM_THRESHOLD,
                       dtypes: Tuple[str, ...] = ("f32",)) -> dict:
    """Count full-size streams in the ENTRY computation of an HLO module.

    Defs are writes, operands are reads — both post-fusion, i.e. actual
    HBM traffic under XLA's fusion model.  Parameter declarations and
    tuple plumbing define no stream; their tensors are counted where an
    instruction actually consumes them.  Only tensors of the requested
    ``dtypes`` at or above ``threshold`` elements count (the first shaped
    match on a line is its def, the rest its operands), so e.g. int16
    wire-code or bf16 state lines are invisible to the default f32 audit
    and become visible by passing ``dtypes=("f32", "bf16", "s16")``.

    Returns ``{"streams", "reads", "writes", "bytes"}`` — the
    BENCH_fused.json record shape.
    """
    unknown = [d for d in dtypes if d not in STREAM_DTYPE_BYTES]
    if unknown:
        raise ValueError(f"unknown stream dtypes {unknown}; "
                         f"known: {sorted(STREAM_DTYPE_BYTES)}")
    shape_re = re.compile(r"\b(" + "|".join(map(re.escape, dtypes))
                          + r")\[([\d,]*)\]")
    entry, depth, in_entry = [], 0, False
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            in_entry = True
            depth = 0
        if in_entry:
            depth += line.count("{") - line.count("}")
            entry.append(line)
            if depth <= 0 and "}" in line:
                break
    reads = writes = read_bytes = write_bytes = 0
    for line in entry[1:]:
        s = line.strip()
        if not s or s == "}" or "parameter(" in s \
                or s.startswith(("ROOT %tuple", "ROOT tuple")) \
                or "get-tuple-element" in s:
            continue
        shapes = shape_re.findall(s)
        if not shapes or "=" not in s:
            continue
        dt, dims = shapes[0]
        d = _elems(dims)
        if d >= threshold:
            writes += 1
            write_bytes += d * STREAM_DTYPE_BYTES[dt]
        for dt, dims in shapes[1:]:
            d = _elems(dims)
            if d >= threshold:
                reads += 1
                read_bytes += d * STREAM_DTYPE_BYTES[dt]
    return {"streams": reads + writes, "reads": reads, "writes": writes,
            "bytes": read_bytes + write_bytes}
