"""Shared finding type for the static-analysis passes (`repro.analysis`).

Every lint pass (``source_lint``, ``fingerprint_lint``, the invariant
checks in ``invariants``) reports violations as :class:`Finding` records
so the ``python -m repro.analysis.lint`` driver can render them uniformly
(``path:line: [pass] message``) and exit non-zero iff any pass found one.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation, anchored to a file/line where possible."""

    #: which pass produced it ("source", "fingerprint", "invariants")
    pass_name: str
    #: repo-relative path of the offending file ("" for repo-level findings)
    path: str
    #: 1-based line number (0 when the finding is not line-anchored)
    line: int
    #: human-pointed description of the violated contract
    message: str

    def render(self) -> str:
        """``path:line: [pass] message`` (line omitted when 0)."""
        loc = f"{self.path}:{self.line}" if self.line else (self.path or "<repo>")
        return f"{loc}: [{self.pass_name}] {self.message}"


def render_findings(findings: Iterable[Finding]) -> str:
    """Render findings one per line, stable order (path, line, message)."""
    ordered = sorted(findings, key=lambda f: (f.path, f.line, f.message))
    return "\n".join(f.render() for f in ordered)


def sort_findings(findings: Iterable[Finding]) -> List[Finding]:
    """Stable (path, line, message) ordering used by the CLI driver."""
    return sorted(findings, key=lambda f: (f.path, f.line, f.message))
