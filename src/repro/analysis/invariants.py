"""Declarative engine-invariant registry for the gossip engines.

CHOCO-SGD's value proposition is *provable* communication structure
(Koloskova et al. 2019): the pipelined engine's wire must be gated by
zero matmuls, the fused backend must launch exactly two kernels per
bucket per round, the async engine must add zero permute launches over
its link-failure baseline, the matching engine must keep every permute
inside a switch branch.  Each of those used to live as a literal inside
one benchmark or test; here they are *data* — an
:class:`EngineInvariant` per (engine, backend) — checked uniformly by
:func:`check_invariant`, consumed by ``benchmarks/bench_{overlap,fused,
async}.py``, asserted over live compiles by ``tests/test_invariants.py``,
and re-validated against the committed BENCH_*.json records by
``python -m repro.analysis.lint`` (:func:`lint_bench_invariants`).

Expectations are tiny arithmetic expressions over a measurement context
(``"2 * buckets * steps"``, ``"dots_total"``, ``"0"``) so a new engine
adds one registry line, not a new parser: see
``docs/ARCHITECTURE.md §Static analysis & invariants``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

from repro.analysis.findings import Finding

#: names an expectation expression may reference, with the dummy values
#: the registry self-check evaluates them under
CONTEXT_VARS = {
    "buckets": 2,        # bucket count of the packed spec
    "steps": 1,          # gossip rounds per SGD step
    "rounds": 2,         # compiled schedule rounds
    "dots_total": 30,    # total matmuls in the compiled step
    "baseline": 16,      # reference engine's measurement (parity checks)
    "budget": 3,         # diag-step collective-launch budget (telemetry)
}


@dataclasses.dataclass(frozen=True)
class EngineInvariant:
    """One engine x backend contract: metric -> expected-value expression.

    ``expect`` maps a measured metric name (``permute_launches``,
    ``dots_feeding_collective``, ``pallas_calls``,
    ``entry_permute_launches``) to an arithmetic expression over
    :data:`CONTEXT_VARS`.  ``backend="*"`` applies to every kernel
    backend.
    """

    engine: str
    backend: str
    description: str
    expect: Tuple[Tuple[str, str], ...]


#: The registry: every structural claim a benchmark or test asserts about
#: a gossip engine's compiled/traced form lives here, nowhere else.
ENGINE_INVARIANTS: Tuple[EngineInvariant, ...] = (
    EngineInvariant(
        engine="choco_serial", backend="jnp",
        description="serial engine: the payload is Q(x_half - x_hat) and "
                    "x_half is downstream of the gradient, so EVERY "
                    "forward/backward matmul gates the wire; no fused "
                    "kernels are traced",
        expect=(("dots_feeding_collective", "dots_total"),
                ("pallas_calls", "0"))),
    EngineInvariant(
        engine="choco_serial", backend="pallas",
        description="fused backend: exactly two kernel launches per bucket "
                    "per gossip round — one quantize+pack, one "
                    "dequant+EF-update; more would mean unfused glue "
                    "re-reading the buckets",
        expect=(("pallas_calls", "2 * buckets * steps"),)),
    EngineInvariant(
        engine="choco_pipelined", backend="*",
        description="pipelined engine: the payload Q(x_k - x_hat_k) reads "
                    "only the carry, so ZERO matmuls gate the wire (the "
                    "collective is launchable at step start) and "
                    "pipelining adds zero permute launches over serial",
        expect=(("dots_feeding_collective", "0"),
                ("permute_launches", "baseline"))),
    EngineInvariant(
        engine="choco_staleness", backend="jnp",
        description="bounded-staleness engine: arrived-vs-stale selection "
                    "is where-mask arithmetic over ring slots — zero "
                    "permute launches added over the linkfail baseline",
        expect=(("permute_launches", "baseline"),)),
    EngineInvariant(
        engine="choco_staleness_stragglers", backend="jnp",
        description="per-edge straggler staleness: heterogeneous delay "
                    "tables change WHICH ring slot each edge reads, never "
                    "how much is shipped — zero permute launches added "
                    "over the global-staleness baseline",
        expect=(("permute_launches", "baseline"),)),
    EngineInvariant(
        engine="choco_matching", backend="jnp",
        description="matching engine: one sampled round per step via "
                    "lax.switch — the entry computation carries zero "
                    "unconditional permute launches",
        expect=(("entry_permute_launches", "0"),)),
    EngineInvariant(
        engine="telemetry_off", backend="*",
        description="telemetry subsystem: with diagnostics off, the "
                    "compiled train-step HLO is byte-identical to a build "
                    "that never constructed the diagnostics executable — "
                    "observability must cost nothing when unused",
        expect=(("hlo_identical", "1"),)),
    EngineInvariant(
        engine="telemetry_diag", backend="*",
        description="diagnostics executable: reductions only — zero "
                    "permute launches, and its collective launches stay "
                    "within the per-tap budget recorded when the "
                    "benchmark was run",
        expect=(("permute_launches", "0"),
                ("collective_launches", "budget"))),
)


def get_invariant(engine: str, backend: str = "jnp") -> EngineInvariant:
    """Look up the invariant for (engine, backend); a ``backend="*"``
    entry matches any backend.  Raises ``KeyError`` for unknown engines."""
    fallback = None
    for inv in ENGINE_INVARIANTS:
        if inv.engine != engine:
            continue
        if inv.backend == backend:
            return inv
        if inv.backend == "*":
            fallback = inv
    if fallback is not None:
        return fallback
    raise KeyError(f"no EngineInvariant registered for engine={engine!r} "
                   f"backend={backend!r}")


def evaluate_expectation(expr: str, ctx: Optional[Dict[str, int]] = None) -> int:
    """Evaluate an expectation expression over a measurement context.

    The expression language is deliberately tiny: integer literals,
    :data:`CONTEXT_VARS` names, and ``+ - * // ( )``.  Unknown names or
    other syntax raise ``ValueError`` (caught by the registry self-check).
    """
    ctx = dict(CONTEXT_VARS if ctx is None else ctx)
    allowed = set("0123456789+-*/() _")
    stripped = expr
    for name in sorted(ctx, key=len, reverse=True):
        stripped = stripped.replace(name, "")
    if not set(stripped) <= allowed:
        raise ValueError(f"expectation {expr!r} uses names outside the "
                         f"context {sorted(ctx)}")
    try:
        return int(eval(expr, {"__builtins__": {}}, ctx))  # noqa: S307
    except Exception as e:
        raise ValueError(f"expectation {expr!r} failed to evaluate over "
                         f"{sorted(ctx)}: {e}") from e


def check_invariant(inv: EngineInvariant, measured: Dict[str, int],
                    ctx: Optional[Dict[str, int]] = None) -> List[str]:
    """Check measurements against one invariant.

    ``measured`` maps metric names to observed values; ``ctx`` supplies
    the expression variables (``buckets``, ``steps``, ``dots_total``,
    ``baseline``, ...).  Metrics the caller did not measure are skipped —
    a benchmark checks only what it observed.  Returns a list of pointed
    violation strings; empty means the contract holds.
    """
    violations = []
    for metric, expr in inv.expect:
        if metric not in measured:
            continue
        expected = evaluate_expectation(expr, ctx)
        actual = measured[metric]
        if actual != expected:
            violations.append(
                f"{inv.engine}/{inv.backend}: {metric} = {actual}, "
                f"expected {expr} = {expected} ({inv.description})")
    return violations


def assert_invariant(engine: str, backend: str, measured: Dict[str, int],
                     ctx: Optional[Dict[str, int]] = None) -> None:
    """Registry lookup + check + raise: the one-liner the benchmarks call
    instead of private literal asserts."""
    violations = check_invariant(get_invariant(engine, backend), measured, ctx)
    if violations:
        raise AssertionError("; ".join(violations))


# ---------------------------------------------------------------------------
# lint pass: registry self-check + committed BENCH_*.json conformance
# ---------------------------------------------------------------------------

def _registry_findings() -> List[Finding]:
    findings = []
    seen = set()
    for inv in ENGINE_INVARIANTS:
        key = (inv.engine, inv.backend)
        if key in seen:
            findings.append(Finding(
                "invariants", "src/repro/analysis/invariants.py", 0,
                f"duplicate registry entry for {key}"))
        seen.add(key)
        for metric, expr in inv.expect:
            try:
                evaluate_expectation(expr)
            except ValueError as e:
                findings.append(Finding(
                    "invariants", "src/repro/analysis/invariants.py", 0,
                    f"{inv.engine}/{inv.backend}: bad expectation "
                    f"for {metric}: {e}"))
    return findings


def _bench_overlap_findings(root: str) -> List[Finding]:
    path = os.path.join(root, "BENCH_overlap.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        rec = json.load(f)
    findings = []
    serial, pipe = rec.get("serial", {}), rec.get("pipelined", {})
    ctx = dict(CONTEXT_VARS)
    ctx["dots_total"] = serial.get("dots_total", 0)
    for v in check_invariant(get_invariant("choco_serial", "jnp"),
                             {"dots_feeding_collective":
                              serial.get("dots_feeding_collective", -1)}, ctx):
        findings.append(Finding("invariants", "BENCH_overlap.json", 0, v))
    ctx["baseline"] = serial.get("permute_launches", 0)
    ctx["dots_total"] = pipe.get("dots_total", 0)
    measured = {"dots_feeding_collective":
                pipe.get("dots_feeding_collective", -1),
                "permute_launches": pipe.get("permute_launches", -1)}
    for v in check_invariant(get_invariant("choco_pipelined", "jnp"),
                             measured, ctx):
        findings.append(Finding("invariants", "BENCH_overlap.json", 0, v))
    return findings


def _bench_fused_findings(root: str) -> List[Finding]:
    path = os.path.join(root, "BENCH_fused.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        rec = json.load(f)
    findings = []
    pallas = rec.get("pallas", {})
    ctx = dict(CONTEXT_VARS)
    ctx["buckets"] = pallas.get("n_buckets", 0)
    ctx["steps"] = 1          # the fused audit traces one gossip round
    for v in check_invariant(get_invariant("choco_serial", "pallas"),
                             {"pallas_calls": pallas.get("pallas_calls", -1)},
                             ctx):
        findings.append(Finding("invariants", "BENCH_fused.json", 0, v))
    return findings


def _bench_telemetry_findings(root: str) -> List[Finding]:
    path = os.path.join(root, "BENCH_telemetry.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        rec = json.load(f)
    findings = []
    parity, diag = rec.get("parity", {}), rec.get("diag", {})
    ctx = dict(CONTEXT_VARS)
    # budget comes from the record itself (like the overlap baseline): a
    # doctored collective count that disagrees with its own budget fails
    ctx["budget"] = diag.get("collective_budget", 0)
    measured = {"hlo_identical": int(parity.get("hlo_identical", -1)),
                "permute_launches": diag.get("permute_launches", -1),
                "collective_launches": diag.get("collective_launches", -1)}
    for v in check_invariant(get_invariant("telemetry_off", "jnp"),
                             measured, ctx):
        findings.append(Finding("invariants", "BENCH_telemetry.json", 0, v))
    for v in check_invariant(get_invariant("telemetry_diag", "jnp"),
                             measured, ctx):
        findings.append(Finding("invariants", "BENCH_telemetry.json", 0, v))
    return findings


def _bench_scenarios_findings(root: str) -> List[Finding]:
    path = os.path.join(root, "BENCH_scenarios.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        rec = json.load(f)
    findings = []
    straggler = rec.get("straggler", {})
    ctx = dict(CONTEXT_VARS)
    ctx["baseline"] = straggler.get("global_staleness", 0)
    for v in check_invariant(
            get_invariant("choco_staleness_stragglers", "jnp"),
            {"permute_launches": straggler.get("straggler_staleness", -1)},
            ctx):
        findings.append(Finding("invariants", "BENCH_scenarios.json", 0, v))
    return findings


def lint_bench_invariants(root: str) -> List[Finding]:
    """The invariant lint pass: the registry is well-formed and the
    committed benchmark records (BENCH_overlap.json / BENCH_fused.json /
    BENCH_telemetry.json) still satisfy the contracts they were measured
    under.  A doctored or regressed record — e.g. a wrong permute-launch
    count, a non-zero gated-matmul count for the pipelined engine, or a
    telemetry record claiming HLO parity it doesn't have — is a finding."""
    return (_registry_findings() + _bench_overlap_findings(root)
            + _bench_fused_findings(root) + _bench_telemetry_findings(root)
            + _bench_scenarios_findings(root))
