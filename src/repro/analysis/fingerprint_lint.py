"""Fingerprint-coverage lint: every ChocoConfig field is accounted for.

The checkpoint manifest fingerprint (``DecentralizedTrainer.fingerprint``)
is the contract that decides whether a restore is resume-exact, elastic,
or refused.  A ChocoConfig field that silently falls outside it is a
correctness hazard: a resumed run could change, say, a compression knob
and keep error-feedback state built under a different omega.  This pass
closes that hole *statically*:

    every field of ``ChocoConfig`` must either be read by
    ``fingerprint()`` (directly, or by a helper method it calls), or be
    named in the trainer's ``FINGERPRINT_EXEMPT`` allowlist with a
    non-empty reason string.

Everything is AST — the pass never imports the trainer (no jax), so it
runs in the fast tier and works on scratch fixture trees via ``root``.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Set, Tuple

from repro.analysis.findings import Finding

CONFIG_REL = "src/repro/configs/base.py"
TRAINER_REL = "src/repro/train/trainer.py"
CONFIG_CLASS = "ChocoConfig"
TRAINER_CLASS = "DecentralizedTrainer"
FINGERPRINT_METHOD = "fingerprint"
EXEMPT_NAME = "FINGERPRINT_EXEMPT"


def _parse(root: str, rel: str):
    path = os.path.join(root, *rel.split("/"))
    if not os.path.exists(path):
        return None
    with open(path, encoding="utf-8") as f:
        return ast.parse(f.read(), filename=path)


def _find_class(tree: ast.Module, name: str):
    return next((n for n in tree.body
                 if isinstance(n, ast.ClassDef) and n.name == name), None)


def choco_config_fields(root: str,
                        config_rel: str = CONFIG_REL) -> Dict[str, int]:
    """``{field_name: lineno}`` for every annotated ChocoConfig field."""
    tree = _parse(root, config_rel)
    cls = _find_class(tree, CONFIG_CLASS) if tree else None
    if cls is None:
        return {}
    return {n.target.id: n.lineno for n in cls.body
            if isinstance(n, ast.AnnAssign) and isinstance(n.target, ast.Name)}


def _choco_attrs(fn: ast.FunctionDef) -> Set[str]:
    """Names X accessed as ``self.choco.X`` anywhere in a method body."""
    out = set()
    for node in ast.walk(fn):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Attribute)
                and node.value.attr == "choco"
                and isinstance(node.value.value, ast.Name)
                and node.value.value.id == "self"):
            out.add(node.attr)
    return out


def fingerprinted_fields(root: str,
                         trainer_rel: str = TRAINER_REL) -> Set[str]:
    """ChocoConfig attrs read by ``fingerprint()`` — including, one call
    hop deep, the ``self.<helper>()`` methods it delegates to (e.g.
    ``_effective_staleness`` reads ``max_staleness``)."""
    tree = _parse(root, trainer_rel)
    cls = _find_class(tree, TRAINER_CLASS) if tree else None
    if cls is None:
        return set()
    methods = {n.name: n for n in cls.body if isinstance(n, ast.FunctionDef)}
    fp = methods.get(FINGERPRINT_METHOD)
    if fp is None:
        return set()
    fields = _choco_attrs(fp)
    for node in ast.walk(fp):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "self"
                and node.func.attr in methods):
            fields |= _choco_attrs(methods[node.func.attr])
    return fields


def exempt_fields(root: str, trainer_rel: str = TRAINER_REL
                  ) -> Tuple[Dict[str, str], List[Finding]]:
    """Parse the module-level ``FINGERPRINT_EXEMPT`` dict literal.

    Returns ``({field: reason}, findings)`` — malformed entries (non-string
    keys, empty reasons) become findings rather than exemptions.
    """
    tree = _parse(root, trainer_rel)
    if tree is None:
        return {}, []
    node = next((n.value for n in tree.body if isinstance(n, ast.Assign)
                 for t in n.targets
                 if isinstance(t, ast.Name) and t.id == EXEMPT_NAME), None)
    if not isinstance(node, ast.Dict):
        return {}, []
    exempt, findings = {}, []
    for k, v in zip(node.keys, node.values):
        key = k.value if isinstance(k, ast.Constant) else None
        reason = v.value if isinstance(v, ast.Constant) else None
        if not isinstance(key, str) or not isinstance(reason, str) \
                or not reason.strip():
            findings.append(Finding(
                "fingerprint", trainer_rel, getattr(k, "lineno", 0),
                f"{EXEMPT_NAME} entries must map a field-name string to a "
                f"non-empty reason string"))
            continue
        exempt[key] = reason
    return exempt, findings


def run_fingerprint_lint(root: str, config_rel: str = CONFIG_REL,
                         trainer_rel: str = TRAINER_REL) -> List[Finding]:
    """The full coverage check: every ChocoConfig field fingerprinted XOR
    exempt-with-reason; exemptions must name real, un-fingerprinted
    fields."""
    fields = choco_config_fields(root, config_rel)
    if not fields:
        return [Finding("fingerprint", config_rel, 0,
                        f"could not locate {CONFIG_CLASS} fields — the "
                        f"fingerprint-coverage contract has nothing to "
                        f"check against")]
    fingerprinted = fingerprinted_fields(root, trainer_rel)
    exempt, findings = exempt_fields(root, trainer_rel)
    for name, lineno in sorted(fields.items()):
        in_fp, in_ex = name in fingerprinted, name in exempt
        if in_fp and in_ex:
            findings.append(Finding(
                "fingerprint", trainer_rel, 0,
                f"ChocoConfig.{name} is both fingerprinted and listed in "
                f"{EXEMPT_NAME} — drop the stale exemption"))
        elif not in_fp and not in_ex:
            findings.append(Finding(
                "fingerprint", config_rel, lineno,
                f"ChocoConfig.{name} is not covered by "
                f"{TRAINER_CLASS}.{FINGERPRINT_METHOD}() and has no "
                f"{EXEMPT_NAME} entry: a resumed run could change it "
                f"without the restore path noticing — fingerprint it, or "
                f"exempt it with a reason"))
    for name in sorted(exempt):
        if name not in fields:
            findings.append(Finding(
                "fingerprint", trainer_rel, 0,
                f"{EXEMPT_NAME} names {name!r}, which is not a "
                f"ChocoConfig field — stale exemption"))
    return findings
