"""Render dry-run JSONL records into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def load(path: str) -> List[Dict]:
    """Load one dry-run JSONL file into a list of record dicts."""
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    return recs


def fmt_bytes(b):
    """Human-readable byte count ("1.5MB"); "-" for missing values."""
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def roofline_table(recs: List[Dict]) -> str:
    """Markdown roofline table (one row per arch x shape, skips/fails
    annotated) in the EXPERIMENTS.md format."""
    hdr = ("| arch | shape | status | compute s | memory s | collective s | "
           "dominant | useful | state GB/dev | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | skip | - | - | - | - | - | - | "
                        f"{r['reason']} |")
            continue
        if r["status"] == "fail":
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | - | - | - | - | - | - | "
                        f"{r.get('error', '')[:60]} |")
            continue
        rl = r.get("roofline", {})
        mem = r.get("memory", {})
        gb = mem.get("analytic_arg_bytes_per_device")
        gb = f"{gb / 2**30:.2f}" if gb else "-"
        useful = rl.get("useful_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {rl.get('compute_s', 0):.4f} | "
            f"{rl.get('memory_s', 0):.3f} | {rl.get('collective_s', 0):.4f} | "
            f"{rl.get('dominant', '-')} | {useful and f'{useful:.2f}' or '-'} | {gb} | |")
    return hdr + "\n".join(rows) + "\n"


def dominant_summary(recs: List[Dict]) -> str:
    """One-line count of which roofline term dominates across records."""
    from collections import Counter
    c = Counter(r["roofline"]["dominant"] for r in recs
                if r["status"] == "ok" and "roofline" in r)
    return ", ".join(f"{k}: {v}" for k, v in c.most_common())


if __name__ == "__main__":
    for p in sys.argv[1:]:
        recs = load(p)
        print(f"## {p}")
        print(roofline_table(recs))
        print("dominant terms:", dominant_summary(recs))
