"""AST lint pass: repo contracts that used to exist only as prose.

Four contracts, all checked purely from source text (no imports, no jax —
the pass runs in milliseconds and works on scratch fixture trees):

* **jax-free-at-import** — the modules the CLI must be able to import
  before XLA_FLAGS is frozen by the first jax import
  (``launch/train.py``, ``launch/serve.py``, ``launch/env.py``,
  ``kernels/dispatch.py``, the host-side ``obs`` modules, and everything
  under ``configs/``) must not import jax at module scope.
* **traced purity** — no wall-clock (``time.time`` & friends), stdlib
  ``random``, global-state ``np.random``, or ``open()`` file-I/O calls
  anywhere in ``comm/``, ``core/``, or ``obs/``: the round functions
  there are traced, and a host-side RNG, clock, or file handle inside
  them either bakes a constant into the compiled step or breaks the
  shared-seed determinism contract (docs/ARCHITECTURE.md).  Explicitly
  seeded ``np.random.default_rng`` is allowed — it is deterministic,
  host-side builder code.  The obs sink/timer/trace modules are
  host-side *by design* (wall clocks and file writes are their whole
  job) and sit on :data:`TRACED_PURITY_EXEMPT`; only the traced
  ``obs/metrics.py`` is held to the contract.
* **fail-fast ordering** — every ``SystemExit(2)`` fail-fast in
  ``launch/train.py::main`` (``parser.error`` calls and literal raises)
  must execute before the function's first ``import jax``: a validation
  error that fires after device init is not fail-fast.
* **docstring coverage** — every module under ``src/repro`` carries a
  module docstring, and every public top-level function/class is
  documented.  Dataclasses and NamedTuples are exempt from the *class*
  docstring requirement (their auto ``__doc__`` is the constructor
  signature — the same semantics as the historical ``inspect.getdoc``
  gate in ``tests/test_docs.py``, which now delegates here).
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Optional, Tuple

from repro.analysis.findings import Finding

#: modules (relative to src/repro) whose MODULE SCOPE must stay jax-free;
#: a trailing "/" gates every .py file under that directory
JAX_FREE_AT_IMPORT = ("launch/train.py", "launch/serve.py", "launch/env.py",
                      "kernels/dispatch.py", "configs/",
                      "obs/__init__.py", "obs/schema.py", "obs/sinks.py",
                      "obs/timers.py", "obs/trace.py")

#: packages whose source is held to the traced-purity contract
TRACED_PACKAGES = ("comm", "core", "obs")

#: files inside TRACED_PACKAGES that are host-side by design (metric
#: sinks, step timers, profiler drivers): wall clocks and file I/O are
#: their job, so the purity contract skips them — everything else under
#: obs (notably the traced obs/metrics.py) stays gated
TRACED_PURITY_EXEMPT = ("obs/sinks.py", "obs/timers.py", "obs/trace.py")

#: time-module attributes that read the wall clock
_CLOCK_CALLS = ("time", "perf_counter", "monotonic", "time_ns",
                "perf_counter_ns", "monotonic_ns", "clock")


def _src_repro(root: str) -> str:
    return os.path.join(root, "src", "repro")


def _rel(root: str, path: str) -> str:
    return os.path.relpath(path, root).replace(os.sep, "/")


def _parse(path: str) -> Optional[ast.Module]:
    try:
        with open(path, encoding="utf-8") as f:
            return ast.parse(f.read(), filename=path)
    except SyntaxError:
        return None


def _python_files(base: str) -> List[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(base):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                out.append(os.path.join(dirpath, fn))
    return out


# ---------------------------------------------------------------------------
# contract 1: jax-free at import
# ---------------------------------------------------------------------------

def _module_scope_imports(tree: ast.Module) -> Iterable[ast.stmt]:
    """Import nodes executed at import time: the module body plus
    module-level If/Try/With bodies — but never function/class bodies, and
    never ``if TYPE_CHECKING:`` blocks (those don't run at import)."""
    stack: List[ast.stmt] = list(tree.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            yield node
        elif isinstance(node, ast.If):
            test = node.test
            is_tc = (isinstance(test, ast.Name) and test.id == "TYPE_CHECKING") \
                or (isinstance(test, ast.Attribute)
                    and test.attr == "TYPE_CHECKING")
            if not is_tc:
                stack.extend(node.body)
            stack.extend(node.orelse)
        elif isinstance(node, ast.Try):
            stack.extend(node.body + node.orelse + node.finalbody)
            for h in node.handlers:
                stack.extend(h.body)
        elif isinstance(node, ast.With):
            stack.extend(node.body)


def _imports_jax(node: ast.stmt) -> bool:
    if isinstance(node, ast.Import):
        return any(a.name == "jax" or a.name.startswith("jax.")
                   for a in node.names)
    if isinstance(node, ast.ImportFrom):
        mod = node.module or ""
        return mod == "jax" or mod.startswith("jax.")
    return False


def _gated_files(root: str) -> List[str]:
    base = _src_repro(root)
    out = []
    for entry in JAX_FREE_AT_IMPORT:
        path = os.path.join(base, *entry.split("/"))
        if entry.endswith("/"):
            if os.path.isdir(path):
                out.extend(_python_files(path))
        elif os.path.exists(path):
            out.append(path)
    return out


def lint_jax_free(root: str) -> List[Finding]:
    """jax-free-at-import findings for the gated module set."""
    findings = []
    for path in _gated_files(root):
        tree = _parse(path)
        if tree is None:
            continue
        for node in _module_scope_imports(tree):
            if _imports_jax(node):
                findings.append(Finding(
                    "source", _rel(root, path), node.lineno,
                    "module-scope jax import in a jax-free-at-import gated "
                    "module: the CLI fail-fast matrix and XLA_FLAGS setup "
                    "import this file before jax — move the import inside "
                    "the function that needs it"))
    return findings


# ---------------------------------------------------------------------------
# contract 2: traced purity (comm/ + core/)
# ---------------------------------------------------------------------------

def _stdlib_rng_aliases(tree: ast.Module) -> Tuple[set, set, set]:
    """(time aliases, stdlib-random aliases, numpy aliases) bound at module
    scope.  ``from jax import random`` binds jax.random, not the stdlib
    module, so it never lands in the random set."""
    time_names, random_names, numpy_names = set(), set(), set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                bound = a.asname or a.name.split(".")[0]
                if a.name == "time" or a.name.startswith("time."):
                    time_names.add(bound)
                elif a.name == "random":
                    random_names.add(bound)
                elif a.name == "numpy" or a.name.startswith("numpy."):
                    numpy_names.add(bound)
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod in ("numpy",):
                for a in node.names:
                    if a.name == "random":
                        numpy_names.add("__numpy_random_direct__")
    return time_names, random_names, numpy_names


def lint_traced_purity(root: str,
                       packages: Tuple[str, ...] = TRACED_PACKAGES
                       ) -> List[Finding]:
    """Purity findings for the traced packages: wall-clock reads, stdlib
    ``random``, global-state ``np.random``, and ``open()`` file-I/O calls
    (seeded ``np.random.default_rng`` is explicitly allowed; the
    host-side obs modules on :data:`TRACED_PURITY_EXEMPT` are skipped)."""
    findings = []
    for pkg in packages:
        for path in _python_files(os.path.join(_src_repro(root), pkg)):
            rel_in_src = os.path.relpath(
                path, _src_repro(root)).replace(os.sep, "/")
            if rel_in_src in TRACED_PURITY_EXEMPT:
                continue
            tree = _parse(path)
            if tree is None:
                continue
            time_names, random_names, numpy_names = _stdlib_rng_aliases(tree)
            for node in ast.walk(tree):
                if not isinstance(node, ast.Call):
                    continue
                fn = node.func
                if isinstance(fn, ast.Name) and fn.id == "open":
                    findings.append(Finding(
                        "source", _rel(root, path), node.lineno,
                        "open() in a traced package: file I/O belongs in "
                        "the host-side sink modules (obs/sinks.py, "
                        "checkpoint/), never in traced round functions"))
                    continue
                if not isinstance(fn, ast.Attribute):
                    continue
                msg = None
                if (isinstance(fn.value, ast.Name)
                        and fn.value.id in time_names
                        and fn.attr in _CLOCK_CALLS):
                    msg = (f"wall-clock call time.{fn.attr}() in a traced "
                           f"package: a clock read inside a jitted round "
                           f"function bakes a constant into the compiled "
                           f"step — time benchmarks in benchmarks/, not "
                           f"here")
                elif (isinstance(fn.value, ast.Name)
                      and fn.value.id in random_names):
                    msg = (f"stdlib random.{fn.attr}() in a traced package: "
                           f"host RNG breaks the shared-seed determinism "
                           f"contract — every draw must come from the "
                           f"exchange key (jax.random.fold_in)")
                elif (isinstance(fn.value, ast.Attribute)
                      and fn.value.attr == "random"
                      and isinstance(fn.value.value, ast.Name)
                      and fn.value.value.id in numpy_names
                      and fn.attr != "default_rng"):
                    msg = (f"np.random.{fn.attr}() in a traced package: "
                           f"global-state numpy RNG is neither traceable "
                           f"nor seed-reproducible — use the exchange key, "
                           f"or a seeded np.random.default_rng for "
                           f"host-side builders")
                if msg:
                    findings.append(Finding("source", _rel(root, path),
                                            node.lineno, msg))
    return findings


# ---------------------------------------------------------------------------
# contract 3: fail-fast ordering in launch/train.py::main
# ---------------------------------------------------------------------------

def lint_failfast_order(root: str,
                        rel_path: str = "launch/train.py",
                        func: str = "main") -> List[Finding]:
    """Every ``parser.error`` / ``raise SystemExit(2)`` in the launcher's
    ``main`` must precede the function's first ``import jax``."""
    path = os.path.join(_src_repro(root), *rel_path.split("/"))
    tree = _parse(path) if os.path.exists(path) else None
    if tree is None:
        return []
    main_fn = next((n for n in tree.body
                    if isinstance(n, ast.FunctionDef) and n.name == func),
                   None)
    if main_fn is None:
        return []
    jax_line = None
    for node in ast.walk(main_fn):
        if isinstance(node, (ast.Import, ast.ImportFrom)) \
                and _imports_jax(node):
            jax_line = node.lineno if jax_line is None \
                else min(jax_line, node.lineno)
    if jax_line is None:
        return []
    parser_names = set()
    for node in ast.walk(main_fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            callee = node.value.func
            name = callee.attr if isinstance(callee, ast.Attribute) \
                else callee.id if isinstance(callee, ast.Name) else ""
            if name == "ArgumentParser":
                parser_names.update(t.id for t in node.targets
                                    if isinstance(t, ast.Name))
    findings = []
    for node in ast.walk(main_fn):
        late = None
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
                and node.func.attr == "error"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id in parser_names):
            late = f"{node.func.value.id}.error(...)"
        elif (isinstance(node, ast.Raise) and isinstance(node.exc, ast.Call)
              and isinstance(node.exc.func, ast.Name)
              and node.exc.func.id == "SystemExit"
              and node.exc.args
              and isinstance(node.exc.args[0], ast.Constant)
              and node.exc.args[0].value == 2):
            late = "raise SystemExit(2)"
        if late and node.lineno > jax_line:
            findings.append(Finding(
                "source", _rel(root, path), node.lineno,
                f"{late} after the first `import jax` (line {jax_line}): "
                f"fail-fast validation must run pre-jax, before XLA_FLAGS "
                f"freeze and device init"))
    return findings


# ---------------------------------------------------------------------------
# contract 4: docstring coverage, all src/repro packages
# ---------------------------------------------------------------------------

def _is_auto_documented_class(node: ast.ClassDef) -> bool:
    """Dataclasses and NamedTuples synthesize a ``__doc__`` (the
    constructor signature), which the historical ``inspect.getdoc`` gate
    accepted — keep that semantics."""
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = target.attr if isinstance(target, ast.Attribute) \
            else target.id if isinstance(target, ast.Name) else ""
        if name == "dataclass":
            return True
    for base in node.bases:
        name = base.attr if isinstance(base, ast.Attribute) \
            else base.id if isinstance(base, ast.Name) else ""
        if name == "NamedTuple":
            return True
    return False


def repro_packages(root: str) -> List[str]:
    """Every package directory under src/repro (sorted)."""
    base = _src_repro(root)
    if not os.path.isdir(base):
        return []
    return sorted(d for d in os.listdir(base)
                  if os.path.isdir(os.path.join(base, d))
                  and d != "__pycache__")


def docstring_findings(root: str,
                       packages: Optional[Iterable[str]] = None
                       ) -> List[Finding]:
    """Missing-docstring findings for the given packages (default: every
    package under src/repro)."""
    pkgs = list(packages) if packages is not None else repro_packages(root)
    findings = []
    for pkg in pkgs:
        for path in _python_files(os.path.join(_src_repro(root), pkg)):
            tree = _parse(path)
            if tree is None:
                continue
            rel = _rel(root, path)
            if not (ast.get_docstring(tree) or "").strip():
                findings.append(Finding(
                    "source", rel, 1, "missing module docstring"))
            for node in tree.body:
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef, ast.ClassDef)):
                    continue
                if node.name.startswith("_"):
                    continue
                if isinstance(node, ast.ClassDef) \
                        and _is_auto_documented_class(node):
                    continue
                if not (ast.get_docstring(node) or "").strip():
                    kind = "class" if isinstance(node, ast.ClassDef) \
                        else "function"
                    findings.append(Finding(
                        "source", rel, node.lineno,
                        f"missing docstring on public {kind} "
                        f"`{node.name}`"))
    return findings


def run_source_lint(root: str) -> List[Finding]:
    """All four source contracts over one repo root."""
    return (lint_jax_free(root) + lint_traced_purity(root)
            + lint_failfast_order(root) + docstring_findings(root))
