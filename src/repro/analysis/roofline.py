"""Roofline analysis from compiled XLA artifacts (no hardware required).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs            / (peak_FLOP/s)         [per device]
    memory     = HLO_bytes_accessed   / HBM_bw                [per device]
    collective = wire_bytes           / ICI_bw                [per device]

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (per-device program).
Wire bytes are parsed from ``compiled.as_text()`` by summing the shaped
outputs of every collective op with the per-op wire-cost convention:

    all-gather          bytes(output) * (g-1)/g     (ring algorithm)
    reduce-scatter      bytes(input)  * (g-1)/g ~= bytes(output)*(g-1)
    all-reduce          2 * bytes(buffer) * (g-1)/g (RS + AG)
    all-to-all          bytes(output) * (g-1)/g
    collective-permute  bytes(output)               (point-to-point)

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12
#: HBM bandwidth used by every roofline/traffic model in the repo — the
#: single source of truth (benchmarks/common.py imports it from here).
#: Override for other parts with REPRO_HBM_BW (bytes/s).
HBM_BW = float(os.environ.get("REPRO_HBM_BW", 819e9))
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g.:  %ag = bf16[16,2048]{1,0} all-gather(...), replica_groups={{0,1},..}
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")(?:-start)?\(")

_TUPLE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:                       # iota format [n_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    buffer_bytes: Dict[str, int]      # summed shaped bytes per op kind
    wire_bytes: Dict[str, float]      # per-device wire traffic per op kind

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str, n_devices: int = 256) -> CollectiveStats:
    """Parse collective ops out of HLO text into :class:`CollectiveStats`.

    Counts each launch once (async ``-done`` halves are skipped), sums the
    shaped buffer bytes per op kind, and applies the module-docstring wire
    conventions to estimate per-device wire traffic.  ``n_devices`` is the
    fallback group size when a line carries no ``replica_groups``.
    """
    counts = {k: 0 for k in _COLLECTIVES}
    buf = {k: 0 for k in _COLLECTIVES}
    wire = {k: 0.0 for k in _COLLECTIVES}
    seen_done = set()
    for line in hlo_text.splitlines():
        s = line.strip()
        if not any(c in s for c in _COLLECTIVES):
            continue
        if re.search(r"(all-gather|all-reduce|collective-permute|all-to-all|reduce-scatter)-done", s):
            continue                                  # async pair: count start only
        m = _OP_RE.search(s)
        if not m:
            continue
        kind = m.group(3)
        # tuple-shaped outputs: sum every element shape on the line's LHS
        lhs = s.split(kind)[0]
        shapes = _TUPLE_RE.findall(lhs)
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes) or \
            _shape_bytes(m.group(1), m.group(2))
        g = max(_group_size(s, n_devices), 1)
        counts[kind] += 1
        buf[kind] += nbytes
        frac = (g - 1) / g
        if kind == "all-gather":
            wire[kind] += nbytes * frac
        elif kind == "all-reduce":
            wire[kind] += 2 * nbytes * frac
        elif kind == "reduce-scatter":
            wire[kind] += nbytes * frac
        elif kind == "all-to-all":
            wire[kind] += nbytes * frac
        else:  # collective-permute: point-to-point
            wire[kind] += nbytes
    return CollectiveStats(counts, buf, wire)


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    bytes_accessed: float        # per-device HLO bytes
    wire_bytes: float            # per-device collective bytes
    n_devices: int
    model_flops: float           # analytic useful flops (whole step, all devices)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.flops / PEAK_FLOPS
        self.memory_s = self.bytes_accessed / HBM_BW
        self.collective_s = self.wire_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flop_ratio(self) -> Optional[float]:
        total = self.flops * self.n_devices
        return self.model_flops / total if total else None

    @property
    def step_time_s(self) -> float:
        """Simple max-of-terms roofline estimate."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def row(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "bytes_per_dev": self.bytes_accessed,
            "wire_bytes_per_dev": self.wire_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flop_ratio,
            "roofline_step_s": self.step_time_s,
        }


def analyze(compiled, *, n_devices: int, model_flops: float) -> "tuple[Roofline, CollectiveStats]":
    """Roofline a compiled executable: cost_analysis() flops/bytes plus the
    parsed collective wire bytes, under the module's hardware constants."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text(), n_devices)
    rl = Roofline(flops=flops, bytes_accessed=byts,
                  wire_bytes=stats.total_wire_bytes, n_devices=n_devices,
                  model_flops=model_flops)
    return rl, stats


def model_flops_for(cfg, shape) -> float:
    """Analytic useful FLOPs per step: 6 N D (train) / 2 N D (inference),
    N = active params (exact, via eval_shape), D = tokens processed."""
    from repro.models.transformer import count_active_params
    n = count_active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch     # decode: one token per sequence
    return 2.0 * n * tokens
