"""Metrics-schema lint pass: the registry in ``obs/schema.py`` and the
emit sites agree, statically.

:func:`validate_record` already rejects unregistered keys at runtime —
but only on code paths a test actually runs.  This pass closes the gap
from source text alone (no imports, works on scratch fixture roots):

* the ``METRIC_SPECS`` literal is well-formed — every entry a
  ``MetricSpec`` call with a constant ``<namespace>/<snake_case>`` name,
  non-empty units and description, no duplicates;
* every metric-key string literal in the emitting packages (``obs``,
  ``launch``, ``train``) is registered — an unregistered emit is a
  finding at the emit site;
* every registered name is emitted somewhere — a stale registry entry
  (metric renamed or deleted without pruning the schema) is a finding at
  its ``MetricSpec`` line.

Only string literals whose namespace prefix is registered count as emit
sites, so ordinary path-ish strings (``"launch/env"``) never false-
positive unless they collide with a live metric namespace — which is the
collision the pass exists to surface.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Tuple

from repro.analysis.findings import Finding

#: where the registry literal lives, relative to src/repro
SCHEMA_REL = "obs/schema.py"

#: packages whose string literals are scanned as candidate emit sites
EMIT_PACKAGES = ("obs", "launch", "train")

#: mirrors obs.schema.METRIC_KEY_RE (kept literal: this pass must not
#: import the module it lints)
_KEY_RE = re.compile(r"^[a-z]+/[a-z0-9_]+$")


def _parse(path: str) -> Optional[ast.Module]:
    try:
        with open(path, encoding="utf-8") as f:
            return ast.parse(f.read(), filename=path)
    except (SyntaxError, OSError):
        return None


def _const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _spec_entries(tree: ast.Module, rel: str
                  ) -> Tuple[Dict[str, int], List[Finding]]:
    """(registered name -> lineno, findings) from the METRIC_SPECS
    literal.  A missing or non-tuple METRIC_SPECS is itself a finding —
    the registry is load-bearing."""
    assign = None
    for node in tree.body:
        targets = (node.targets if isinstance(node, ast.Assign)
                   else [node.target] if isinstance(node, ast.AnnAssign)
                   else [])
        if any(isinstance(t, ast.Name) and t.id == "METRIC_SPECS"
               for t in targets):
            assign = node
    if assign is None or not isinstance(assign.value, (ast.Tuple, ast.List)):
        return {}, [Finding("metrics", rel, 1,
                            "METRIC_SPECS tuple literal not found: the "
                            "metric registry must stay a parseable literal")]
    names: Dict[str, int] = {}
    findings: List[Finding] = []
    for el in assign.value.elts:
        line = getattr(el, "lineno", assign.lineno)
        if not (isinstance(el, ast.Call) and len(el.args) == 3
                and not el.keywords):
            findings.append(Finding(
                "metrics", rel, line,
                "malformed registry entry: expected "
                "MetricSpec(name, units, description) with three "
                "positional string literals"))
            continue
        name, units, desc = (_const_str(a) for a in el.args)
        if name is None or units is None or desc is None:
            findings.append(Finding(
                "metrics", rel, line,
                "registry entry fields must be string literals (the pass "
                "reads them without importing the module)"))
            continue
        if not _KEY_RE.match(name):
            findings.append(Finding(
                "metrics", rel, line,
                f"metric name {name!r} does not match "
                f"<namespace>/<snake_case> ({_KEY_RE.pattern})"))
            continue
        if not units.strip() or not desc.strip():
            findings.append(Finding(
                "metrics", rel, line,
                f"metric {name!r} needs non-empty units and description "
                f"(the registry is the documentation of record)"))
        if name in names:
            findings.append(Finding(
                "metrics", rel, line, f"duplicate metric name {name!r}"))
            continue
        names[name] = line
    return names, findings


def _python_files(base: str) -> List[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(base):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                out.append(os.path.join(dirpath, fn))
    return out


def run_metrics_lint(root: str) -> List[Finding]:
    """The full pass over one repo root: registry shape, unregistered
    emits, stale entries."""
    src = os.path.join(root, "src", "repro")
    schema_path = os.path.join(src, *SCHEMA_REL.split("/"))
    if not os.path.exists(schema_path):
        return []    # fixture roots without an obs package have no contract
    tree = _parse(schema_path)
    if tree is None:
        return [Finding("metrics", f"src/repro/{SCHEMA_REL}", 1,
                        "schema module failed to parse")]
    rel_schema = f"src/repro/{SCHEMA_REL}"
    names, findings = _spec_entries(tree, rel_schema)
    namespaces = {n.split("/", 1)[0] for n in names}

    emitted: Dict[str, Tuple[str, int]] = {}
    for pkg in EMIT_PACKAGES:
        for path in _python_files(os.path.join(src, pkg)):
            if os.path.abspath(path) == os.path.abspath(schema_path):
                continue
            mod = _parse(path)
            if mod is None:
                continue
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            for node in ast.walk(mod):
                key = _const_str(node)
                if key is None or not _KEY_RE.match(key):
                    continue
                if key.split("/", 1)[0] not in namespaces:
                    continue
                if key not in names:
                    findings.append(Finding(
                        "metrics", rel, node.lineno,
                        f"emitted metric key {key!r} is not registered: "
                        f"add a MetricSpec (name, units, description) to "
                        f"obs/schema.py"))
                emitted.setdefault(key, (rel, node.lineno))
    for name, line in names.items():
        if name not in emitted:
            findings.append(Finding(
                "metrics", rel_schema, line,
                f"stale registry entry {name!r}: no emit site in "
                f"{'/'.join(EMIT_PACKAGES)} references it — prune it or "
                f"wire the metric up"))
    return findings
