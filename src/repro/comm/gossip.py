"""Distributed CHOCO gossip over a device mesh, driven by compiled schedules.

The gossip graph lives on one or more mesh axes (``axes``): every slice of
the mesh along those axes is one "node" of the paper's communication graph.
The exchange is implemented inside ``shard_map`` with ``jax.lax.ppermute``
of the *compressed payload only* — the collective bytes in the compiled HLO
are the paper's transmitted bits.  Every tensor-parallel / FSDP shard
compresses and gossips its own slice (coordinate-wise operators commute with
sharding).

Which neighbours exchange, in how many rounds, with what weights, is no
longer hardcoded: a :class:`~repro.comm.schedule.GossipSchedule` (compiled
once, pure Python, from any ``core.topology.Topology``) lists the
permutation rounds of W − I, and this engine replays them — one
``lax.ppermute`` per round, every round reusing the same packed payloads.
Ring and torus are now just two compiled schedules; hypercube, star, chain,
fully-connected, and arbitrary W (via greedy edge coloring) run through the
identical code path.  A *sequence* of schedules gives time-varying mixing,
cycled across the ``gossip_steps`` consensus rounds of each SGD step
(multiple gossip rounds per step: Hashemi et al., NeurIPS 2020).

Two engines for the choco exchange:
  * ``packed`` (default) — the bucketed flat-buffer engine (comm/packing.py):
    the whole pytree is packed into a few dtype-homogeneous buckets, each
    compressed ONCE and shipped as ONE static-shape payload per neighbour —
    a handful of collective-permutes per round regardless of leaf count;
  * ``per-leaf`` (legacy) — compress + ppermute every leaf separately; kept
    as the reference/bench baseline (see benchmarks/bench_collectives.py).

Three exchange modes:
  * ``choco``     — Algorithm 2 lines 4-9 (compressed, error-feedback)
  * ``plain``     — Algorithm 3 line 4-5 (exact neighbour averaging)
  * ``allreduce`` — centralized mini-batch SGD baseline (pmean over the axes)
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compression import Compressor
from repro.comm.schedule import GossipSchedule

# jax.shard_map landed in 0.5.x; on 0.4.x the same function lives under
# jax.experimental.shard_map.  Resolve once at import time.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map


def _leaf_keys(key, n: int, salt: int):
    return jax.random.split(jax.random.fold_in(key, salt), n)


# Leaves larger than this are compressed row-blockwise: reshape to (R, BLOCK)
# and vmap the operator per row.  Identical omega guarantee (Assumption 1 per
# block), avoids int32 overflow in lax.top_k for multi-billion-element expert
# stacks, and matches the Pallas block-topk kernel's TPU-native semantics.
BLOCK_COMPRESS_SIZE = 1 << 22


def _compress_leaf(compressor: Compressor, key, flat):
    """Returns (payload, dense_fn) where dense_fn(payload) -> flat dense q."""
    d = flat.size
    if d <= BLOCK_COMPRESS_SIZE:
        pl_ = compressor.compress(key, flat)
        return pl_, lambda p: p.dense()
    C = BLOCK_COMPRESS_SIZE
    R = -(-d // C)
    padded = jnp.pad(flat, (0, R * C - d))
    rows = padded.reshape(R, C)
    if compressor.stochastic:
        keys = jax.random.split(key, R)
        pl_ = jax.vmap(compressor.compress)(keys, rows)
    else:
        pl_ = jax.vmap(lambda r: compressor.compress(None, r))(rows)

    def dense_fn(p):
        return jax.vmap(lambda q: q.dense())(p).reshape(R * C)[:d]

    return pl_, dense_fn


def _pack_align(compressor: Optional[Compressor], pack_align: Optional[int]):
    """Segment alignment for the packed engine: the compressor's block width
    for blockwise operators (so bucket compression commutes with packing),
    the 128-lane unit otherwise."""
    block = getattr(compressor, "block", None)
    if pack_align is None:
        return block or 128
    if block and pack_align % block != 0:
        raise ValueError(
            f"pack_align={pack_align} must be a multiple of the compressor's "
            f"block width {block}: blockwise selection must never straddle "
            f"leaf segments, or packed != per-leaf compression")
    return pack_align


def _leaf_routes(state_specs, gossip_axes) -> Optional[list]:
    """Per-leaf bucket-routing keys from the exchange's PartitionSpecs: the
    set of NON-gossip mesh axes each leaf is sharded over.  Leaves sharded
    differently (e.g. model-sharded weights vs model-replicated norm scales)
    must not share a bucket — bucket-level selection and scales would differ
    across those shards and de-replicate the replicated leaves."""
    if state_specs is None:
        return None
    gset = set(gossip_axes if isinstance(gossip_axes, (tuple, list))
               else (gossip_axes,))
    specs = jax.tree_util.tree_leaves(
        state_specs, is_leaf=lambda x: isinstance(x, P))
    routes = []
    for sp in specs:
        axes = set()
        if isinstance(sp, P):
            for entry in sp:
                if entry is None:
                    continue
                for a in (entry if isinstance(entry, tuple) else (entry,)):
                    axes.add(a)
        routes.append(tuple(sorted(axes - gset)))
    return routes


def _flatten_states(x_half, x_hat, s):
    leaves_h, treedef = jax.tree_util.tree_flatten(x_half)
    leaves_hat = treedef.flatten_up_to(x_hat)
    leaves_s = treedef.flatten_up_to(s)
    return leaves_h, leaves_hat, leaves_s, treedef


def _packed_self_half(compressor, key, leaves_h, leaves_hat, spec):
    """Shared first half of a packed choco round: deltas -> payloads,
    per-leaf dense q, and the updated public copies x_hat."""
    from repro.comm.packing import compress_packed
    deltas = [(lh.astype(lhat.dtype) - lhat).ravel()
              for lh, lhat in zip(leaves_h, leaves_hat)]
    payloads, q_leaves = compress_packed(compressor, key, spec, deltas)
    new_hat = [lhat + q.reshape(lh.shape).astype(lhat.dtype)
               for lh, lhat, q in zip(leaves_h, leaves_hat, q_leaves)]
    return payloads, q_leaves, new_hat


def _choco_leaf_updates(leaves_h, leaves_s, q_leaves, nbr_leaves, new_hat,
                        w_self, w_nbr, gamma):
    """Algorithm 5 lines 8-10, per leaf (elementwise; XLA fuses these)."""
    new_s, new_x = [], []
    for lh, ls, qd, nb, nh in zip(leaves_h, leaves_s, q_leaves, nbr_leaves,
                                  new_hat):
        sn = ls + (w_self * qd + w_nbr * nb).reshape(lh.shape).astype(ls.dtype)
        new_s.append(sn)
        new_x.append(lh + gamma * (sn - nh).astype(lh.dtype))
    return new_s, new_x


# ---------------------------------------------------------------------------
# schedule plumbing
# ---------------------------------------------------------------------------

def _weight_groups(schedule: GossipSchedule):
    """Consecutive rounds sharing one receive weight merge into a group:
    their dense payloads accumulate unweighted and the weight applies once.
    (A uniform ring's +1/-1 shifts are one group — reproducing the
    pre-schedule engine's ``w_nbr * (left + right)`` arithmetic exactly.)"""
    groups = []
    for rnd in schedule.rounds:
        wkey = rnd.weight if rnd.weight is not None else rnd.weights
        if groups and groups[-1][0] == wkey:
            groups[-1][1].append(rnd.perm)
        else:
            groups.append([wkey, [rnd.perm]])
    return [(w, tuple(perms)) for w, perms in groups]


def _flat_node_index(axes: Tuple[str, ...], sizes: Tuple[int, ...]):
    """Row-major flat node id over the gossip axes — matches ppermute's
    flattening of a tuple axis name."""
    idx = jax.lax.axis_index(axes[0])
    for a, sz in zip(axes[1:], sizes[1:]):
        idx = idx * sz + jax.lax.axis_index(a)
    return idx


def _weight_value(w, flat_idx_fn):
    """Uniform weights stay python floats (weak-typed: they convert to the
    payload dtype, preserving the legacy engines' arithmetic bit for bit);
    per-node weights gather one scalar by the local node id (flat_idx_fn is
    only invoked on that branch)."""
    if isinstance(w, float):
        return w
    return jnp.asarray(w, jnp.float32)[flat_idx_fn()]


def _accumulate_rounds(payloads, perms, axis_arg, dense_fn):
    """sum_r dense(ppermute_r(payloads)) — no zero-init, so a single-round
    group is exactly the received payload's dense form."""
    acc = None
    for perm in perms:
        got = jax.lax.ppermute(payloads, axis_arg, list(perm))
        dl = dense_fn(got)
        acc = dl if acc is None else [a + d for a, d in zip(acc, dl)]
    return acc


def _neighbor_sum(payloads, groups, axis_arg, dense_fn, flat_idx_fn):
    """Weighted neighbour aggregate  sum_j w_ij q_j  (j != i) as flat
    buffers.  Returns (buffers, w_nbr): a single weight group defers its
    scalar to the caller (applied leaf-wise, matching the legacy engines);
    multiple groups weight each group's accumulator and pre-sum, so the
    caller applies w_nbr = 1.0."""
    if len(groups) == 1:
        w, perms = groups[0]
        acc = _accumulate_rounds(payloads, perms, axis_arg, dense_fn)
        return acc, _weight_value(w, flat_idx_fn)
    total = None
    for w, perms in groups:
        acc = _accumulate_rounds(payloads, perms, axis_arg, dense_fn)
        wv = _weight_value(w, flat_idx_fn)
        contrib = [wv * a for a in acc]
        total = contrib if total is None else [t + c
                                               for t, c in zip(total, contrib)]
    return total, 1.0


class _LazyFlatIndex:
    """Computes the flat node id at most once per traced exchange (only
    schedules with per-node weights need it)."""

    def __init__(self, axes, sizes):
        self.axes, self.sizes, self.value = axes, sizes, None

    def __call__(self):
        if self.value is None:
            self.value = _flat_node_index(self.axes, self.sizes)
        return self.value


def _self_weight(schedule: GossipSchedule, flat_idx_fn):
    if schedule.self_weight is not None:
        return schedule.self_weight
    return jnp.asarray(schedule.self_weights, jnp.float32)[flat_idx_fn()]


# ---------------------------------------------------------------------------
# choco engines
# ---------------------------------------------------------------------------

def make_choco_schedule_fn(*, axes: Tuple[str, ...], sizes: Tuple[int, ...],
                           schedules: Tuple[GossipSchedule, ...],
                           compressor: Compressor, gamma: float,
                           gossip_steps: int = 1,
                           exact_small_leaves: bool = False,
                           small_leaf_threshold: int = 8_192,
                           packed: bool = True,
                           pack_align: Optional[int] = None,
                           leaf_routes: Optional[list] = None) -> Callable:
    """Returns local_fn(key, x_half, x_hat, s) -> (x, x_hat, s) for shard_map.

    Implements, per local shard and ``gossip_steps`` times per call
    (schedule t = schedules[t % len(schedules)] — time-varying mixing):

        q      = Q(x - x_hat)
        x_hat += q
        s     += sum_j w_ij q_j          (schedule rounds, ppermute'd)
        x      = x + gamma (s - x_hat)

    packed=True (default): bucketed flat-buffer engine — the pytree is packed
    into a few dtype-homogeneous buckets (spec from comm/packing.py), each
    compressed once and shipped as one static-shape payload per neighbour.
    The spec (and flatten) is built ONCE per exchange, so k gossip steps
    amortize k compressions into one pack.
    packed=False: legacy per-leaf compression + one ppermute per leaf per
    round; kept as the reference engine.

    exact_small_leaves: leaves below the threshold (norm scales, biases) ship
    uncompressed — for a top-1% sparsifier the (value, index) pair costs 8
    bytes/coordinate, so compressing a 4 KB norm vector saves nothing while
    adding top-k latency; beyond-paper toggle, off for paper-faithful runs.
    In the packed engine this is a bucket-routing rule: small leaves go to a
    dense "exact" bucket instead of taking a per-leaf branch.
    """
    from repro.core.compression import Identity
    identity = Identity()
    n = 1
    for sz in sizes:
        n *= sz
    for sch in schedules:
        assert sch.n == n, f"schedule n={sch.n} != mesh gossip extent {n}"
    assert gossip_steps >= 1
    axis_arg = axes[0] if len(axes) == 1 else tuple(axes)
    align = _pack_align(compressor, pack_align)
    compiled = [(sch, _weight_groups(sch)) for sch in schedules]

    def packed_local_fn(key, x_half, x_hat, s):
        from repro.comm.packing import (bucket_dense, make_bucket_spec,
                                        unpack_leaves)
        # distinct randomness per gossip node and per model/fsdp shard
        for a in axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(a))
        leaves_h, leaves_hat, leaves_s, treedef = _flatten_states(
            x_half, x_hat, s)
        spec = make_bucket_spec(leaves_hat, align=align,
                                exact_small_leaves=exact_small_leaves,
                                small_leaf_threshold=small_leaf_threshold,
                                routes=leaf_routes)
        flat_idx = _LazyFlatIndex(axes, sizes)
        for t in range(gossip_steps):
            sched, groups = compiled[t % len(compiled)]
            tkey = key if t == 0 else jax.random.fold_in(key, t)
            payloads, q_leaves, new_hat = _packed_self_half(
                compressor, tkey, leaves_h, leaves_hat, spec)
            if not groups:                     # n == 1: no neighbours
                nbr_leaves, w_nbr = [q * 0.0 for q in q_leaves], 0.0
            else:
                dense_fn = lambda got: [bucket_dense(g, b) for g, b
                                        in zip(got, spec.buckets)]
                nbr_bufs, w_nbr = _neighbor_sum(payloads, groups, axis_arg,
                                                dense_fn, flat_idx)
                nbr_leaves = unpack_leaves(spec, nbr_bufs)
            w_self = _self_weight(sched, flat_idx)
            leaves_s, leaves_h = _choco_leaf_updates(
                leaves_h, leaves_s, q_leaves, nbr_leaves, new_hat,
                w_self, w_nbr, gamma)
            leaves_hat = new_hat
        unflatten = treedef.unflatten
        return unflatten(leaves_h), unflatten(leaves_hat), unflatten(leaves_s)

    if packed:
        return packed_local_fn

    def local_fn(key, x_half, x_hat, s):
        # distinct randomness per gossip node and per model/fsdp shard
        for a in axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(a))
        leaves_h, leaves_hat, leaves_s, treedef = _flatten_states(
            x_half, x_hat, s)
        flat_idx = _LazyFlatIndex(axes, sizes)
        for t in range(gossip_steps):
            sched, groups = compiled[t % len(compiled)]
            tkey = key if t == 0 else jax.random.fold_in(key, t)
            keys = _leaf_keys(tkey, len(leaves_h), 0)

            payloads, dense_fns, new_hat, q_dense = [], [], [], []
            for i, (lh, lhat) in enumerate(zip(leaves_h, leaves_hat)):
                # compress in the EF-state dtype: bf16 states -> bf16 wire
                delta = (lh.astype(lhat.dtype) - lhat).ravel()
                comp_i = (identity if exact_small_leaves
                          and delta.size <= small_leaf_threshold else compressor)
                pl, dfn = _compress_leaf(
                    comp_i, keys[i] if comp_i.stochastic else None, delta)
                payloads.append(pl)
                dense_fns.append(dfn)
                qd = dfn(pl)
                q_dense.append(qd)
                new_hat.append(lhat + qd.reshape(lh.shape).astype(lhat.dtype))

            if not groups:
                nbr_sum, w_nbr = [q * 0.0 for q in q_dense], 0.0
            else:
                dense_fn = lambda got: [dfn(g) for dfn, g
                                        in zip(dense_fns, got)]
                nbr_sum, w_nbr = _neighbor_sum(payloads, groups, axis_arg,
                                               dense_fn, flat_idx)
            w_self = _self_weight(sched, flat_idx)
            leaves_s, leaves_h = _choco_leaf_updates(
                leaves_h, leaves_s, q_dense, nbr_sum, new_hat,
                w_self, w_nbr, gamma)
            leaves_hat = new_hat
        unflatten = treedef.unflatten
        return unflatten(leaves_h), unflatten(leaves_hat), unflatten(leaves_s)

    return local_fn


# ---------------------------------------------------------------------------
# exact baselines
# ---------------------------------------------------------------------------

def make_plain_schedule_fn(*, axes: Tuple[str, ...], sizes: Tuple[int, ...],
                           schedules: Tuple[GossipSchedule, ...],
                           gossip_steps: int = 1) -> Callable:
    """Exact neighbour averaging (Algorithm 3): x = sum_j w_ij x_j, on any
    compiled schedule (the uncompressed iterates themselves are the wire
    payload)."""
    compiled = [(sch, _weight_groups(sch)) for sch in schedules]
    axis_arg = axes[0] if len(axes) == 1 else tuple(axes)

    def local_fn(key, x_half, x_hat, s):
        del key
        x = x_half
        flat_idx = _LazyFlatIndex(axes, sizes)
        for t in range(gossip_steps):
            sched, groups = compiled[t % len(compiled)]
            if not groups:
                continue
            leaves, treedef = jax.tree_util.tree_flatten(x)
            nbr, w_nbr = _neighbor_sum(leaves, groups, axis_arg,
                                       lambda got: got, flat_idx)
            w_self = _self_weight(sched, flat_idx)
            # cast back: per-node weights are f32 scalars and would upcast
            # bf16 params (uniform python-float weights make this a no-op)
            x = treedef.unflatten([(w_self * a + w_nbr * b).astype(a.dtype)
                                   for a, b in zip(leaves, nbr)])
        return x, x_hat, s

    return local_fn


def make_allreduce_fn(*, axes) -> Callable:
    """Centralized baseline: exact average over the gossip axes (all-reduce)."""
    axis_arg = axes[0] if len(axes) == 1 else tuple(axes)

    def local_fn(key, x_half, x_hat, s):
        del key
        new_x = jax.tree.map(lambda a: jax.lax.pmean(a, axis_arg), x_half)
        return new_x, x_hat, s
    return local_fn


# ---------------------------------------------------------------------------
# exchange builder
# ---------------------------------------------------------------------------

def _default_schedules(axes, sizes) -> Tuple[GossipSchedule, ...]:
    """Back-compat default: a uniform ring on one gossip axis, the 2-d torus
    over a (pod, data) axis pair — the two pre-schedule engine graphs."""
    from repro.comm.schedule import compile_schedule
    from repro.core.topology import ring, torus2d
    if len(axes) == 1:
        return (compile_schedule(ring(sizes[0])),)
    assert len(axes) == 2, "gossip over more than 2 mesh axes needs explicit schedules"
    return (compile_schedule(torus2d(*sizes), grid=tuple(sizes)),)


def make_gossip_exchange(*, mode: str, mesh, state_specs, axis,
                         compressor: Optional[Compressor] = None,
                         gamma: float = 1.0, exact_small_leaves: bool = False,
                         small_leaf_threshold: int = 8_192,
                         packed: bool = True,
                         pack_align: Optional[int] = None,
                         schedules: Optional[Sequence[GossipSchedule]] = None,
                         gossip_steps: int = 1) -> Callable:
    """Build the jit-able exchange: (key, x_half, x_hat, s) -> (x, x_hat, s).

    axis: one mesh axis name, or a tuple of axis names whose row-major
    flattening carries the schedule's node ids (the trainer maps the torus
    onto the (pod, data) ICI grid this way).
    state_specs: pytree of PartitionSpec matching the param pytree (with the
    leading node dim mapped to the gossip axes).
    schedules: compiled GossipSchedule sequence (time-varying mixing cycles
    through it across gossip_steps); None = a ring on a single axis / the
    2-d torus on an axis pair, matching the pre-schedule engines.
    packed selects the bucketed flat-buffer engine (default) vs the legacy
    per-leaf exchange.
    """
    axes = tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)
    sizes = tuple(mesh.shape[a] for a in axes)
    schedules = (tuple(schedules) if schedules
                 else _default_schedules(axes, sizes))
    if len(schedules) > 1 and gossip_steps % len(schedules) != 0:
        # the t-loop restarts at 0 every exchange, so a sequence longer than
        # gossip_steps would silently never run its tail schedules (while
        # gamma is still computed conservatively over the whole sequence)
        raise ValueError(
            f"time-varying mixing with {len(schedules)} schedules needs "
            f"gossip_steps to be a multiple of the sequence length so every "
            f"schedule runs each SGD step; got gossip_steps={gossip_steps}")

    if mode == "choco":
        local_fn = make_choco_schedule_fn(
            axes=axes, sizes=sizes, schedules=schedules,
            compressor=compressor, gamma=gamma, gossip_steps=gossip_steps,
            exact_small_leaves=exact_small_leaves,
            small_leaf_threshold=small_leaf_threshold,
            packed=packed, pack_align=pack_align,
            leaf_routes=_leaf_routes(state_specs, axes))
    elif mode == "plain":
        local_fn = make_plain_schedule_fn(axes=axes, sizes=sizes,
                                          schedules=schedules,
                                          gossip_steps=gossip_steps)
    elif mode == "allreduce":
        local_fn = make_allreduce_fn(axes=axes)
    else:
        raise ValueError(mode)

    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), state_specs, state_specs, state_specs),
        out_specs=(state_specs, state_specs, state_specs),
    )
