"""Distributed CHOCO gossip over a device mesh, driven by compiled schedules.

Implements the source paper's Algorithm 2 lines 4-9 / Algorithm 5 (choco),
Algorithm 3 (plain), and the engine dispatch for the stochastic-process and
push-sum variants.  Wire audits: EXPERIMENTS.md §Perf D (bucketed payloads)
and §Perf E (schedule replay); the stochastic engines are audited in
§Perf F and the bounded-staleness engine in §Perf G.

The gossip graph lives on one or more mesh axes (``axes``): every slice of
the mesh along those axes is one "node" of the paper's communication graph.
The exchange is implemented inside ``shard_map`` with ``jax.lax.ppermute``
of the *compressed payload only* — the collective bytes in the compiled HLO
are the paper's transmitted bits.  Every tensor-parallel / FSDP shard
compresses and gossips its own slice (coordinate-wise operators commute with
sharding).

Which neighbours exchange, in how many rounds, with what weights, is no
longer hardcoded: a :class:`~repro.comm.schedule.GossipSchedule` (compiled
once, pure Python, from any ``core.topology.Topology``) lists the
permutation rounds of W − I, and this engine replays them — one
``lax.ppermute`` per round, every round reusing the same packed payloads.
Ring and torus are now just two compiled schedules; hypercube, star, chain,
fully-connected, and arbitrary W (via greedy edge coloring) run through the
identical code path.  A *sequence* of schedules gives time-varying mixing,
cycled across the ``gossip_steps`` consensus rounds of each SGD step
(multiple gossip rounds per step: Hashemi et al., NeurIPS 2020).

Two engines for the choco exchange:
  * ``packed`` (default) — the bucketed flat-buffer engine (comm/packing.py):
    the whole pytree is packed into a few dtype-homogeneous buckets, each
    compressed ONCE and shipped as ONE static-shape payload per neighbour —
    a handful of collective-permutes per round regardless of leaf count;
  * ``per-leaf`` (legacy) — compress + ppermute every leaf separately; kept
    as the reference/bench baseline (see benchmarks/bench_collectives.py).

Four exchange modes:
  * ``choco``     — Algorithm 2 lines 4-9 (compressed, error-feedback)
  * ``plain``     — Algorithm 3 line 4-5 (exact neighbour averaging)
  * ``allreduce`` — centralized mini-batch SGD baseline (pmean over the axes)
  * ``pushsum``   — directed column-stochastic mixing with the (x, w) weight
                    pair and de-biased x/w (comm/pushsum.py)

Stochastic topologies: choco and plain also accept a ``TopologyProcess``
(comm/stochastic.py) — a per-step distribution over mixing matrices
(randomized matchings sampled one round at a time, or i.i.d. Bernoulli link
failures).  Every node draws the identical sample from the shared exchange
key (fold_in, zero communication).  The compressed process engine
(:func:`make_process_choco_fn`) keeps per-round reference replicas instead
of the static engine's running aggregate s — see its docstring for why s is
unsound under time-varying W.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compression import Compressor
from repro.comm.schedule import GossipSchedule

# jax.shard_map landed in 0.5.x; on 0.4.x the same function lives under
# jax.experimental.shard_map.  Resolve once at import time.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map


def _leaf_keys(key, n: int, salt: int):
    return jax.random.split(jax.random.fold_in(key, salt), n)


# Leaves larger than this are compressed row-blockwise: reshape to (R, BLOCK)
# and vmap the operator per row.  Identical omega guarantee (Assumption 1 per
# block), avoids int32 overflow in lax.top_k for multi-billion-element expert
# stacks, and matches the Pallas block-topk kernel's TPU-native semantics.
BLOCK_COMPRESS_SIZE = 1 << 22


def _compress_leaf(compressor: Compressor, key, flat):
    """Returns (payload, dense_fn) where dense_fn(payload) -> flat dense q."""
    d = flat.size
    if d <= BLOCK_COMPRESS_SIZE:
        pl_ = compressor.compress(key, flat)
        return pl_, lambda p: p.dense()
    C = BLOCK_COMPRESS_SIZE
    R = -(-d // C)
    padded = jnp.pad(flat, (0, R * C - d))
    rows = padded.reshape(R, C)
    if compressor.stochastic:
        keys = jax.random.split(key, R)
        pl_ = jax.vmap(compressor.compress)(keys, rows)
    else:
        pl_ = jax.vmap(lambda r: compressor.compress(None, r))(rows)

    def dense_fn(p):
        return jax.vmap(lambda q: q.dense())(p).reshape(R * C)[:d]

    return pl_, dense_fn


def _pack_align(compressor: Optional[Compressor], pack_align: Optional[int]):
    """Segment alignment for the packed engine: the compressor's block width
    for blockwise operators (so bucket compression commutes with packing),
    the 128-lane unit otherwise."""
    block = getattr(compressor, "block", None)
    if pack_align is None:
        return block or 128
    if block and pack_align % block != 0:
        raise ValueError(
            f"pack_align={pack_align} must be a multiple of the compressor's "
            f"block width {block}: blockwise selection must never straddle "
            f"leaf segments, or packed != per-leaf compression")
    return pack_align


def _leaf_routes(state_specs, gossip_axes) -> Optional[list]:
    """Per-leaf bucket-routing keys from the exchange's PartitionSpecs: the
    set of NON-gossip mesh axes each leaf is sharded over.  Leaves sharded
    differently (e.g. model-sharded weights vs model-replicated norm scales)
    must not share a bucket — bucket-level selection and scales would differ
    across those shards and de-replicate the replicated leaves."""
    if state_specs is None:
        return None
    gset = set(gossip_axes if isinstance(gossip_axes, (tuple, list))
               else (gossip_axes,))
    specs = jax.tree_util.tree_leaves(
        state_specs, is_leaf=lambda x: isinstance(x, P))
    routes = []
    for sp in specs:
        axes = set()
        if isinstance(sp, P):
            for entry in sp:
                if entry is None:
                    continue
                for a in (entry if isinstance(entry, tuple) else (entry,)):
                    axes.add(a)
        routes.append(tuple(sorted(axes - gset)))
    return routes


def _flatten_states(x_half, x_hat, s):
    leaves_h, treedef = jax.tree_util.tree_flatten(x_half)
    leaves_hat = treedef.flatten_up_to(x_hat)
    leaves_s = treedef.flatten_up_to(s)
    return leaves_h, leaves_hat, leaves_s, treedef


def _packed_self_half(compressor, key, leaves_h, leaves_hat, spec,
                      backend: str = "jnp"):
    """Send half of a packed choco round: deltas -> payloads, per-leaf
    dense q, and the updated public copies x_hat.  Factored so the serial
    and pipelined engines share one compress stage — the receive half
    (:func:`_neighbor_sum`) is a separate call, which keeps the collective's
    start/done free of any data dependency the caller does not create.
    ``backend`` is the resolved kernel backend for the quantize math
    (kernels/dispatch.py); both backends produce identical wire bytes."""
    from repro.comm.packing import compress_packed
    deltas = [(lh.astype(lhat.dtype) - lhat).ravel()
              for lh, lhat in zip(leaves_h, leaves_hat)]
    payloads, q_leaves = compress_packed(compressor, key, spec, deltas,
                                         backend=backend)
    new_hat = [lhat + q.reshape(lh.shape).astype(lhat.dtype)
               for lh, lhat, q in zip(leaves_h, leaves_hat, q_leaves)]
    return payloads, q_leaves, new_hat


def _per_leaf_self_half(compressor, identity, exact_small_leaves: bool,
                        small_leaf_threshold: int, tkey, leaves_h,
                        leaves_hat):
    """Send half of a legacy per-leaf choco round: compress every leaf's EF
    delta separately (tiny leaves optionally exact), advance x_hat.
    Returns (payloads, dense_fns, q_dense, new_hat) — the per-leaf twin of
    :func:`_packed_self_half`, shared by the serial and pipelined engines."""
    keys = _leaf_keys(tkey, len(leaves_h), 0)
    payloads, dense_fns, new_hat, q_dense = [], [], [], []
    for i, (lh, lhat) in enumerate(zip(leaves_h, leaves_hat)):
        # compress in the EF-state dtype: bf16 states -> bf16 wire
        delta = (lh.astype(lhat.dtype) - lhat).ravel()
        comp_i = (identity if exact_small_leaves
                  and delta.size <= small_leaf_threshold else compressor)
        pl, dfn = _compress_leaf(
            comp_i, keys[i] if comp_i.stochastic else None, delta)
        payloads.append(pl)
        dense_fns.append(dfn)
        qd = dfn(pl)
        q_dense.append(qd)
        new_hat.append(lhat + qd.reshape(lh.shape).astype(lhat.dtype))
    return payloads, dense_fns, q_dense, new_hat


def _choco_leaf_updates(leaves_h, leaves_s, q_leaves, nbr_leaves, new_hat,
                        w_self, w_nbr, gamma):
    """Algorithm 5 lines 8-10, per leaf (elementwise; XLA fuses these).
    ``gamma`` is a scalar or a per-leaf sequence (per-bucket Theorem-2
    stepsizes resolved by :func:`_resolve_leaf_gammas`)."""
    gammas = _broadcast_gammas(gamma, len(leaves_h))
    new_s, new_x = [], []
    for lh, ls, qd, nb, nh, g in zip(leaves_h, leaves_s, q_leaves,
                                     nbr_leaves, new_hat, gammas):
        sn = ls + (w_self * qd + w_nbr * nb).reshape(lh.shape).astype(ls.dtype)
        new_s.append(sn)
        new_x.append(lh + g * (sn - nh).astype(lh.dtype))
    return new_s, new_x


def _broadcast_gammas(gamma, n_leaves: int):
    """Scalar gamma -> n_leaves copies; a per-leaf list passes through."""
    if isinstance(gamma, (list, tuple)):
        assert len(gamma) == n_leaves, (len(gamma), n_leaves)
        return list(gamma)
    return [gamma] * n_leaves


def _resolve_bucket_gammas(gamma, spec, compressor: Compressor):
    """Per-BUCKET consensus stepsizes, in bucket order.  A plain float
    broadcasts; a :class:`~repro.core.choco_gossip.GammaSpec` derives
    Theorem 2 from each bucket's own omega (each bucket is an independent
    coordinate-wise CHOCO instance), so exact buckets (omega = 1) stop
    being dragged down to the worst top-k bucket's contraction and vice
    versa.  Consumed directly by the fused bucket-space EF path."""
    from repro.core.choco_gossip import GammaSpec
    if not isinstance(gamma, GammaSpec):
        return [gamma] * spec.n_buckets
    from repro.comm.packing import bucket_omegas
    omegas = bucket_omegas(spec, compressor)
    return [gamma.value(w) for w in omegas]


def _resolve_leaf_gammas(gamma, spec, compressor: Compressor):
    """Per-leaf consensus stepsizes for the packed engine: each leaf
    inherits its bucket's gamma (:func:`_resolve_bucket_gammas`), in
    tree_flatten order.  A plain float passes through unchanged."""
    from repro.core.choco_gossip import GammaSpec
    if not isinstance(gamma, GammaSpec):
        return gamma
    by_bucket = _resolve_bucket_gammas(gamma, spec, compressor)
    return [by_bucket[slot.bucket]
            for slot in sorted(spec.slots, key=lambda sl: sl.leaf)]


def _fused_update_ok(spec, leaves_h, leaves_s) -> bool:
    """Whether the fused bucket-space EF path applies: every bucket buffer,
    every packed slot, and every (x, s) state leaf must already be float32.
    Then pack/unpack are pure copies (no dtype rounding), bucket-space
    subtraction commutes with packing, and the fused path computes the
    exact per-leaf update algebra on the bucket buffers.  Mixed-precision
    EF states (bf16 x_hat) keep the leaf path, with the pallas backend
    still fusing the quantize."""
    f32 = jnp.dtype(jnp.float32)
    return (all(jnp.dtype(b.dtype) == f32 for b in spec.buckets)
            and all(jnp.dtype(sl.dtype) == f32 for sl in spec.slots)
            and all(jnp.dtype(l.dtype) == f32 for l in leaves_h)
            and all(jnp.dtype(l.dtype) == f32 for l in leaves_s))


# ---------------------------------------------------------------------------
# schedule plumbing
# ---------------------------------------------------------------------------

def _weight_groups(schedule: GossipSchedule):
    """Consecutive rounds sharing one receive weight merge into a group:
    their dense payloads accumulate unweighted and the weight applies once.
    (A uniform ring's +1/-1 shifts are one group — reproducing the
    pre-schedule engine's ``w_nbr * (left + right)`` arithmetic exactly.)"""
    groups = []
    for rnd in schedule.rounds:
        wkey = rnd.weight if rnd.weight is not None else rnd.weights
        if groups and groups[-1][0] == wkey:
            groups[-1][1].append(rnd.perm)
        else:
            groups.append([wkey, [rnd.perm]])
    return [(w, tuple(perms)) for w, perms in groups]


def _flat_node_index(axes: Tuple[str, ...], sizes: Tuple[int, ...]):
    """Row-major flat node id over the gossip axes — matches ppermute's
    flattening of a tuple axis name."""
    idx = jax.lax.axis_index(axes[0])
    for a, sz in zip(axes[1:], sizes[1:]):
        idx = idx * sz + jax.lax.axis_index(a)
    return idx


def _weight_value(w, flat_idx_fn):
    """Uniform weights stay python floats (weak-typed: they convert to the
    payload dtype, preserving the legacy engines' arithmetic bit for bit);
    per-node weights gather one scalar by the local node id (flat_idx_fn is
    only invoked on that branch)."""
    if isinstance(w, float):
        return w
    return jnp.asarray(w, jnp.float32)[flat_idx_fn()]


def _accumulate_rounds(payloads, perms, axis_arg, dense_fn):
    """sum_r dense(ppermute_r(payloads)) — no zero-init, so a single-round
    group is exactly the received payload's dense form."""
    acc = None
    for perm in perms:
        got = jax.lax.ppermute(payloads, axis_arg, list(perm))
        dl = dense_fn(got)
        acc = dl if acc is None else [a + d for a, d in zip(acc, dl)]
    return acc


def _neighbor_sum(payloads, groups, axis_arg, dense_fn, flat_idx_fn):
    """Weighted neighbour aggregate  sum_j w_ij q_j  (j != i) as flat
    buffers.  Returns (buffers, w_nbr): a single weight group defers its
    scalar to the caller (applied leaf-wise, matching the legacy engines);
    multiple groups weight each group's accumulator and pre-sum, so the
    caller applies w_nbr = 1.0."""
    if len(groups) == 1:
        w, perms = groups[0]
        acc = _accumulate_rounds(payloads, perms, axis_arg, dense_fn)
        return acc, _weight_value(w, flat_idx_fn)
    total = None
    for w, perms in groups:
        acc = _accumulate_rounds(payloads, perms, axis_arg, dense_fn)
        wv = _weight_value(w, flat_idx_fn)
        contrib = [wv * a for a in acc]
        total = contrib if total is None else [t + c
                                               for t, c in zip(total, contrib)]
    return total, 1.0


class _LazyFlatIndex:
    """Computes the flat node id at most once per traced exchange (only
    schedules with per-node weights need it)."""

    def __init__(self, axes, sizes):
        self.axes, self.sizes, self.value = axes, sizes, None

    def __call__(self):
        if self.value is None:
            self.value = _flat_node_index(self.axes, self.sizes)
        return self.value


# ---------------------------------------------------------------------------
# stochastic topology processes (comm/stochastic.py)
# ---------------------------------------------------------------------------

def _process_neighbor_sum(process, payloads, axis_arg, dense_fn, flat_idx_fn,
                          sample_key, t):
    """Sampled-round neighbour aggregate for the PLAIN engine under a
    TopologyProcess (the payload is the fresh iterate x itself, so sampled
    mixing is exact: x' = W_t x).

    Returns (nbr_bufs, w_nbr, w_self) — the sampled-step analogue of
    ``_neighbor_sum`` + ``_self_weight``.  ``sample_key`` is the exchange key
    BEFORE the per-axis fold-ins, so every node draws the identical sample
    (fold_in(key, SAMPLE_SALT + t) — see comm/stochastic.py) without
    communication.

    * matching — ``lax.switch`` over one-ppermute branches: only the sampled
      round's permute executes, so a k-round schedule costs ONE collective
      launch per gossip round instead of k.  Receive/self weights are the
      process's 1/p_r-scaled vectors, gathered at the local node id (f32 in
      every branch, so all switch branches have identical avals).
    * linkfail — every compiled round still ships (the payload is sent; the
      lossy link drops it in flight), but each destination scales its
      received contribution by the round's Bernoulli edge keep-mask and
      folds the dropped weight back into its self weight.

    The compressed CHOCO engine does NOT use this helper: integrating
    sampled q's into the running aggregate s is unsound (s = sum_tau W_tau
    q_tau is a non-decaying random walk around the static-W target — see
    make_process_choco_fn for the replica-based algorithm that replaces it).
    """
    i = flat_idx_fn()
    if process.kind == "matching":
        rounds = process.schedule.rounds

        def branch(r):
            recv = jnp.asarray(process.branch_recv[r], jnp.float32)
            selfw = jnp.asarray(process.branch_self[r], jnp.float32)
            perm = list(rounds[r].perm)

            def run(pl):
                got = jax.lax.ppermute(pl, axis_arg, perm)
                bufs = dense_fn(got)
                wv = recv[i]
                return [wv * b for b in bufs], selfw[i]
            return run

        idx = process.round_index(sample_key, t)
        nbr_bufs, w_self = jax.lax.switch(
            idx, [branch(r) for r in range(len(rounds))], payloads)
        return nbr_bufs, 1.0, w_self

    if process.kind == "linkfail":
        mask = process.edge_mask(sample_key, t)
        rmasks = process.round_masks(mask)
        total, recv_w = None, jnp.float32(0.0)
        for rnd, rm, recv in zip(process.schedule.rounds, rmasks,
                                 process.round_recv):
            got = jax.lax.ppermute(payloads, axis_arg, list(rnd.perm))
            bufs = dense_fn(got)
            wv = (jnp.asarray(recv, jnp.float32) * rm)[i]
            contrib = [wv * b for b in bufs]
            total = contrib if total is None else [a + c for a, c
                                                   in zip(total, contrib)]
            recv_w = recv_w + wv
        return total, 1.0, 1.0 - recv_w

    raise ValueError(f"unknown topology process kind {process.kind!r}")


def _self_weight(schedule: GossipSchedule, flat_idx_fn):
    if schedule.self_weight is not None:
        return schedule.self_weight
    return jnp.asarray(schedule.self_weights, jnp.float32)[flat_idx_fn()]


# ---------------------------------------------------------------------------
# choco engines
# ---------------------------------------------------------------------------

def make_choco_schedule_fn(*, axes: Tuple[str, ...], sizes: Tuple[int, ...],
                           schedules: Tuple[GossipSchedule, ...],
                           compressor: Compressor, gamma: float,
                           gossip_steps: int = 1,
                           exact_small_leaves: bool = False,
                           small_leaf_threshold: int = 8_192,
                           packed: bool = True,
                           pack_align: Optional[int] = None,
                           leaf_routes: Optional[list] = None,
                           kernel_backend: str = "jnp") -> Callable:
    """Returns local_fn(key, x_half, x_hat, s) -> (x, x_hat, s) for shard_map.

    Implements, per local shard and ``gossip_steps`` times per call
    (schedule t = schedules[t % len(schedules)] — time-varying mixing):

        q      = Q(x - x_hat)
        x_hat += q
        s     += sum_j w_ij q_j          (schedule rounds, ppermute'd)
        x      = x + gamma (s - x_hat)

    packed=True (default): bucketed flat-buffer engine — the pytree is packed
    into a few dtype-homogeneous buckets (spec from comm/packing.py), each
    compressed once and shipped as one static-shape payload per neighbour.
    The spec (and flatten) is built ONCE per exchange, so k gossip steps
    amortize k compressions into one pack.
    packed=False: legacy per-leaf compression + one ppermute per leaf per
    round; kept as the reference engine.

    exact_small_leaves: leaves below the threshold (norm scales, biases) ship
    uncompressed — for a top-1% sparsifier the (value, index) pair costs 8
    bytes/coordinate, so compressing a 4 KB norm vector saves nothing while
    adding top-k latency; beyond-paper toggle, off for paper-faithful runs.
    In the packed engine this is a bucket-routing rule: small leaves go to a
    dense "exact" bucket instead of taking a per-leaf branch.

    kernel_backend: the RESOLVED backend ("jnp"/"pallas") from
    kernels/dispatch.py — resolution (auto probing, toolchain gating)
    happens in :func:`make_gossip_exchange`; this builder only consumes
    the decision.  With "pallas" and all-f32 EF state the packed engine
    switches to the fused bucket-space path: state lives in bucket buffers
    across all gossip_steps, each round issues ONE fused quantize launch
    and ONE fused EF-update launch per bucket (kernels/qsgd.py +
    kernels/ef_update.py) instead of 8 full-size jnp streams per leaf,
    and leaves are unpacked once at the end.  Parity contract with the
    jnp path: identical wire payloads (same codes, same scales — the
    norm reductions and float associations match exactly) and identical
    x_hat; the x/s iterates agree up to FMA-contraction rounding (the
    backends compile structurally different graphs, so LLVM/XLA may
    contract different mul+add pairs — ulp-level, asserted in
    tests/test_fused.py).
    """
    from repro.core.choco_gossip import GammaSpec
    from repro.core.compression import Identity
    identity = Identity()
    if isinstance(gamma, GammaSpec) and not packed:
        raise ValueError(
            "per-bucket gamma (GammaSpec) requires the packed engine: the "
            "legacy per-leaf exchange has no bucket spec to derive omegas "
            "from — pass a float gamma, or packed=True")
    n = 1
    for sz in sizes:
        n *= sz
    for sch in schedules:
        assert sch.n == n, f"schedule n={sch.n} != mesh gossip extent {n}"
    assert gossip_steps >= 1
    axis_arg = axes[0] if len(axes) == 1 else tuple(axes)
    align = _pack_align(compressor, pack_align)
    compiled = [(sch, _weight_groups(sch)) for sch in schedules]

    def packed_local_fn(key, x_half, x_hat, s):
        from repro.comm.packing import (bucket_dense, compress_bufs,
                                        make_bucket_spec, pack_leaves,
                                        unpack_leaves)
        from repro.kernels import dispatch as kdispatch
        # distinct randomness per gossip node and per model/fsdp shard
        for a in axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(a))
        leaves_h, leaves_hat, leaves_s, treedef = _flatten_states(
            x_half, x_hat, s)
        spec = make_bucket_spec(leaves_hat, align=align,
                                exact_small_leaves=exact_small_leaves,
                                small_leaf_threshold=small_leaf_threshold,
                                routes=leaf_routes)
        flat_idx = _LazyFlatIndex(axes, sizes)
        unflatten = treedef.unflatten
        dense_fn = lambda got: [bucket_dense(g, b) for g, b
                                in zip(got, spec.buckets)]

        if (kernel_backend == "pallas"
                and _fused_update_ok(spec, leaves_h, leaves_s)):
            # fused bucket-space path: pack the three state trees ONCE,
            # run every gossip round on the bucket buffers (one fused
            # quantize launch + one fused EF-update launch per bucket),
            # unpack once at the end.  Padding is exactly preserved: it
            # starts 0 in every buffer, deltas/q/neighbour sums are 0
            # there, and the EF update maps (0,0,0,0,0) -> (0,0,0).
            bucket_gammas = _resolve_bucket_gammas(gamma, spec, compressor)
            shapes = [lh.shape for lh in leaves_h]
            h_bufs = pack_leaves(spec, leaves_h)
            hat_bufs = pack_leaves(spec, leaves_hat)
            s_bufs = pack_leaves(spec, leaves_s)
            for t in range(gossip_steps):
                sched, groups = compiled[t % len(compiled)]
                tkey = key if t == 0 else jax.random.fold_in(key, t)
                d_bufs = [hb - hatb for hb, hatb in zip(h_bufs, hat_bufs)]
                payloads, q_bufs = compress_bufs(compressor, tkey, spec,
                                                 d_bufs, backend="pallas")
                if not groups:                 # n == 1: no neighbours
                    nbr_bufs, w_nbr = [q * 0.0 for q in q_bufs], 0.0
                else:
                    nbr_bufs, w_nbr = _neighbor_sum(
                        payloads, groups, axis_arg, dense_fn, flat_idx)
                w_self = _self_weight(sched, flat_idx)
                for b in range(spec.n_buckets):
                    h_bufs[b], hat_bufs[b], s_bufs[b] = \
                        kdispatch.ef_bucket_update(
                            h_bufs[b], hat_bufs[b], s_bufs[b],
                            q_bufs[b], nbr_bufs[b], w_self, w_nbr,
                            bucket_gammas[b], backend="pallas")
            leaves_h = [f.reshape(sh) for f, sh
                        in zip(unpack_leaves(spec, h_bufs), shapes)]
            leaves_hat = [f.reshape(sh) for f, sh
                          in zip(unpack_leaves(spec, hat_bufs), shapes)]
            leaves_s = [f.reshape(sh) for f, sh
                        in zip(unpack_leaves(spec, s_bufs), shapes)]
            return (unflatten(leaves_h), unflatten(leaves_hat),
                    unflatten(leaves_s))

        gammas = _resolve_leaf_gammas(gamma, spec, compressor)
        for t in range(gossip_steps):
            sched, groups = compiled[t % len(compiled)]
            tkey = key if t == 0 else jax.random.fold_in(key, t)
            payloads, q_leaves, new_hat = _packed_self_half(
                compressor, tkey, leaves_h, leaves_hat, spec,
                backend=kernel_backend)
            if not groups:                     # n == 1: no neighbours
                nbr_leaves, w_nbr = [q * 0.0 for q in q_leaves], 0.0
            else:
                nbr_bufs, w_nbr = _neighbor_sum(payloads, groups, axis_arg,
                                                dense_fn, flat_idx)
                nbr_leaves = unpack_leaves(spec, nbr_bufs)
            w_self = _self_weight(sched, flat_idx)
            leaves_s, leaves_h = _choco_leaf_updates(
                leaves_h, leaves_s, q_leaves, nbr_leaves, new_hat,
                w_self, w_nbr, gammas)
            leaves_hat = new_hat
        return unflatten(leaves_h), unflatten(leaves_hat), unflatten(leaves_s)

    if packed:
        return packed_local_fn

    def local_fn(key, x_half, x_hat, s):
        # distinct randomness per gossip node and per model/fsdp shard
        for a in axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(a))
        leaves_h, leaves_hat, leaves_s, treedef = _flatten_states(
            x_half, x_hat, s)
        flat_idx = _LazyFlatIndex(axes, sizes)
        for t in range(gossip_steps):
            sched, groups = compiled[t % len(compiled)]
            tkey = key if t == 0 else jax.random.fold_in(key, t)
            payloads, dense_fns, q_dense, new_hat = _per_leaf_self_half(
                compressor, identity, exact_small_leaves,
                small_leaf_threshold, tkey, leaves_h, leaves_hat)
            if not groups:
                nbr_sum, w_nbr = [q * 0.0 for q in q_dense], 0.0
            else:
                dense_fn = lambda got: [dfn(g) for dfn, g
                                        in zip(dense_fns, got)]
                nbr_sum, w_nbr = _neighbor_sum(payloads, groups, axis_arg,
                                               dense_fn, flat_idx)
            w_self = _self_weight(sched, flat_idx)
            leaves_s, leaves_h = _choco_leaf_updates(
                leaves_h, leaves_s, q_dense, nbr_sum, new_hat,
                w_self, w_nbr, gamma)
            leaves_hat = new_hat
        unflatten = treedef.unflatten
        return unflatten(leaves_h), unflatten(leaves_hat), unflatten(leaves_s)

    return local_fn


# ---------------------------------------------------------------------------
# stochastic-process choco engine (per-round references — Algorithm 2 style)
# ---------------------------------------------------------------------------

def _send_vec(perm, n) -> Tuple[float, ...]:
    vec = [0.0] * n
    for src, _ in perm:
        vec[src] = 1.0
    return tuple(vec)


def _make_compress_stage(compressor: Compressor, *, packed: bool, align: int,
                         leaf_routes: Optional[list]) -> Callable:
    """Shared compression front half of the replica-based engines: returns
    ``stage(tkey, deltas, shapes_like) -> (payloads, q_leaves, dense_fn)``
    where ``payloads`` are the wire arrays handed to ``lax.ppermute``,
    ``q_leaves`` the dense local q per leaf, and ``dense_fn`` densifies a
    received payload back to per-leaf flat buffers.  ``packed`` selects the
    bucketed flat-buffer path (one payload per bucket) vs the legacy
    per-leaf path; both are consumed by ``make_process_choco_fn`` and the
    bounded-staleness engine (comm/async_gossip.py)."""
    def packed_stage(tkey, deltas, shapes_like):
        from repro.comm.packing import (bucket_dense, compress_packed,
                                        make_bucket_spec, unpack_leaves)
        spec = make_bucket_spec(shapes_like, align=align, routes=leaf_routes)
        payloads, q_leaves = compress_packed(compressor, tkey, spec, deltas)
        dense_fn = lambda got: unpack_leaves(
            spec, [bucket_dense(g, b) for g, b in zip(got, spec.buckets)])
        return payloads, q_leaves, dense_fn

    def per_leaf_stage(tkey, deltas, shapes_like):
        keys = _leaf_keys(tkey, len(deltas), 0)
        payloads, dfns, q_leaves = [], [], []
        for i, d in enumerate(deltas):
            pl, dfn = _compress_leaf(
                compressor, keys[i] if compressor.stochastic else None, d)
            payloads.append(pl)
            dfns.append(dfn)
            q_leaves.append(dfn(pl))
        return payloads, q_leaves, (
            lambda got: [dfn(g) for dfn, g in zip(dfns, got)])

    return packed_stage if packed else per_leaf_stage


def _ef_send_half(compress_stage, tkey, leaves_x, hat):
    """Error-feedback send half shared by the replica engines: compress the
    EF deltas against the public copies ``hat``, advance them, and return
    the wire payloads plus the densify callback.  Factored so the send side
    is one dependency-free block in the traced graph — the receive half is
    whatever the engine later does with ``payloads``, which keeps the
    collective's start/done pair separable in the compiled HLO (the
    property the pipelined engine and benchmarks/bench_overlap.py rely on).
    """
    deltas = [(a.astype(h.dtype) - h).ravel()
              for a, h in zip(leaves_x, hat)]
    payloads, q_leaves, dense_fn = compress_stage(tkey, deltas, hat)
    q_trees = [q.reshape(h.shape).astype(h.dtype)
               for h, q in zip(hat, q_leaves)]
    new_hat = [h + q for h, q in zip(hat, q_trees)]
    return payloads, q_trees, new_hat, dense_fn


def make_process_choco_fn(*, axes: Tuple[str, ...], sizes: Tuple[int, ...],
                          process, compressor: Compressor, gamma: float,
                          gossip_steps: int = 1, packed: bool = True,
                          pack_align: Optional[int] = None,
                          leaf_routes: Optional[list] = None) -> Callable:
    """Compressed gossip under a sampled/masked TopologyProcess.

    The static engine's running aggregate s_i = sum_tau (W q_tau)_i is only
    meaningful when W is FIXED: under per-step sampled W_t it becomes a
    non-decaying random walk around the target sum_j w_ij x_hat_j and the
    iterates drift away (unbiased but integrating variance).  The sound
    algorithm is the source paper's Algorithm 2 itself — every consumer of a
    public copy must hear every update of it — realized here with
    *per-round references*, the minimal replica set for round-sampled
    communication:

      * own references H_r (one per schedule round the node sends in):
        q_i^(r) = Q(x_i - H_r), H_r += q_i^(r), updated ONLY when round r
        actually ships;
      * source replicas S_r (one per round): S_r += received q — exact
        copies of the round-r source's H_r, because that source updates its
        H_r in exactly the rounds this node hears it (static round
        structure + shared sampling seed = replica consistency with zero
        metadata on the wire);
      * update  x_i += gamma * sum_r live_r * v_r[i] * (S_r - H_r)  — the
        Algorithm-1 row form with every term locally fresh.

    matching: one round live per gossip round (``lax.switch`` — a single
    permute launch and a single compression per step, against the sampled
    round's reference).  linkfail: all rounds ship one shared q (single
    compression, x_hat is the one own-reference) and the Bernoulli edge
    mask gates each round's receive weight.

    Memory: matching holds 2R state trees (R own refs + R replicas), and
    linkfail R + 1 — the O(degree) public-copy cost of the paper's
    Algorithm 2, which the static engine's Algorithm-5 s-trick avoids only
    because its W never changes.  The trainer allocates x_hat / s as lists
    of trees accordingly.
    """
    n = 1
    for sz in sizes:
        n *= sz
    assert process.n == n, f"process n={process.n} != mesh extent {n}"
    assert gossip_steps >= 1
    axis_arg = axes[0] if len(axes) == 1 else tuple(axes)
    align = _pack_align(compressor, pack_align)
    rounds = process.schedule.rounds
    R = len(rounds)
    send_vecs = [_send_vec(rnd.perm, n) for rnd in rounds]

    compress_stage = _make_compress_stage(compressor, packed=packed,
                                          align=align,
                                          leaf_routes=leaf_routes)

    def matching_local_fn(key, x_half, hat_list, s_list):
        sample_key = key
        for a in axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(a))
        leaves_x, treedef = jax.tree_util.tree_flatten(x_half)
        H = [treedef.flatten_up_to(h) for h in hat_list]   # R own refs
        S = [treedef.flatten_up_to(sv) for sv in s_list]   # R replicas
        flat_idx = _LazyFlatIndex(axes, sizes)
        i = flat_idx()
        for t in range(gossip_steps):
            tkey = key if t == 0 else jax.random.fold_in(key, t)

            def branch(r):
                recv = jnp.asarray(process.branch_recv[r], jnp.float32)
                send = jnp.asarray(send_vecs[r], jnp.float32)
                perm = list(rounds[r].perm)

                def run(ops):
                    lx, Hs, Ss = ops
                    ref = Hs[r]
                    deltas = [(a.astype(h.dtype) - h).ravel()
                              for a, h in zip(lx, ref)]
                    payloads, q_leaves, dense_fn = compress_stage(
                        jax.random.fold_in(tkey, r), deltas, ref)
                    m_send = send[i]
                    new_ref = [h + (m_send
                                    * q.reshape(h.shape)).astype(h.dtype)
                               for h, q in zip(ref, q_leaves)]
                    got = jax.lax.ppermute(payloads, axis_arg, perm)
                    recv_dense = dense_fn(got)
                    # non-receivers get ppermute zeros: replica unchanged
                    new_rep = [sv + rd.reshape(sv.shape).astype(sv.dtype)
                               for sv, rd in zip(Ss[r], recv_dense)]
                    v = recv[i]
                    # cast the whole f32-weighted update back: v is a strong
                    # f32 scalar and would silently upcast bf16 params
                    new_x = [a + (gamma * v * (sr - hr)).astype(a.dtype)
                             for a, sr, hr in zip(lx, new_rep, new_ref)]
                    Hs2 = [new_ref if rr == r else Hs[rr] for rr in range(R)]
                    Ss2 = [new_rep if rr == r else Ss[rr] for rr in range(R)]
                    return new_x, Hs2, Ss2
                return run

            idx = process.round_index(sample_key, t)
            leaves_x, H, S = jax.lax.switch(
                idx, [branch(r) for r in range(R)], (leaves_x, H, S))
        u = treedef.unflatten
        return u(leaves_x), [u(h) for h in H], [u(sv) for sv in S]

    def linkfail_local_fn(key, x_half, x_hat, s_list):
        sample_key = key
        for a in axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(a))
        leaves_x, treedef = jax.tree_util.tree_flatten(x_half)
        leaves_hat = treedef.flatten_up_to(x_hat)
        S = [treedef.flatten_up_to(sv) for sv in s_list]
        flat_idx = _LazyFlatIndex(axes, sizes)
        i = flat_idx()
        for t in range(gossip_steps):
            tkey = key if t == 0 else jax.random.fold_in(key, t)
            deltas = [(a.astype(h.dtype) - h).ravel()
                      for a, h in zip(leaves_x, leaves_hat)]
            payloads, q_leaves, dense_fn = compress_stage(tkey, deltas,
                                                          leaves_hat)
            leaves_hat = [h + q.reshape(h.shape).astype(h.dtype)
                          for h, q in zip(leaves_hat, q_leaves)]
            mask = process.edge_mask(sample_key, t)
            rmasks = process.round_masks(mask)
            acc = [jnp.zeros((), a.dtype) for a in leaves_x]
            new_S = []
            for r, rnd in enumerate(rounds):
                got = jax.lax.ppermute(payloads, axis_arg, list(rnd.perm))
                recv_dense = dense_fn(got)
                # the replica ALWAYS integrates (the payload was sent; the
                # lossy link gates only the mixing weight below) — it must
                # keep tracking the source's x_hat exactly
                S_r = [sv + rd.reshape(sv.shape).astype(sv.dtype)
                       for sv, rd in zip(S[r], recv_dense)]
                new_S.append(S_r)
                wv = (jnp.asarray(process.round_recv[r], jnp.float32)
                      * rmasks[r])[i]
                acc = [a + wv * (sr - h)
                       for a, sr, h in zip(acc, S_r, leaves_hat)]
            S = new_S
            # acc is f32 (strong per-node mask weights): cast the whole
            # update back so bf16 params stay bf16
            leaves_x = [a + (gamma * ac).astype(a.dtype)
                        for a, ac in zip(leaves_x, acc)]
        u = treedef.unflatten
        return u(leaves_x), u(leaves_hat), [u(sv) for sv in S]

    if process.kind == "matching":
        return matching_local_fn
    if process.kind == "linkfail":
        return linkfail_local_fn
    raise ValueError(f"unknown topology process kind {process.kind!r}")


# ---------------------------------------------------------------------------
# exact baselines
# ---------------------------------------------------------------------------

def make_plain_schedule_fn(*, axes: Tuple[str, ...], sizes: Tuple[int, ...],
                           schedules: Tuple[GossipSchedule, ...],
                           gossip_steps: int = 1,
                           process=None) -> Callable:
    """Exact neighbour averaging (Algorithm 3): x = sum_j w_ij x_j, on any
    compiled schedule (the uncompressed iterates themselves are the wire
    payload).  process != None averages with the sampled mixing matrix of a
    comm/stochastic.py TopologyProcess instead of the static W."""
    compiled = [(sch, _weight_groups(sch)) for sch in schedules]
    axis_arg = axes[0] if len(axes) == 1 else tuple(axes)

    def local_fn(key, x_half, x_hat, s):
        sample_key = key
        x = x_half
        flat_idx = _LazyFlatIndex(axes, sizes)
        for t in range(gossip_steps):
            sched, groups = compiled[t % len(compiled)]
            if process is None and not groups:
                continue
            leaves, treedef = jax.tree_util.tree_flatten(x)
            if process is not None:
                nbr, w_nbr, w_self = _process_neighbor_sum(
                    process, leaves, axis_arg, lambda got: got, flat_idx,
                    sample_key, t)
            else:
                nbr, w_nbr = _neighbor_sum(leaves, groups, axis_arg,
                                           lambda got: got, flat_idx)
                w_self = _self_weight(sched, flat_idx)
            # cast back: per-node weights are f32 scalars and would upcast
            # bf16 params (uniform python-float weights make this a no-op)
            x = treedef.unflatten([(w_self * a + w_nbr * b).astype(a.dtype)
                                   for a, b in zip(leaves, nbr)])
        return x, x_hat, s

    return local_fn


def make_allreduce_fn(*, axes) -> Callable:
    """Centralized baseline: exact average over the gossip axes (all-reduce)."""
    axis_arg = axes[0] if len(axes) == 1 else tuple(axes)

    def local_fn(key, x_half, x_hat, s):
        del key
        new_x = jax.tree.map(lambda a: jax.lax.pmean(a, axis_arg), x_half)
        return new_x, x_hat, s
    return local_fn


# ---------------------------------------------------------------------------
# exchange builder
# ---------------------------------------------------------------------------

def _default_schedules(axes, sizes) -> Tuple[GossipSchedule, ...]:
    """Back-compat default: a uniform ring on one gossip axis, the 2-d torus
    over a (pod, data) axis pair — the two pre-schedule engine graphs."""
    from repro.comm.schedule import compile_schedule
    from repro.core.topology import ring, torus2d
    if len(axes) == 1:
        return (compile_schedule(ring(sizes[0])),)
    assert len(axes) == 2, "gossip over more than 2 mesh axes needs explicit schedules"
    return (compile_schedule(torus2d(*sizes), grid=tuple(sizes)),)


def make_gossip_exchange(*, mode: str, mesh, state_specs, axis,
                         compressor: Optional[Compressor] = None,
                         gamma: float = 1.0, exact_small_leaves: bool = False,
                         small_leaf_threshold: int = 8_192,
                         packed: bool = True,
                         pack_align: Optional[int] = None,
                         schedules: Optional[Sequence[GossipSchedule]] = None,
                         gossip_steps: int = 1,
                         process=None,
                         pipelined: bool = False,
                         weight_specs=None,
                         kernel_backend: str = "auto") -> Callable:
    """Build the jit-able exchange: (key, x_half, x_hat, s) -> (x, x_hat, s).

    axis: one mesh axis name, or a tuple of axis names whose row-major
    flattening carries the schedule's node ids (the trainer maps the torus
    onto the (pod, data) ICI grid this way).
    state_specs: pytree of PartitionSpec matching the param pytree (with the
    leading node dim mapped to the gossip axes).
    schedules: compiled GossipSchedule sequence (time-varying mixing cycles
    through it across gossip_steps); None = a ring on a single axis / the
    2-d torus on an axis pair, matching the pre-schedule engines.
    packed selects the bucketed flat-buffer engine (default) vs the legacy
    per-leaf exchange.
    process: comm/stochastic.py TopologyProcess — replaces the static round
    replay with per-step sampled rounds (choco / plain modes only); its
    schedule IS the schedule, so ``schedules`` must be omitted or length 1.
    mode="pushsum" builds the directed column-stochastic engine
    (comm/pushsum.py): the returned callable has the 5-ary push-sum
    signature (key, x, x_hat, s, w) -> (x, x_hat, s, w) and needs
    ``weight_specs`` (PartitionSpec of the per-node weight scalar).
    pipelined=True (choco, static schedule only) builds the overlap engine
    (comm/pipelined.py): identical signature and state trees, but the
    x-update reads the PREVIOUS round's (s, x_hat) pair, so the collective
    has no consumer in the current update and can run concurrently with
    whatever compute the caller traces around the exchange.
    kernel_backend: "auto" (default) probes the toolchain and picks the
    Pallas kernels when they can run compiled on this jax/backend
    (kernels/dispatch.py); "pallas"/"jnp" force.  Only the packed static
    choco engines (serial + pipelined) are pallas-eligible — forcing
    "pallas" elsewhere raises.  Backends ship identical wire bytes and
    identical x_hat; x/s agree to FMA-contraction rounding (see
    make_choco_schedule_fn).
    """
    from repro.kernels import dispatch as kdispatch
    engine_eligible = (mode == "choco" and packed and process is None)
    resolved_backend = kdispatch.resolve_backend(
        kernel_backend, engine_eligible=engine_eligible)
    axes = tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)
    sizes = tuple(mesh.shape[a] for a in axes)
    n = 1
    for sz in sizes:
        n *= sz
    if process is not None:
        if mode not in ("choco", "plain"):
            raise ValueError(
                f"topology processes run on the choco/plain engines only; "
                f"mode={mode!r} (the push-sum engine handles directed graphs "
                f"itself, allreduce has no gossip graph)")
        if getattr(process, "kind", None) == "staleness" and mode != "choco":
            raise ValueError(
                "bounded staleness runs on the compressed choco engine "
                "only: the stale snapshots are reconstructed from rings of "
                "compressed increments, and the plain engine ships fresh "
                "iterates with no increment stream to ring-buffer")
        if schedules is not None and len(tuple(schedules)) > 1:
            raise ValueError(
                "a topology process already IS the per-step mixing "
                "distribution; combining it with a time-varying schedule "
                "sequence is ambiguous — pass one or the other")
        if process.n != n:
            raise ValueError(f"process n={process.n} != mesh gossip "
                             f"extent {n}")
        schedules = (process.schedule,)

    if mode == "pushsum":
        from repro.comm.pushsum import make_pushsum_schedule_fn
        if not packed:
            raise ValueError("the push-sum engine is packed-only (the weight "
                             "scalar rides in-band with the bucket payloads); "
                             "per-leaf push-sum is not implemented")
        if schedules is None or len(tuple(schedules)) != 1:
            raise ValueError("push-sum needs exactly one compiled directed "
                             "schedule (compile_directed_schedule)")
        if weight_specs is None:
            raise ValueError("push-sum needs weight_specs: the PartitionSpec "
                             "of the per-node (n, 1) weight column")
        local_fn = make_pushsum_schedule_fn(
            axes=axes, sizes=sizes, schedule=tuple(schedules)[0],
            compressor=compressor, gamma=gamma, gossip_steps=gossip_steps,
            pack_align=pack_align,
            leaf_routes=_leaf_routes(state_specs, axes))
        return shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(), state_specs, state_specs, state_specs,
                      weight_specs),
            out_specs=(state_specs, state_specs, state_specs, weight_specs),
        )

    if pipelined:
        if mode != "choco":
            raise ValueError(
                f"pipelined gossip runs on the compressed choco engine only "
                f"(mode={mode!r}): overlapping the exchange requires the "
                f"EF-compressed increment stream whose integration can be "
                f"deferred one round — plain/allreduce ship fresh iterates "
                f"the update must consume immediately")
        if process is not None:
            raise ValueError(
                "pipelined gossip composes a deterministic one-round delay "
                "with a STATIC schedule; stacking it on a stochastic "
                "topology process (whose gamma already folds its own "
                "delay/sampling model) is unsupported — pick one")
        if schedules is not None and len(tuple(schedules)) > 1:
            raise ValueError(
                "pipelined gossip supports a single static schedule: the "
                "tau=1 gamma is derived from one delay-averaged mixing "
                "matrix, which a time-varying sequence does not have")

    schedules = (tuple(schedules) if schedules
                 else _default_schedules(axes, sizes))
    if len(schedules) > 1 and gossip_steps % len(schedules) != 0:
        # the t-loop restarts at 0 every exchange, so a sequence longer than
        # gossip_steps would silently never run its tail schedules (while
        # gamma is still computed conservatively over the whole sequence)
        raise ValueError(
            f"time-varying mixing with {len(schedules)} schedules needs "
            f"gossip_steps to be a multiple of the sequence length so every "
            f"schedule runs each SGD step; got gossip_steps={gossip_steps}")

    if mode == "choco" and process is not None \
            and getattr(process, "kind", None) == "staleness":
        # bounded-staleness engine (comm/async_gossip.py): x_hat is the
        # [public copy + depth-tau own ring] list, s the [R replicas +
        # R*tau receive rings] list — see make_async_choco_fn
        from repro.comm.async_gossip import make_async_choco_fn
        local_fn = make_async_choco_fn(
            axes=axes, sizes=sizes, process=process, compressor=compressor,
            gamma=gamma, gossip_steps=gossip_steps, packed=packed,
            pack_align=pack_align,
            leaf_routes=_leaf_routes(state_specs, axes))
        R = len(process.schedule.rounds)
        tau = process.max_staleness
        hat_specs = [state_specs] * (1 + tau)
        s_specs = [state_specs] * (R * (1 + tau))
        return shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(), state_specs, hat_specs, s_specs),
            out_specs=(state_specs, hat_specs, s_specs),
        )

    if mode == "choco" and process is not None:
        # replica-based engine: x_hat / s are LISTS of state trees (per-round
        # references — see make_process_choco_fn); their specs replicate the
        # single-tree specs element-wise
        local_fn = make_process_choco_fn(
            axes=axes, sizes=sizes, process=process, compressor=compressor,
            gamma=gamma, gossip_steps=gossip_steps, packed=packed,
            pack_align=pack_align,
            leaf_routes=_leaf_routes(state_specs, axes))
        R = len(process.schedule.rounds)
        hat_specs = (state_specs if process.kind == "linkfail"
                     else [state_specs] * R)
        s_specs = [state_specs] * R
        return shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(), state_specs, hat_specs, s_specs),
            out_specs=(state_specs, hat_specs, s_specs),
        )

    if mode == "choco" and pipelined:
        from repro.comm.pipelined import make_pipelined_choco_fn
        local_fn = make_pipelined_choco_fn(
            axes=axes, sizes=sizes, schedule=schedules[0],
            compressor=compressor, gamma=gamma, gossip_steps=gossip_steps,
            exact_small_leaves=exact_small_leaves,
            small_leaf_threshold=small_leaf_threshold,
            packed=packed, pack_align=pack_align,
            leaf_routes=_leaf_routes(state_specs, axes),
            kernel_backend=resolved_backend)
    elif mode == "choco":
        local_fn = make_choco_schedule_fn(
            axes=axes, sizes=sizes, schedules=schedules,
            compressor=compressor, gamma=gamma, gossip_steps=gossip_steps,
            exact_small_leaves=exact_small_leaves,
            small_leaf_threshold=small_leaf_threshold,
            packed=packed, pack_align=pack_align,
            leaf_routes=_leaf_routes(state_specs, axes),
            kernel_backend=resolved_backend)
    elif mode == "plain":
        local_fn = make_plain_schedule_fn(axes=axes, sizes=sizes,
                                          schedules=schedules,
                                          gossip_steps=gossip_steps,
                                          process=process)
    elif mode == "allreduce":
        local_fn = make_allreduce_fn(axes=axes)
    else:
        raise ValueError(mode)

    smap_kwargs = {}
    if (resolved_backend == "pallas"
            and not kdispatch.shard_map_check_rep("pallas")):
        # jax 0.4.x shard_map has no replication rule for pallas_call;
        # the exchange's specs carry no replicated outputs anyway
        smap_kwargs["check_rep"] = False
    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), state_specs, state_specs, state_specs),
        out_specs=(state_specs, state_specs, state_specs),
        **smap_kwargs,
    )
