"""Distributed CHOCO gossip over a device mesh.

The gossip ring lives on one mesh axis (``gossip_axis``): every slice of the
mesh along that axis is one "node" of the paper's communication graph.  The
exchange is implemented inside ``shard_map`` with ``jax.lax.ppermute`` of the
*compressed payload only* — the collective bytes in the compiled HLO are the
paper's transmitted bits.  Every tensor-parallel / FSDP shard compresses and
gossips its own slice (coordinate-wise operators commute with sharding).

Two engines for the choco exchange:
  * ``packed`` (default) — the bucketed flat-buffer engine (comm/packing.py):
    the whole pytree is packed into a few dtype-homogeneous buckets, each
    compressed ONCE and shipped as ONE static-shape payload per neighbour —
    a handful of collective-permutes per round regardless of leaf count;
  * ``per-leaf`` (legacy) — compress + ppermute every leaf separately; kept
    as the reference/bench baseline (see benchmarks/bench_collectives.py).

Three exchange modes:
  * ``choco``     — Algorithm 2 lines 4-9 (compressed, error-feedback)
  * ``plain``     — Algorithm 3 line 4-5 (exact neighbour averaging)
  * ``allreduce`` — centralized mini-batch SGD baseline (pmean over the axis)
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compression import Compressor

# jax.shard_map landed in 0.5.x; on 0.4.x the same function lives under
# jax.experimental.shard_map.  Resolve once at import time.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map


def ring_perm(n: int, shift: int):
    return [(i, (i + shift) % n) for i in range(n)]


def ring_weights(n: int) -> Tuple[float, float]:
    """Uniform-averaging ring W (paper Table 1): returns (w_self, w_neighbor).
    n>=3: degree-2 ring, w = 1/3 each.  n==2: single edge, 1/2 each.
    n==1: trivial."""
    if n == 1:
        return 1.0, 0.0
    if n == 2:
        return 0.5, 0.5
    return 1.0 / 3.0, 1.0 / 3.0


def _leaf_keys(key, n: int, salt: int):
    return jax.random.split(jax.random.fold_in(key, salt), n)


# Leaves larger than this are compressed row-blockwise: reshape to (R, BLOCK)
# and vmap the operator per row.  Identical omega guarantee (Assumption 1 per
# block), avoids int32 overflow in lax.top_k for multi-billion-element expert
# stacks, and matches the Pallas block-topk kernel's TPU-native semantics.
BLOCK_COMPRESS_SIZE = 1 << 22


def _compress_leaf(compressor: Compressor, key, flat):
    """Returns (payload, dense_fn) where dense_fn(payload) -> flat dense q."""
    d = flat.size
    if d <= BLOCK_COMPRESS_SIZE:
        pl_ = compressor.compress(key, flat)
        return pl_, lambda p: p.dense()
    C = BLOCK_COMPRESS_SIZE
    R = -(-d // C)
    padded = jnp.pad(flat, (0, R * C - d))
    rows = padded.reshape(R, C)
    if compressor.stochastic:
        keys = jax.random.split(key, R)
        pl_ = jax.vmap(compressor.compress)(keys, rows)
    else:
        pl_ = jax.vmap(lambda r: compressor.compress(None, r))(rows)

    def dense_fn(p):
        return jax.vmap(lambda q: q.dense())(p).reshape(R * C)[:d]

    return pl_, dense_fn


def _axis_edges(n: int) -> int:
    """Ring edges contributed by one torus axis of size n."""
    return 2 if n > 2 else (1 if n == 2 else 0)


def _pack_align(compressor: Optional[Compressor], pack_align: Optional[int]):
    """Segment alignment for the packed engine: the compressor's block width
    for blockwise operators (so bucket compression commutes with packing),
    the 128-lane unit otherwise."""
    block = getattr(compressor, "block", None)
    if pack_align is None:
        return block or 128
    if block and pack_align % block != 0:
        raise ValueError(
            f"pack_align={pack_align} must be a multiple of the compressor's "
            f"block width {block}: blockwise selection must never straddle "
            f"leaf segments, or packed != per-leaf compression")
    return pack_align


def _leaf_routes(state_specs, gossip_axes) -> Optional[list]:
    """Per-leaf bucket-routing keys from the exchange's PartitionSpecs: the
    set of NON-gossip mesh axes each leaf is sharded over.  Leaves sharded
    differently (e.g. model-sharded weights vs model-replicated norm scales)
    must not share a bucket — bucket-level selection and scales would differ
    across those shards and de-replicate the replicated leaves."""
    if state_specs is None:
        return None
    gset = set(gossip_axes if isinstance(gossip_axes, (tuple, list))
               else (gossip_axes,))
    specs = jax.tree_util.tree_leaves(
        state_specs, is_leaf=lambda x: isinstance(x, P))
    routes = []
    for sp in specs:
        axes = set()
        if isinstance(sp, P):
            for entry in sp:
                if entry is None:
                    continue
                for a in (entry if isinstance(entry, tuple) else (entry,)):
                    axes.add(a)
        routes.append(tuple(sorted(axes - gset)))
    return routes


def _flatten_states(x_half, x_hat, s):
    leaves_h, treedef = jax.tree_util.tree_flatten(x_half)
    leaves_hat = treedef.flatten_up_to(x_hat)
    leaves_s = treedef.flatten_up_to(s)
    return leaves_h, leaves_hat, leaves_s, treedef


def _packed_self_half(compressor, key, leaves_h, leaves_hat, spec):
    """Shared first half of a packed choco round: deltas -> payloads,
    per-leaf dense q, and the updated public copies x_hat."""
    from repro.comm.packing import compress_packed
    deltas = [(lh.astype(lhat.dtype) - lhat).ravel()
              for lh, lhat in zip(leaves_h, leaves_hat)]
    payloads, q_leaves = compress_packed(compressor, key, spec, deltas)
    new_hat = [lhat + q.reshape(lh.shape).astype(lhat.dtype)
               for lh, lhat, q in zip(leaves_h, leaves_hat, q_leaves)]
    return payloads, q_leaves, new_hat


def _choco_leaf_updates(leaves_h, leaves_s, q_leaves, nbr_leaves, new_hat,
                        w_self, w_nbr, gamma):
    """Algorithm 5 lines 8-10, per leaf (elementwise; XLA fuses these)."""
    new_s, new_x = [], []
    for lh, ls, qd, nb, nh in zip(leaves_h, leaves_s, q_leaves, nbr_leaves,
                                  new_hat):
        sn = ls + (w_self * qd + w_nbr * nb).reshape(lh.shape).astype(ls.dtype)
        new_s.append(sn)
        new_x.append(lh + gamma * (sn - nh).astype(lh.dtype))
    return new_s, new_x


def make_choco_gossip_2d_fn(*, axes: Tuple[str, ...], sizes: Tuple[int, ...],
                            compressor: Compressor, gamma: float,
                            exact_small_leaves: bool = False,
                            small_leaf_threshold: int = 8_192,
                            packed: bool = True,
                            pack_align: Optional[int] = None,
                            leaf_routes: Optional[list] = None) -> Callable:
    """CHOCO gossip on a 2-D torus of mesh axes (paper Table 1: torus
    delta = O(1/n) vs ring O(1/n^2)).  Each node compresses ONCE and
    ppermutes the payload along every axis ring — 2x the ring's wire for a
    quadratically better spectral gap.  Beyond-paper: the paper analyses the
    torus but never maps it onto a physical interconnect; here the two axes
    are pod x data rings of the ICI fabric."""
    from repro.core.compression import Identity
    identity = Identity()
    n_edges = sum(_axis_edges(n) for n in sizes)
    w = 1.0 / (1.0 + n_edges)        # uniform-averaging torus W
    align = _pack_align(compressor, pack_align)

    def packed_local_fn(key, x_half, x_hat, s):
        from repro.comm.packing import (bucket_dense, make_bucket_spec,
                                        unpack_leaves)
        for a in axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(a))
        leaves_h, leaves_hat, leaves_s, treedef = _flatten_states(
            x_half, x_hat, s)
        spec = make_bucket_spec(leaves_hat, align=align,
                                exact_small_leaves=exact_small_leaves,
                                small_leaf_threshold=small_leaf_threshold,
                                routes=leaf_routes)
        payloads, q_leaves, new_hat = _packed_self_half(
            compressor, key, leaves_h, leaves_hat, spec)

        nbr_bufs = [jnp.zeros((b.size,), b.dtype) for b in spec.buckets]
        for a, n in zip(axes, sizes):
            if n < 2:
                continue
            got = jax.lax.ppermute(payloads, a, ring_perm(n, 1))
            nbr_bufs = [acc + bucket_dense(g, b)
                        for acc, g, b in zip(nbr_bufs, got, spec.buckets)]
            if n > 2:
                got = jax.lax.ppermute(payloads, a, ring_perm(n, -1))
                nbr_bufs = [acc + bucket_dense(g, b)
                            for acc, g, b in zip(nbr_bufs, got, spec.buckets)]
        nbr_leaves = unpack_leaves(spec, nbr_bufs)

        new_s, new_x = _choco_leaf_updates(leaves_h, leaves_s, q_leaves,
                                           nbr_leaves, new_hat, w, w, gamma)
        unflatten = treedef.unflatten
        return unflatten(new_x), unflatten(new_hat), unflatten(new_s)

    if packed:
        return packed_local_fn

    def local_fn(key, x_half, x_hat, s):
        for a in axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(a))
        leaves_h, treedef = jax.tree_util.tree_flatten(x_half)
        leaves_hat = treedef.flatten_up_to(x_hat)
        leaves_s = treedef.flatten_up_to(s)
        keys = _leaf_keys(key, len(leaves_h), 0)

        payloads, dense_fns, new_hat, q_dense = [], [], [], []
        for i, (lh, lhat) in enumerate(zip(leaves_h, leaves_hat)):
            delta = (lh.astype(lhat.dtype) - lhat).ravel()
            comp_i = (identity if exact_small_leaves
                      and delta.size <= small_leaf_threshold else compressor)
            pl, dfn = _compress_leaf(
                comp_i, keys[i] if comp_i.stochastic else None, delta)
            payloads.append(pl)
            dense_fns.append(dfn)
            qd = dfn(pl)
            q_dense.append(qd)
            new_hat.append(lhat + qd.reshape(lh.shape).astype(lhat.dtype))

        nbr_sum = [q * 0.0 for q in q_dense]
        for a, n in zip(axes, sizes):
            if n < 2:
                continue
            got = jax.lax.ppermute(payloads, a, ring_perm(n, 1))
            nbr_sum = [acc + dfn(g) for acc, dfn, g in zip(nbr_sum, dense_fns, got)]
            if n > 2:
                got = jax.lax.ppermute(payloads, a, ring_perm(n, -1))
                nbr_sum = [acc + dfn(g) for acc, dfn, g in zip(nbr_sum, dense_fns, got)]

        new_s, new_x = _choco_leaf_updates(leaves_h, leaves_s, q_dense,
                                           nbr_sum, new_hat, w, w, gamma)
        unflatten = treedef.unflatten
        return unflatten(new_x), unflatten(new_hat), unflatten(new_s)

    return local_fn


def make_choco_gossip_fn(*, axis: str, axis_size: int, compressor: Compressor,
                         gamma: float, exact_small_leaves: bool = False,
                         small_leaf_threshold: int = 8_192,
                         packed: bool = True,
                         pack_align: Optional[int] = None,
                         leaf_routes: Optional[list] = None) -> Callable:
    """Returns local_fn(key, x_half, x_hat, s) -> (x, x_hat, s) for shard_map.

    Implements (per local shard):
        q      = Q(x_half - x_hat)
        x_hat += q
        s     += sum_j w_ij q_j            (self + ring neighbours, ppermute'd)
        x      = x_half + gamma (s - x_hat)

    packed=True (default): bucketed flat-buffer engine — the pytree is packed
    into a few dtype-homogeneous buckets (spec from comm/packing.py), each
    compressed once and shipped as one static-shape payload per neighbour.
    packed=False: legacy per-leaf compression + one ppermute per leaf.

    exact_small_leaves: leaves below the threshold (norm scales, biases) ship
    uncompressed — for a top-1% sparsifier the (value, index) pair costs 8
    bytes/coordinate, so compressing a 4 KB norm vector saves nothing while
    adding top-k latency; beyond-paper toggle, off for paper-faithful runs.
    In the packed engine this is a bucket-routing rule: small leaves go to a
    dense "exact" bucket instead of taking a per-leaf branch.
    """
    from repro.core.compression import Identity
    identity = Identity()
    w_self, w_nbr = ring_weights(axis_size)
    fwd = ring_perm(axis_size, 1)     # receive from left neighbour
    bwd = ring_perm(axis_size, -1)    # receive from right neighbour
    align = _pack_align(compressor, pack_align)

    def packed_local_fn(key, x_half, x_hat, s):
        from repro.comm.packing import (bucket_dense, make_bucket_spec,
                                        payloads_dense_leaves, unpack_leaves)
        # distinct randomness per gossip node and per model/fsdp shard
        key = jax.random.fold_in(key, jax.lax.axis_index(axis))
        leaves_h, leaves_hat, leaves_s, treedef = _flatten_states(
            x_half, x_hat, s)
        spec = make_bucket_spec(leaves_hat, align=align,
                                exact_small_leaves=exact_small_leaves,
                                small_leaf_threshold=small_leaf_threshold,
                                routes=leaf_routes)
        payloads, q_leaves, new_hat = _packed_self_half(
            compressor, key, leaves_h, leaves_hat, spec)

        if axis_size == 1:
            nbr_leaves = [q * 0.0 for q in q_leaves]
        elif axis_size == 2:
            got = jax.lax.ppermute(payloads, axis, fwd)
            nbr_leaves = payloads_dense_leaves(spec, got)
        else:
            got_l = jax.lax.ppermute(payloads, axis, fwd)
            got_r = jax.lax.ppermute(payloads, axis, bwd)
            nbr_bufs = [bucket_dense(l, b) + bucket_dense(r, b)
                        for l, r, b in zip(got_l, got_r, spec.buckets)]
            nbr_leaves = unpack_leaves(spec, nbr_bufs)

        new_s, new_x = _choco_leaf_updates(leaves_h, leaves_s, q_leaves,
                                           nbr_leaves, new_hat,
                                           w_self, w_nbr, gamma)
        unflatten = treedef.unflatten
        return unflatten(new_x), unflatten(new_hat), unflatten(new_s)

    if packed:
        return packed_local_fn

    def local_fn(key, x_half, x_hat, s):
        # distinct randomness per gossip node and per model/fsdp shard
        key = jax.random.fold_in(key, jax.lax.axis_index(axis))
        leaves_h, treedef = jax.tree_util.tree_flatten(x_half)
        leaves_hat = treedef.flatten_up_to(x_hat)
        leaves_s = treedef.flatten_up_to(s)
        keys = _leaf_keys(key, len(leaves_h), 0)

        payloads, dense_fns, new_hat, q_dense = [], [], [], []
        for i, (lh, lhat) in enumerate(zip(leaves_h, leaves_hat)):
            # compress in the EF-state dtype: bf16 states -> bf16 wire values
            delta = (lh.astype(lhat.dtype) - lhat).ravel()
            comp_i = (identity if exact_small_leaves
                      and delta.size <= small_leaf_threshold else compressor)
            pl, dfn = _compress_leaf(
                comp_i, keys[i] if comp_i.stochastic else None, delta)
            payloads.append(pl)
            dense_fns.append(dfn)
            qd = dfn(pl)
            q_dense.append(qd)
            new_hat.append(lhat + qd.reshape(lh.shape).astype(lhat.dtype))

        if axis_size == 1:
            nbr_sum = [q * 0.0 for q in q_dense]
        elif axis_size == 2:
            got = jax.lax.ppermute(payloads, axis, fwd)
            nbr_sum = [dfn(g) for dfn, g in zip(dense_fns, got)]
        else:
            got_l = jax.lax.ppermute(payloads, axis, fwd)
            got_r = jax.lax.ppermute(payloads, axis, bwd)
            nbr_sum = [dfn(l) + dfn(r)
                       for dfn, l, r in zip(dense_fns, got_l, got_r)]

        new_s, new_x = _choco_leaf_updates(leaves_h, leaves_s, q_dense,
                                           nbr_sum, new_hat,
                                           w_self, w_nbr, gamma)
        unflatten = treedef.unflatten
        return unflatten(new_x), unflatten(new_hat), unflatten(new_s)

    return local_fn


def make_plain_gossip_fn(*, axis: str, axis_size: int) -> Callable:
    """Exact neighbour averaging (Algorithm 3): x = sum_j w_ij x_j."""
    w_self, w_nbr = ring_weights(axis_size)
    fwd = ring_perm(axis_size, 1)
    bwd = ring_perm(axis_size, -1)

    def local_fn(key, x_half, x_hat, s):
        del key
        if axis_size == 1:
            return x_half, x_hat, s
        if axis_size == 2:
            other = jax.lax.ppermute(x_half, axis, fwd)
            new_x = jax.tree.map(lambda a, b: w_self * a + w_nbr * b, x_half, other)
        else:
            left = jax.lax.ppermute(x_half, axis, fwd)
            right = jax.lax.ppermute(x_half, axis, bwd)
            new_x = jax.tree.map(lambda a, b, c: w_self * a + w_nbr * (b + c),
                                 x_half, left, right)
        return new_x, x_hat, s

    return local_fn


def make_allreduce_fn(*, axis: str, axis_size: int) -> Callable:
    """Centralized baseline: exact average over the gossip axis (all-reduce)."""
    def local_fn(key, x_half, x_hat, s):
        del key
        new_x = jax.tree.map(lambda a: jax.lax.pmean(a, axis), x_half)
        return new_x, x_hat, s
    return local_fn


def make_gossip_exchange(*, mode: str, mesh, state_specs, axis: str,
                         compressor: Optional[Compressor] = None,
                         gamma: float = 1.0, exact_small_leaves: bool = False,
                         small_leaf_threshold: int = 8_192,
                         packed: bool = True,
                         pack_align: Optional[int] = None) -> Callable:
    """Build the jit-able exchange: (key, x_half, x_hat, s) -> (x, x_hat, s).

    state_specs: pytree of PartitionSpec matching the param pytree (with the
    leading node dim mapped to `axis`).  packed selects the bucketed
    flat-buffer engine (default) vs the legacy per-leaf exchange.
    """
    if isinstance(axis, (tuple, list)):        # 2-D torus gossip
        sizes = tuple(mesh.shape[a] for a in axis)
        if mode != "choco":
            raise NotImplementedError("torus gossip implemented for choco mode")
        local_fn = make_choco_gossip_2d_fn(
            axes=tuple(axis), sizes=sizes, compressor=compressor, gamma=gamma,
            exact_small_leaves=exact_small_leaves,
            small_leaf_threshold=small_leaf_threshold,
            packed=packed, pack_align=pack_align,
            leaf_routes=_leaf_routes(state_specs, axis))
        return shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(), state_specs, state_specs, state_specs),
            out_specs=(state_specs, state_specs, state_specs),
        )
    axis_size = mesh.shape[axis]
    if mode == "choco":
        local_fn = make_choco_gossip_fn(axis=axis, axis_size=axis_size,
                                        compressor=compressor, gamma=gamma,
                                        exact_small_leaves=exact_small_leaves,
                                        small_leaf_threshold=small_leaf_threshold,
                                        packed=packed, pack_align=pack_align,
                                        leaf_routes=_leaf_routes(state_specs, axis))
    elif mode == "plain":
        local_fn = make_plain_gossip_fn(axis=axis, axis_size=axis_size)
    elif mode == "allreduce":
        local_fn = make_allreduce_fn(axis=axis, axis_size=axis_size)
    else:
        raise ValueError(mode)

    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), state_specs, state_specs, state_specs),
        out_specs=(state_specs, state_specs, state_specs),
    )
