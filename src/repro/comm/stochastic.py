"""Stochastic topology processes: per-step distributions over mixing matrices.

The process engines realize the source paper's Algorithm 2 (per-neighbour
public copies) rather than the Algorithm-5 aggregate — §Perf F of
EXPERIMENTS.md records why (the s-aggregate is a noise integrator under
sampled W) plus the consensus-rate and single-launch audits; the
bounded-staleness member of this family lives in comm/async_gossip.py
(§Perf G).

PR 2's schedule compiler turned a *fixed* Topology into a static round
decomposition.  Real deployments see time-varying and unreliable links, and
the theory tolerates them: Koloskova et al. (2020) show CHOCO-style error
feedback converges under stochastic mixing as long as the *expected* W mixes.
This module generalizes the static ``Topology -> GossipSchedule`` pipeline to
a :class:`TopologyProcess` — a per-step distribution over mixing matrices
with three consumers:

  * the distributed gossip engines (``comm/gossip.py``) — every CHOCO / plain
    exchange accepts a process and replays its *sampled* rounds instead of
    the full static schedule;
  * the matrix simulator (``sample_matrix``) — the (n, n) mixing matrix of a
    given (key, t), used for parity tests and benchmarks;
  * the trainer — ``expected_matrix`` / ``expected_delta_beta`` feed the
    Theorem-2 stepsize with the *expected*-W eigengap.

Two process families:

  * :class:`MatchingProcess` — each step samples ONE round of the compiled
    schedule (uniform or weighted by round mass), with the round's receive
    weights scaled by 1/p_r so the expected mixing matrix equals the static
    W **exactly**.  Per-step wire cost drops from ``n_rounds`` permute
    launches to one (``lax.switch`` over single-round branches).
  * :class:`LinkFailureProcess` — i.i.d. Bernoulli edge drops on any
    compiled schedule; a dropped edge's weight is folded back into both
    endpoints' self weight, so every sampled W stays row-stochastic,
    symmetric, and nonnegative.  E[W_t] = (1 - p) W + p I, and the trainer
    re-derives gamma from that expected matrix's eigengap.

A note on the compressed engine: CHOCO's memory-efficient aggregate
s_i = sum_tau (W q_tau)_i is an identity that holds only for a FIXED W —
under per-step sampled W_t it integrates sampling noise without decay and
the iterates drift (verified empirically; the information is simply never
on the wire).  The distributed engine therefore runs the source paper's
Algorithm-2 form with *per-round reference replicas*
(comm/gossip.py ``make_process_choco_fn``), whose matrix twin is
:func:`choco_process_round` here.  The plain engine needs no replicas: its
payload is the fresh iterate, so sampled mixing is exact as-is.

Determinism contract (the "no communication" seed plumbing): every sample is
a pure function of the *pre-axis-fold* exchange key and the in-step round
index t, via ``jax.random.fold_in(key, SAMPLE_SALT + t)``.  The trainer
already passes ``fold_in(state.key, state.step)`` as the exchange key, so all
nodes — and all engines (packed / per-leaf / plain) and the simulator — draw
the identical round from the same seed without exchanging a single byte.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.schedule import (GossipRound, GossipSchedule,
                                 compile_schedule, round_recv_vec)
from repro.core.topology import Topology, beta_norm, spectral_gap

#: fold_in salt separating topology sampling from compressor randomness
#: (which folds per-axis node ids and per-leaf salts on the same key)
SAMPLE_SALT = 0x70C0

_MATCHING_SAMPLERS = ("uniform", "weighted")


def _round_matrix(rnd: GossipRound, n: int,
                  scale: float = 1.0) -> np.ndarray:
    """Off-diagonal contribution of one round, scaled."""
    M = np.zeros((n, n), dtype=np.float64)
    for src, dst in rnd.perm:
        w = rnd.weight if rnd.weight is not None else rnd.weights[dst]
        M[dst, src] += w * scale
    return M


class TopologyProcess:
    """Base: a per-step distribution over n x n mixing matrices.

    Subclasses provide ``sample_matrix(key, t)`` (traced, for the simulator)
    plus the static descriptors the distributed engines replay; the shared
    ``_sample_key`` fold is THE determinism contract — engine and simulator
    must derive every random draw from it identically."""

    kind: str = "abstract"
    schedule: GossipSchedule

    @property
    def n(self) -> int:
        return self.schedule.n

    @staticmethod
    def _sample_key(key: jax.Array, t: int) -> jax.Array:
        return jax.random.fold_in(key, SAMPLE_SALT + t)

    def sample_matrix(self, key: jax.Array, t: int) -> jax.Array:
        raise NotImplementedError

    def expected_matrix(self) -> np.ndarray:
        raise NotImplementedError

    def expected_delta_beta(self) -> Tuple[float, float]:
        """(delta, beta) of the EXPECTED mixing matrix — what the Theorem-2
        consensus stepsize should be computed from under stochastic mixing
        (Koloskova et al. 2020 analyze exactly this quantity)."""
        E = self.expected_matrix()
        return spectral_gap(E), beta_norm(E)

    def effective_omega(self, omega: float) -> float:
        """Assumption-1 compression quality as the Theorem-2 stepsize should
        see it under this process.  The default is the compressor's own
        omega; processes that let compressed increments go stale before they
        are consumed (comm/async_gossip.py StalenessProcess) shrink it by
        their worst-case outstanding-update count."""
        return omega


def _index_schedule_edges(schedule: GossipSchedule):
    """Canonical undirected-edge indexing of a compiled schedule's support.

    Returns ``(edges, round_edge_ids, round_recv)``: ``edges`` is the tuple
    of canonical ``(min, max)`` node pairs in first-seen order;
    ``round_edge_ids[r][dst]`` is the edge id feeding destination ``dst`` in
    round r (−1 when the round's partial permutation skips it); and
    ``round_recv[r]`` is the round's per-destination receive-weight vector.
    Both directions of a physical link map to ONE edge id, which is what
    lets :class:`LinkFailureProcess` drop them together and
    :class:`~repro.comm.async_gossip.StalenessProcess` delay them together
    (delays must be shared per edge or the pairwise stale exchange would
    stop preserving the node average)."""
    n = schedule.n
    edges = {}                      # canonical {i, j} -> edge id
    round_edge_ids = []             # per round: (n,) dst -> edge id | -1
    round_recv = []                 # per round: (n,) receive weights
    for rnd in schedule.rounds:
        ids = np.full(n, -1, dtype=np.int32)
        for src, dst in rnd.perm:
            e = (min(src, dst), max(src, dst))
            if e not in edges:
                edges[e] = len(edges)
            ids[dst] = edges[e]
        round_edge_ids.append(tuple(int(v) for v in ids))
        round_recv.append(tuple(round_recv_vec(rnd, n)))
    return (tuple(sorted(edges, key=edges.get)), tuple(round_edge_ids),
            tuple(round_recv))


@dataclasses.dataclass(frozen=True, eq=False)
class MatchingProcess(TopologyProcess):
    """Randomized matchings: sample one edge-colored round per gossip round.

    Round r of the compiled schedule is drawn with probability ``probs[r]``
    and its receive weights are scaled by ``1 / probs[r]``; everything the
    node does not receive goes to its self weight.  The sampled matrix is
    therefore row-stochastic and (for symmetric schedules) symmetric, and

        E[W_t] = sum_r p_r (I - diag(v_r / p_r) + M_r / p_r) = W   exactly,

    because the rounds partition W's off-diagonal mass.  Samplers:

      * ``uniform``  — p_r = 1/R;
      * ``weighted`` — p_r proportional to the round's maximum receive
        weight, which minimizes the worst-case per-round upscale and keeps
        heavier rounds (that carry more of W's mass) sampled more often.

    Feasibility (scaled weights must stay <= 1 so self weights stay >= 0) is
    checked at build time — an infeasible sampler raises with the binding
    round rather than silently producing a non-stochastic W.
    """
    schedule: GossipSchedule
    sampler: str = "uniform"

    def __post_init__(self):
        if self.sampler not in _MATCHING_SAMPLERS:
            raise ValueError(f"unknown matching sampler {self.sampler!r}; "
                             f"have {_MATCHING_SAMPLERS}")
        R = self.schedule.n_rounds
        if R == 0:
            raise ValueError("matching process needs a schedule with at "
                             "least one round (n >= 2)")
        n = self.schedule.n
        recv = np.stack([round_recv_vec(r, n) for r in self.schedule.rounds])
        if self.sampler == "uniform":
            probs = np.full(R, 1.0 / R)
        else:
            mass = recv.max(axis=1)
            probs = mass / mass.sum()
        scaled = recv / probs[:, None]
        worst = float(scaled.max())
        if worst > 1.0 + 1e-9:
            r_bad = int(np.unravel_index(np.argmax(scaled), scaled.shape)[0])
            raise ValueError(
                f"matching sampler {self.sampler!r} infeasible for "
                f"{self.schedule.name!r}: round {r_bad} scales a receive "
                f"weight to {worst:.3f} > 1 (self weight would go negative); "
                f"try sampler='weighted' or a topology with fewer rounds")
        object.__setattr__(self, "probs", tuple(float(p) for p in probs))
        # per-branch scaled receive vectors and self weights (1 - received)
        object.__setattr__(self, "branch_recv",
                           tuple(tuple(row) for row in scaled))
        object.__setattr__(self, "branch_self",
                           tuple(tuple(1.0 - row) for row in scaled))
        # per-round data movement, for the simulator twin of the replica
        # engine: source node per destination (self when not receiving) and
        # the sender indicator
        srcs, sends = [], []
        for rnd in self.schedule.rounds:
            sv = np.arange(n)
            mv = np.zeros(n)
            for src, dst in rnd.perm:
                sv[dst] = src
                mv[src] = 1.0
            srcs.append(tuple(int(v) for v in sv))
            sends.append(tuple(mv))
        object.__setattr__(self, "round_src", tuple(srcs))
        object.__setattr__(self, "round_send", tuple(sends))

    kind = "matching"

    @property
    def n_rounds(self) -> int:
        return self.schedule.n_rounds

    def round_index(self, key: jax.Array, t: int) -> jax.Array:
        """Sampled round id for gossip round t — identical on every node
        (pure function of the shared exchange key).  Inverse-CDF over the
        static cumulative probs (jax.random.choice's searchsorted lowers to
        a scan that shard_map's replication checker rejects)."""
        k = self._sample_key(key, t)
        u = jax.random.uniform(k)
        cum = np.cumsum(np.asarray(self.probs))[:-1]
        return jnp.sum(u >= jnp.asarray(cum, jnp.float32)).astype(jnp.int32)

    def branch_matrices(self) -> np.ndarray:
        """(R, n, n) stack of the per-branch sampled mixing matrices."""
        n = self.n
        mats = []
        for r, rnd in enumerate(self.schedule.rounds):
            M = _round_matrix(rnd, n, scale=1.0 / self.probs[r])
            mats.append(np.diag(np.asarray(self.branch_self[r])) + M)
        return np.stack(mats)

    def sample_matrix(self, key: jax.Array, t: int) -> jax.Array:
        return jnp.asarray(self.branch_matrices())[self.round_index(key, t)]

    def expected_matrix(self) -> np.ndarray:
        return np.einsum("r,rij->ij", np.asarray(self.probs),
                         self.branch_matrices())


@dataclasses.dataclass(frozen=True, eq=False)
class LinkFailureProcess(TopologyProcess):
    """I.i.d. Bernoulli link failures over a compiled schedule.

    Each undirected edge {i, j} of the schedule's support drops with
    probability ``drop_prob``, independently per gossip round; both
    directions drop together (the physical link is down), and each
    endpoint's lost receive weight is folded back into its self weight:

        W_t = diag(W) + M_t . (W - diag(W)) + diag((1 - M_t) row-mass)

    which keeps every sample row-stochastic, symmetric, and nonnegative.
    E[W_t] = (1 - p) W + p I, so the expected eigengap is (1 - p) delta —
    ``expected_delta_beta`` hands the trainer exactly that for gamma.
    """
    schedule: GossipSchedule
    drop_prob: float = 0.1

    def __post_init__(self):
        if not 0.0 <= self.drop_prob < 1.0:
            raise ValueError(f"drop_prob must be in [0, 1), got "
                             f"{self.drop_prob} (p = 1 never mixes)")
        edges, round_edge_ids, round_recv = _index_schedule_edges(
            self.schedule)
        object.__setattr__(self, "n_edges", len(edges))
        object.__setattr__(self, "_edges", edges)
        object.__setattr__(self, "round_edge_ids", round_edge_ids)
        object.__setattr__(self, "round_recv", round_recv)

    kind = "linkfail"

    def edge_mask(self, key: jax.Array, t: int) -> jax.Array:
        """(n_edges,) float32 keep-mask (1 = link up) for gossip round t —
        same on every node, drawn from the shared exchange key."""
        k = self._sample_key(key, t)
        keep = jax.random.bernoulli(k, 1.0 - self.drop_prob,
                                    (max(self.n_edges, 1),))
        return keep.astype(jnp.float32)

    def round_masks(self, mask: jax.Array):
        """Per-round (n,) per-destination keep values from the edge mask."""
        out = []
        for ids in self.round_edge_ids:
            idx = jnp.asarray(ids)
            out.append(jnp.where(idx >= 0, mask[jnp.clip(idx, 0)], 0.0))
        return out

    def sample_matrix(self, key: jax.Array, t: int) -> jax.Array:
        n = self.n
        mask = self.edge_mask(key, t)
        rmasks = self.round_masks(mask)
        W = jnp.zeros((n, n))
        recv_total = jnp.zeros(n)
        for rnd, rm, recv in zip(self.schedule.rounds, rmasks,
                                 self.round_recv):
            M = jnp.zeros((n, n))
            for src, dst in rnd.perm:
                w = (rnd.weight if rnd.weight is not None
                     else rnd.weights[dst])
                M = M.at[dst, src].add(w * rm[dst])
            W = W + M
            recv_total = recv_total + jnp.asarray(recv) * rm
        return W + jnp.diag(1.0 - recv_total)

    def expected_matrix(self) -> np.ndarray:
        W = np.asarray(self.schedule.mixing_matrix())
        p = self.drop_prob
        return (1.0 - p) * W + p * np.eye(self.n)


# ---------------------------------------------------------------------------
# builders + matrix simulators
# ---------------------------------------------------------------------------

def make_topology_process(kind: str, schedule: GossipSchedule, *,
                          matching_sampler: str = "uniform",
                          edge_drop_prob: float = 0.1,
                          max_staleness: int = 1,
                          delay_probs=None,
                          straggler_edges=None,
                          straggler_delay_probs=None) -> TopologyProcess:
    """Named-process registry mirrored by the ``--topology-process`` CLI."""
    if kind == "matching":
        return MatchingProcess(schedule, sampler=matching_sampler)
    if kind == "linkfail":
        return LinkFailureProcess(schedule, drop_prob=edge_drop_prob)
    if kind == "staleness":
        from repro.comm.async_gossip import StalenessProcess
        return StalenessProcess(schedule, max_staleness=max_staleness,
                                delay_probs=delay_probs,
                                straggler_edges=straggler_edges,
                                straggler_delay_probs=straggler_delay_probs)
    raise ValueError(f"unknown topology process {kind!r}; "
                     f"have ('matching', 'linkfail', 'staleness')")


def process_from_topology(kind: str, topo: Topology, **kw) -> TopologyProcess:
    """Convenience: compile ``topo`` and build the named process over it."""
    return make_topology_process(kind, compile_schedule(topo), **kw)


class ProcessGossipState:
    """Matrix-simulator state for the replica-based process engine
    (comm/gossip.py make_process_choco_fn).

    x: (n, d) iterates.  refs: matching — (R, n, d) per-round own references
    H_r (the global view IS the replica set: node i's round-r source replica
    equals row src_r(i) of H_r); linkfail — (n, d) single public copy x_hat
    (replicas are exact because every round always ships)."""

    def __init__(self, x: jax.Array, refs: jax.Array):
        self.x = x
        self.refs = refs


def init_process_state(x0: jax.Array,
                       process: TopologyProcess) -> ProcessGossipState:
    """Zero-initialised simulator state with the process's reference layout
    (matching: (R, n, d) per-round refs; linkfail: a single (n, d) copy)."""
    if process.kind == "matching":
        R = process.schedule.n_rounds
        refs = jnp.zeros((R,) + x0.shape, x0.dtype)
    else:
        refs = jnp.zeros_like(x0)
    return ProcessGossipState(x0, refs)


def choco_process_round(state: ProcessGossipState, process: TopologyProcess,
                        gamma: float, compressor, key: jax.Array, t: int = 0,
                        comp_key: Optional[jax.Array] = None
                        ) -> ProcessGossipState:
    """One round of the SOUND process algorithm — the matrix twin of
    ``make_process_choco_fn`` (see its docstring for why the static engine's
    s-aggregate cannot be reused under sampled W).  ``key`` is the EXCHANGE
    key (pre-axis-fold); engine parity requires driving both with the same
    key sequence and a deterministic compressor.

    matching:  r ~ probs;  q = Q(x - H_r);  H_r += send_r . q;
               x += gamma * v_r . (H_r[src_r] - H_r)
    linkfail:  q = Q(x - x_hat);  x_hat += q;  m ~ Bernoulli edge mask;
               x += gamma * (W_m - I) x_hat      (fresh public copies)
    """
    from repro.core.choco_gossip import _rowwise_compress
    x = state.x
    if process.kind == "matching":
        H = state.refs
        idx = process.round_index(key, t)
        q = _rowwise_compress(compressor, comp_key,
                              x - H[idx])
        send = jnp.asarray(process.round_send)[idx][:, None]
        Hr = H[idx] + send * q
        H = H.at[idx].set(Hr)
        src = jnp.asarray(process.round_src)[idx]
        v = jnp.asarray(process.branch_recv)[idx][:, None]
        x = x + gamma * v * (Hr[src, :] - Hr)
        return ProcessGossipState(x, H)
    if process.kind == "linkfail":
        x_hat = state.refs
        q = _rowwise_compress(compressor, comp_key, x - x_hat)
        x_hat = x_hat + q
        W = process.sample_matrix(key, t)
        x = x + gamma * (W - jnp.eye(process.n)) @ x_hat
        return ProcessGossipState(x, x_hat)
    raise ValueError(process.kind)


def run_choco_gossip_process(x0: jax.Array, process: TopologyProcess,
                             gamma: float, compressor, steps: int,
                             key: Optional[jax.Array] = None):
    """Run `steps` single-round exchanges under the process, mirroring the
    trainer's seed plumbing (exchange key = fold_in(key, step)).  Returns
    (final ProcessGossipState, per-step consensus errors)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    xbar = jnp.mean(x0, axis=0, keepdims=True)
    st = init_process_state(x0, process)
    errs = []
    for step in range(steps):
        ek = jax.random.fold_in(key, step)
        ck = jax.random.fold_in(ek, 1) if compressor.stochastic else None
        st = choco_process_round(st, process, gamma, compressor, ek,
                                 t=0, comp_key=ck)
        errs.append(jnp.mean(jnp.sum((st.x - xbar) ** 2, axis=-1)))
    return st, jnp.stack(errs)
