"""Asynchronous bounded-staleness gossip: proceed on the freshest copy held.

Audited in EXPERIMENTS.md §Perf G; distributed acceptance in
tests/test_async_gossip.py.

Everything before this module was *synchronous*: a gossip round either
delivered a payload this step (static schedules, randomized matchings) or
dropped it outright (link failures).  Real interconnects have a third
behaviour — the payload arrives, but **late** — and nodes that wait for slow
links serialize the whole mesh on its worst edge.  This module models the
standard fix: every node proceeds every step using the freshest neighbour
copy it *has*, with the delay bounded by ``max_staleness`` (tau).

CHOCO-style error feedback is exactly the right substrate for this
(Koloskova et al. 2019, *Decentralized Deep Learning with Arbitrary
Communication Compression*, analyze the same machinery): a stale public copy
``x_hat_j^(t-d)`` differs from the fresh one by the last ``d`` compressed
increments, i.e. staleness is just *additional accumulated compression
error*, and the Theorem-2 Lyapunov argument tolerates it as long as the
bound tau is finite.

:class:`StalenessProcess` joins the ``TopologyProcess`` family
(comm/stochastic.py): per-edge delays ``d_e(t) in {0..tau}`` are drawn
i.i.d. from ``delay_probs`` via the shared pre-axis-fold exchange key
(``fold_in(key, SAMPLE_SALT + t)``), so every node — and the matrix
simulator — sees the identical delay draw with zero coordination bytes.
Both directions of a physical link share one delay (the canonical edge
indexing of :func:`~repro.comm.stochastic._index_schedule_edges`), which is
what keeps the update average-preserving (see below).

The algorithm (paper Algorithm 2 with delayed public copies); per node i,
per gossip round t:

    q_i      = Q(x_i - x_hat_i)        one compression, all rounds ship it
    x_hat_i += q_i                     own ring buffer records q_i
    S_r     += received q              per-round source replica (fresh)
    ring_r   records the received q    (per-round receive ring buffer)
    d        = sampled delay of node i's round-r edge
    x_i     += gamma * sum_r v_r[i] * (x_hat_src^(t-d) - x_hat_i^(t-d))

where the **stale pair** is reconstructed locally from the rings:

    x_hat_src^(t-d) = S_r     - sum_{j<d} ring_r[j]
    x_hat_i^(t-d)   = x_hat_i - sum_{j<d} own_ring[j]

Three properties fall out of this construction:

  * **Average preservation** — node i mixes toward its neighbour's stale
    copy *relative to its own equally-stale copy*; with w_ij = w_ji and the
    per-edge shared delay, the two endpoints' updates cancel pairwise, so
    ``1^T x`` is invariant step by step
    (``test_average_preserved_exactly``).
  * **Zero extra collectives** — every compiled round still ships every
    step (the payload is in flight; only *which snapshot the update reads*
    changes), and the arrived-vs-stale selection is a `where`-mask over the
    static-shape ring slots.  The compiled HLO therefore carries exactly
    the link-failure baseline's permute launches
    (``test_async_permute_count_equals_linkfail``).
  * **Subsumption** — a dropped link is staleness ``infinity`` for one
    step: the link-failure freshness factor (1 - p) is the p -> 1-p limit
    of this module's delay-averaged freshness phi (see
    :meth:`StalenessProcess.expected_matrix`).

Theorem-2 stepsize under staleness: gamma is re-derived from the
*delay-averaged* mixing matrix — per edge, ``E_eff`` delivers the edge
weight at its freshness rate ``phi_e = E[1/(1+d_e)]`` and folds the
remainder into the diagonal (with one global delay distribution this is
exactly ``phi W + (1 - phi) I``, mirroring ``LinkFailureProcess``'s
``E[W] = (1-p) W + p I``); and the delay distribution folds into omega as
the distribution-aware ``omega * phi`` (minimum per-edge phi_e when
straggler edges give links their own distributions; the point mass at tau
recovers the historical worst-case ``omega / (1 + tau)``) —
:meth:`StalenessProcess.effective_omega`.

Per-edge heterogeneity: ``straggler_edges`` / ``straggler_delay_probs``
give named physical links their own delay distribution (default point mass
at tau — a maximally slow link), so one straggler is expressible without
slowing the whole mesh; the engines and the matrix simulator pick this up
automatically because delays enter both ONLY through
:meth:`StalenessProcess.edge_delays`' per-edge cumulative table.

State cost: the engine keeps (1 + tau) own trees (public copy + ring) and
R * (1 + tau) source trees (replica + ring per round) — the per-round
replica machinery of PR 4's process engine extended by a depth-tau ring.
The trainer allocates ``x_hat`` / ``s`` as flat lists accordingly; the
matrix simulator (core/choco_gossip.py ``choco_stale_round``) needs only
(x, x_hat, ring) because the global view makes every replica a row of the
global state.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.schedule import GossipSchedule
from repro.comm.stochastic import TopologyProcess, _index_schedule_edges
from repro.core.compression import Compressor


@dataclasses.dataclass(frozen=True, eq=False)
class StalenessProcess(TopologyProcess):
    """Bounded-staleness delay process over a compiled schedule's edges.

    Each undirected edge of the schedule's support draws an i.i.d. delay
    ``d in {0..max_staleness}`` per gossip round from ``delay_probs``
    (``delay_probs[k]`` = P(d = k); None = uniform).  Both directions of a
    link share the draw, and every node derives the identical draw from the
    shared exchange key — the engines and the matrix simulator never
    exchange a byte of delay metadata.

    ``max_staleness = 0`` forces every edge fresh and reduces the engine to
    the static Algorithm-2 replica form (the link-failure engine at p = 0).

    Per-edge heterogeneity (stragglers): ``straggler_edges`` names physical
    links (canonical ``(min, max)`` node pairs from the schedule's edge
    support) whose delays are drawn from ``straggler_delay_probs`` instead
    of the global ``delay_probs`` — so a single slow link / straggler node
    is expressible without slowing the whole mesh.  ``straggler_delay_probs``
    defaults to the point mass at ``max_staleness`` (a maximally slow link);
    naming an edge outside the schedule's support raises ``ValueError``.
    """
    schedule: GossipSchedule
    max_staleness: int = 1
    delay_probs: Optional[Tuple[float, ...]] = None
    straggler_edges: Optional[Tuple[Tuple[int, int], ...]] = None
    straggler_delay_probs: Optional[Tuple[float, ...]] = None

    kind = "staleness"

    def _normalize_probs(self, probs, what: str) -> Tuple[float, ...]:
        """Validate and normalize a delay distribution over {0..tau}."""
        tau = self.max_staleness
        arr = np.asarray(probs, dtype=np.float64)
        if arr.shape != (tau + 1,):
            raise ValueError(
                f"{what} needs max_staleness + 1 = {tau + 1} "
                f"entries (P(d=0..{tau})), got shape {arr.shape}")
        if arr.min() < 0 or arr.sum() <= 0:
            raise ValueError(f"{what} must be nonnegative with "
                             f"positive mass, got {tuple(arr)}")
        return tuple(float(p) for p in arr / arr.sum())

    def __post_init__(self):
        tau = self.max_staleness
        if tau < 0:
            raise ValueError(f"max_staleness must be >= 0, got {tau}")
        if self.schedule.n_rounds == 0:
            raise ValueError("staleness process needs a schedule with at "
                             "least one round (n >= 2)")
        if self.delay_probs is None:
            probs = tuple(1.0 / (tau + 1) for _ in range(tau + 1))
        else:
            probs = self._normalize_probs(self.delay_probs, "delay_probs")
        object.__setattr__(self, "delay_probs", probs)
        edges, round_edge_ids, round_recv = _index_schedule_edges(
            self.schedule)
        object.__setattr__(self, "n_edges", len(edges))
        object.__setattr__(self, "_edges", edges)
        object.__setattr__(self, "round_edge_ids", round_edge_ids)
        object.__setattr__(self, "round_recv", round_recv)
        # per-edge delay distributions: global row everywhere, straggler
        # rows overridden (point mass at tau unless given explicitly)
        if self.straggler_delay_probs is not None \
                and self.straggler_edges is None:
            raise ValueError("straggler_delay_probs given without "
                             "straggler_edges")
        table = np.tile(np.asarray(probs), (max(len(edges), 1), 1))
        if self.straggler_edges is not None:
            if self.straggler_delay_probs is None:
                sprobs = tuple(0.0 for _ in range(tau)) + (1.0,)
            else:
                sprobs = self._normalize_probs(self.straggler_delay_probs,
                                               "straggler_delay_probs")
            object.__setattr__(self, "straggler_delay_probs", sprobs)
            canon = []
            edge_pos = {e: k for k, e in enumerate(edges)}
            for a, b in self.straggler_edges:
                e = (min(int(a), int(b)), max(int(a), int(b)))
                if e not in edge_pos:
                    raise ValueError(
                        f"unknown straggler edge {a}-{b}: the schedule's "
                        f"edge support is {list(edges)}")
                canon.append(e)
                table[edge_pos[e]] = np.asarray(sprobs)
            object.__setattr__(self, "straggler_edges", tuple(canon))
        object.__setattr__(self, "edge_delay_probs",
                           tuple(tuple(float(p) for p in row)
                                 for row in table))
        # per-round source node per destination (self when not receiving):
        # the simulator reads replicas as rows src_r of the global state
        n = self.schedule.n
        srcs = []
        for rnd in self.schedule.rounds:
            sv = np.arange(n)
            for src, dst in rnd.perm:
                sv[dst] = src
            srcs.append(tuple(int(v) for v in sv))
        object.__setattr__(self, "round_src", tuple(srcs))

    # -- delay statistics ---------------------------------------------------

    @property
    def mean_delay(self) -> float:
        """E[d] under ``delay_probs``."""
        return float(sum(k * p for k, p in enumerate(self.delay_probs)))

    @property
    def freshness(self) -> float:
        """phi = E[1/(1+d)] — the delay-averaged rate factor: a fixed
        delay-d exchange advances consensus at ~1/(1+d) the fresh rate, so
        phi is the expected fraction of a fresh exchange each edge delivers
        per step.  phi = 1 at tau = 0; a dropped link is the phi -> 0
        (d -> infinity) limit, recovering the LinkFailure model.  This is
        the GLOBAL distribution's phi; straggler edges carry their own
        (see :attr:`edge_freshness`)."""
        return float(sum(p / (1.0 + k)
                         for k, p in enumerate(self.delay_probs)))

    @property
    def edge_freshness(self) -> Tuple[float, ...]:
        """Per-edge phi_e = E[1/(1+d_e)] under each edge's own delay
        distribution — equals ``(freshness,) * n_edges`` when no straggler
        edges are configured."""
        return tuple(float(sum(p / (1.0 + k) for k, p in enumerate(row)))
                     for row in self.edge_delay_probs)

    # -- sampling (the shared-seed determinism contract) --------------------

    def edge_delays(self, key: jax.Array, t: int) -> jax.Array:
        """(n_edges,) int32 delays for gossip round t — identical on every
        node (pure function of the shared exchange key).  Inverse-CDF over
        each edge's static cumulative delay distribution, same lowering
        rationale as ``MatchingProcess.round_index`` (searchsorted-free).
        Without straggler edges every row of the cumulative table is the
        global distribution, so the draw is bit-identical to the historical
        single-distribution sampler (same uniforms, same thresholds)."""
        k = self._sample_key(key, t)
        u = jax.random.uniform(k, (max(self.n_edges, 1),))
        cum = np.cumsum(np.asarray(self.edge_delay_probs), axis=1)[:, :-1]
        return jnp.sum(u[:, None] >= jnp.asarray(cum, jnp.float32),
                       axis=1).astype(jnp.int32)

    def round_delays(self, delays: jax.Array):
        """Per-round (n,) per-destination delay vectors from the edge
        delays (0 where the round's partial permutation skips a node — the
        zero receive weight annihilates the term anyway)."""
        out = []
        for ids in self.round_edge_ids:
            idx = jnp.asarray(ids)
            out.append(jnp.where(idx >= 0, delays[jnp.clip(idx, 0)], 0))
        return out

    def round_delay_vecs(self, key: jax.Array, t: int):
        """Convenience for the matrix simulator: sampled per-round
        per-destination delays for gossip round t."""
        return self.round_delays(self.edge_delays(key, t))

    # -- theory surrogates for the trainer ----------------------------------

    def sample_matrix(self, key: jax.Array, t: int) -> jax.Array:
        raise NotImplementedError(
            "a bounded-staleness step mixes SNAPSHOTS from up to tau steps "
            "back — it is not a single (n, n) matrix on the current "
            "iterates.  Use core.choco_gossip.choco_stale_round (the "
            "delay-expanded simulator) for parity checks, and "
            "expected_matrix() for the delay-averaged theory surrogate.")

    def expected_matrix(self) -> np.ndarray:
        """Delay-averaged effective mixing matrix, built PER EDGE: each
        edge delivers its off-diagonal weight at its own
        freshness-discounted rate phi_e = E[1/(1+d_e)], the undelivered
        remainder ``(1 - phi_e) w`` folds into the destination's diagonal.
        With a single global delay distribution every phi_e = phi and this
        collapses to the historical ``phi W + (1 - phi) I`` exactly (rows
        of W sum to 1, so the folded remainders complete the diagonal).
        Same shape as the link-failure ``E[W] = (1-p) W + p I`` — a drop is
        the d -> infinity (phi -> 0) staleness limit — and what
        ``expected_delta_beta`` hands the Theorem-2 stepsize."""
        from repro.comm.schedule import round_recv_vec
        phis = self.edge_freshness
        E = np.diag(np.asarray(self.schedule.self_weights,
                               dtype=np.float64))
        for r, rnd in enumerate(self.schedule.rounds):
            recv = round_recv_vec(rnd, self.n)
            for src, dst in rnd.perm:
                e = self.round_edge_ids[r][dst]
                phi = phis[e] if e >= 0 else 1.0
                E[dst, src] += phi * recv[dst]
                E[dst, dst] += (1.0 - phi) * recv[dst]
        return E

    def effective_omega(self, omega: float) -> float:
        """Fold the delay distribution into the compression quality: a
        delay-d edge reads a snapshot missing the last d compressed
        increments, inflating the accumulated-compression-error term —
        exactly where omega enters the Theorem-2 Lyapunov recursion — by
        the same 1/(1+d) freshness factor that discounts the mixing.  The
        distribution-aware constant is ``omega_eff = omega * phi`` with
        phi = E[1/(1+d)] (the point mass at d = tau recovers the historical
        worst-case ``omega / (1 + tau)``; exact at tau = 0 where phi = 1).
        With straggler edges the SLOWEST edge governs the worst
        accumulated-error path, so the minimum per-edge phi_e is used."""
        return omega * min(self.edge_freshness)


# ---------------------------------------------------------------------------
# distributed engine (packed + per-leaf)
# ---------------------------------------------------------------------------

def make_async_choco_fn(*, axes: Tuple[str, ...], sizes: Tuple[int, ...],
                        process: StalenessProcess, compressor: Compressor,
                        gamma: float, gossip_steps: int = 1,
                        packed: bool = True,
                        pack_align: Optional[int] = None,
                        leaf_routes: Optional[list] = None) -> Callable:
    """Bounded-staleness CHOCO exchange for shard_map.

    Returns ``local_fn(key, x_half, hat_list, s_list)`` where

      * ``hat_list`` — (1 + tau) trees: the own public copy x_hat followed
        by the own ring (``hat_list[1 + j]`` = own q of j steps ago);
      * ``s_list`` — R * (1 + tau) trees: per-round source replicas S_r
        (``s_list[r]``) followed by the per-round receive rings
        (``s_list[R + r * tau + j]`` = round-r received q of j steps ago).

    Every compiled round ships the one shared payload every step — the wire
    schedule is IDENTICAL to the link-failure engine's (zero extra permute
    launches) — and the sampled per-edge delay only selects which ring
    prefix to subtract:

        stale_nbr - stale_own = (S_r - x_hat) - sum_{j<d} (ring_r[j] - own_ring[j])

    The masks ``[j < d]`` are where-style f32 scalars over static-shape ring
    slots, so the compiled step stays static-shape with no control flow.
    Replica consistency is the same argument as the link-failure engine's:
    the payload is ALWAYS sent and ALWAYS integrated (staleness gates only
    the snapshot the mixing update reads), so S_r tracks the round-r
    source's x_hat exactly and the rings hold its true last-tau increments.
    """
    n = 1
    for sz in sizes:
        n *= sz
    assert process.n == n, f"process n={process.n} != mesh extent {n}"
    assert gossip_steps >= 1
    from repro.comm.gossip import (_LazyFlatIndex, _ef_send_half,
                                   _make_compress_stage, _pack_align)
    axis_arg = axes[0] if len(axes) == 1 else tuple(axes)
    align = _pack_align(compressor, pack_align)
    rounds = process.schedule.rounds
    R = len(rounds)
    tau = process.max_staleness
    compress_stage = _make_compress_stage(compressor, packed=packed,
                                          align=align,
                                          leaf_routes=leaf_routes)

    def local_fn(key, x_half, hat_list, s_list):
        sample_key = key
        for a in axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(a))
        leaves_x, treedef = jax.tree_util.tree_flatten(x_half)
        hat = treedef.flatten_up_to(hat_list[0])
        own_ring = [treedef.flatten_up_to(tr) for tr in hat_list[1:]]
        S = [treedef.flatten_up_to(s_list[r]) for r in range(R)]
        rings = [[treedef.flatten_up_to(s_list[R + r * tau + j])
                  for j in range(tau)] for r in range(R)]
        flat_idx = _LazyFlatIndex(axes, sizes)
        i = flat_idx()
        for t in range(gossip_steps):
            tkey = key if t == 0 else jax.random.fold_in(key, t)
            payloads, q_trees, hat, dense_fn = _ef_send_half(
                compress_stage, tkey, leaves_x, hat)
            if tau:
                own_ring = [q_trees] + own_ring[:-1]
            dvecs = process.round_delays(
                process.edge_delays(sample_key, t))
            acc = [jnp.zeros((), a.dtype) for a in leaves_x]
            for r in range(R):
                got = jax.lax.ppermute(payloads, axis_arg,
                                       list(rounds[r].perm))
                recv_dense = dense_fn(got)
                recv_trees = [rd.reshape(sv.shape).astype(sv.dtype)
                              for sv, rd in zip(S[r], recv_dense)]
                # the replica ALWAYS integrates (the payload was sent; the
                # delay gates only which snapshot the update reads below)
                S[r] = [sv + rt for sv, rt in zip(S[r], recv_trees)]
                if tau:
                    rings[r] = [recv_trees] + rings[r][:-1]
                d = dvecs[r][i]
                wv = jnp.asarray(process.round_recv[r], jnp.float32)[i]
                diff = [sr - h for sr, h in zip(S[r], hat)]
                for j in range(tau):
                    m = (d > j).astype(jnp.float32)
                    diff = [df - m * (rr - orr)
                            for df, rr, orr in zip(diff, rings[r][j],
                                                   own_ring[j])]
                acc = [a + wv * df for a, df in zip(acc, diff)]
            # acc is f32 (strong per-node weights / masks): cast the whole
            # update back so bf16 params stay bf16
            leaves_x = [a + (gamma * ac).astype(a.dtype)
                        for a, ac in zip(leaves_x, acc)]
        u = treedef.unflatten
        new_hat_list = [u(hat)] + [u(tr) for tr in own_ring]
        new_s_list = ([u(S[r]) for r in range(R)]
                      + [u(rings[r][j]) for r in range(R)
                         for j in range(tau)])
        return u(leaves_x), new_hat_list, new_s_list

    return local_fn
